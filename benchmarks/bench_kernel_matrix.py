"""Paper Fig. 4: per-kernel transfer matrix.

Every kernel of the target arch evaluated with every compatible donor
schedule; invalid transfers (the paper's -1 bars) reported as such.
Target: mixtral-8x22b from its heuristic donor dbrx-132b — the same-family
pair (both d_model=6144 MoE), the ResNet18-from-ResNet50 analogue.
"""
from __future__ import annotations

from benchmarks import common
from repro.core.heuristic import select_donor
from repro.core.runner import default_runner
from repro.core.transfer import transfer_matrix
from repro.core.tuner import arch_uses

TARGET = "mixtral-8x22b"


def run() -> list[tuple]:
    db = common.full_db()
    uses = arch_uses(TARGET, common.SHAPE, dp=common.DP, tp=common.TP)
    # One memoizing runner serves donor selection and every matrix cell.
    runner = default_runner()
    donor = select_donor(uses, db, exclude=(TARGET,), runner=runner)
    mat = transfer_matrix(uses, db, donors=[donor], runner=runner)
    rows = []
    payload = {"target": TARGET, "donor": donor, "cells": {}}
    total = valid = 0
    for u in uses:
        row = mat[u.instance.workload_key()]
        untuned = runner.seconds(u.instance)
        best = min((s for s in row.values() if s is not None), default=None)
        n_inv = sum(1 for s in row.values() if s is None)
        total += len(row)
        valid += len(row) - n_inv
        rows.append((
            f"fig4/{u.tag}",
            round((best if best is not None else untuned) * 1e6, 3),
            f"class={u.instance.class_id} donors={len(row)} invalid={n_inv}"
            f" best_speedup={untuned / best if best else 1.0:.2f}x",
        ))
        payload["cells"][u.tag] = {
            "class": u.instance.class_id, "untuned_s": untuned,
            "schedules": {k: v for k, v in row.items()},
        }
    payload["valid_fraction"] = valid / max(total, 1)
    tele = payload["runner"] = runner.telemetry()
    common.save_result("fig4_kernel_matrix", payload, metrics={
        "valid_fraction": payload["valid_fraction"],
        "unique_evaluations": tele["measurements"],
    }, gated={"valid_fraction": "higher"})
    rows.append(("fig4/valid_fraction", round(100 * valid / max(total, 1), 1),
                 f"{valid}/{total} transfers produced valid code"))
    rows.append(("fig4/unique_evaluations", int(tele["measurements"]),
                 f"requests={int(tele['requests'])} cache_hits={int(tele['cache_hits'])}"))
    return rows


if __name__ == "__main__":
    common.emit(run(), "Fig.4 — per-kernel transfer matrix")
