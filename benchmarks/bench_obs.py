"""Observability overhead benchmark: tracing must be (nearly) free.

The unified observability layer (DESIGN.md §10) claims two properties:

1. **Disabled is free, enabled is cheap** — the default ``NULL_TRACER``
   costs one attribute check per instrumentation site, and a live tracer
   appends records without perturbing the run.  The same fleet scenario is
   served twice — tracer off, tracer on — and the *wall-clock* throughput
   delta must stay under ``MAX_OVERHEAD_PCT``.
2. **Observation does not change behaviour** — both runs must produce the
   *identical virtual outcome*: same completions, sheds, tokens, makespan,
   and latency percentiles, and 0 cross-replica schedule mismatches.  The
   virtual clock is deterministic, so any divergence means instrumentation
   leaked into the serving path.

On top, the trace itself is validated end-to-end: the Chrome export is
re-loaded and ``repro.obs.report`` must reproduce the fleet's p95 within
1% (the acceptance bound; they agree exactly by construction — the async
request spans carry the very intervals ``FleetMetrics`` aggregates).  The
sample trace is saved to ``benchmarks/results/trace.json`` so CI uploads a
Perfetto-loadable artifact every run.
"""
from __future__ import annotations

import argparse
import os
import time

import jax

from benchmarks import common
from repro.configs import get_arch, reduced
from repro.fleet import ServingFleet, TrafficGenerator
from repro.models import build_model
from repro.obs import Tracer
from repro.obs import report as obs_report
from repro.obs.export import load_records, write_chrome_trace

MAX_OVERHEAD_PCT = 5.0   # enabled-vs-disabled wall-clock budget
P95_TOLERANCE = 0.01     # trace_report p95 vs FleetMetrics p95

PRESETS = {
    "smoke": {"arch": "minitron-4b", "replicas": 2, "slots": 2,
              "max_len": 32, "requests": 48, "arrival_rate": 1.0,
              "queue_cap": 8, "repeats": 3, "seed": 0},
    "full": {"arch": "minitron-4b", "replicas": 3, "slots": 2,
             "max_len": 64, "requests": 128, "arrival_rate": 1.2,
             "queue_cap": 12, "repeats": 5, "seed": 0},
}


def _serve(p: dict, model, params, cfg, tracer) -> tuple[dict, float]:
    """One serve of the preset trace; returns (summary, wall seconds)."""
    fleet = ServingFleet(cfg, model, params, replicas=p["replicas"],
                         slots=p["slots"], max_len=p["max_len"],
                         policy="least_loaded", queue_cap=p["queue_cap"],
                         seed=p["seed"], tracer=tracer)
    gen = TrafficGenerator(seed=p["seed"], vocab_size=cfg.vocab_size,
                           arrival_rate=p["arrival_rate"],
                           tick_s=fleet.tick_s, short_lens=(3, 6),
                           long_lens=(8, 12), new_tokens=(2, 4),
                           prompt_cap=p["max_len"] // 2)
    trace = gen.trace(p["requests"])
    t0 = time.monotonic()
    summary = fleet.serve(trace)
    wall = time.monotonic() - t0
    fleet.close()
    return summary, wall


def _virtual_outcome(s: dict) -> dict:
    """The behaviour fingerprint both runs must share exactly."""
    return {"completed": s["completed"], "shed": s["shed"],
            "tokens": s["tokens"], "makespan_s": s["makespan_s"],
            "latency_p50": s["latency_s"]["p50"],
            "latency_p95": s["latency_s"]["p95"],
            "schedule_mismatches": s["schedule_mismatches"]}


def run(preset: str = "smoke") -> list[tuple]:
    p = PRESETS[preset]
    cfg = reduced(get_arch(p["arch"]))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # Warm-up run: jit compilation must not be charged to either arm.
    _serve(p, model, params, cfg, None)

    # Best-of-N wall times, arms interleaved against drift.
    off_walls, on_walls = [], []
    off_sum = on_sum = tracer = None
    for _ in range(p["repeats"]):
        off_sum, w = _serve(p, model, params, cfg, None)
        off_walls.append(w)
        tracer = Tracer()
        on_sum, w = _serve(p, model, params, cfg, tracer)
        on_walls.append(w)

    off_w, on_w = min(off_walls), min(on_walls)
    overhead_pct = (on_w - off_w) / off_w * 100.0
    same = _virtual_outcome(off_sum) == _virtual_outcome(on_sum)
    mismatches = (off_sum["schedule_mismatches"]
                  + on_sum["schedule_mismatches"])

    # Trace round-trip: export -> load -> report must rebuild the fleet p95.
    os.makedirs(common.RESULTS_DIR, exist_ok=True)
    trace_path = os.path.join(common.RESULTS_DIR, "trace.json")
    write_chrome_trace(trace_path, tracer)
    rep = obs_report.summarize(load_records(trace_path))
    fleet_p95 = on_sum["latency_s"]["p95"]
    trace_p95 = rep["latency"]["latency_s"]["p95"]
    p95_err = (abs(trace_p95 - fleet_p95) / fleet_p95 if fleet_p95 else 0.0)

    overhead_ok = overhead_pct < MAX_OVERHEAD_PCT
    p95_ok = p95_err <= P95_TOLERANCE
    rows = [
        ("obs/disabled_wall_s", round(off_w, 4),
         f"{p['requests']} requests, best of {p['repeats']}"),
        ("obs/enabled_wall_s", round(on_w, 4),
         f"spans={tracer.counts()['spans']} events={tracer.counts()['events']}"),
        ("obs/overhead_pct", round(overhead_pct, 2),
         f"< {MAX_OVERHEAD_PCT}%: {'PASS' if overhead_ok else 'FAIL'}"),
        ("obs/identical_virtual_outcome", int(same),
         f"mismatches={mismatches}: "
         f"{'PASS' if same and mismatches == 0 else 'FAIL'}"),
        ("obs/trace_report_p95_err", round(p95_err, 6),
         f"trace {trace_p95:.6g} vs fleet {fleet_p95:.6g}, "
         f"<= {P95_TOLERANCE:.0%}: {'PASS' if p95_ok else 'FAIL'}"),
    ]
    common.save_result("obs", {
        "preset": preset,
        "arch": p["arch"],
        "repeats": p["repeats"],
        "disabled_wall_s": off_walls,
        "enabled_wall_s": on_walls,
        "overhead_pct": overhead_pct,
        "identical_virtual_outcome": same,
        "schedule_mismatches": mismatches,
        "trace_counts": tracer.counts(),
        "fleet_p95_s": fleet_p95,
        "trace_report_p95_s": trace_p95,
        "trace_report_p95_err": p95_err,
        "disabled_summary": _virtual_outcome(off_sum),
        "enabled_summary": _virtual_outcome(on_sum),
        "report_latency": rep["latency"],
        "pass": bool(overhead_ok and same and mismatches == 0 and p95_ok),
    }, metrics={
        "overhead_pct": overhead_pct,
        "trace_report_p95_err": p95_err,
        "schedule_mismatches": mismatches,
    }, gated={
        "trace_report_p95_err": "lower",
        "schedule_mismatches": "lower",
    })
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default="smoke")
    args = ap.parse_args()
    common.emit(run(args.preset),
                "Observability overhead — tracing on vs off, trace fidelity")
