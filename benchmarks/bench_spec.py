"""Speculative decoding as a transfer-tuned workload: three claims.

A draft-then-verify serving path only pays off when (a) the batched verify
step really costs about one decode step (memory-bound regime), (b) greedy
acceptance keeps the committed stream bit-exact, and (c) the new ``verify``
workload class does not reopen a cold tuning bill.  This benchmark checks
all three against the plain paged engine:

1. **throughput** — two single-replica paged fleets serve the *same*
   seeded decode-heavy trace (short prompts, long generations); the
   speculating fleet (truncated self-draft, ``keep_layers=1``, lightly
   damped deep layers so acceptance is high but not trivially 1.0) must
   reach >= 1.5x the plain fleet's token throughput in virtual seconds;
2. **equivalence** — standalone engines, same prompts: the speculative
   engine's committed tokens must match plain greedy decode exactly
   (0 mismatches), with bursts genuinely mixing accepts and rejects;
3. **transfer-seeded tuning** — the verify cell shares every non-head
   kernel workload with chunk prefill, so transfer-tuning it from the
   chunk/decode donors a plain serving fleet has already tuned must reach
   the same schedule quality in fewer virtual search seconds than cold
   auto-scheduling the verify cells from scratch.

The target is the reduced minitron-4b deepened to 8 layers: speculation's
economics need a real draft/target depth gap (a 2-layer target drafts
almost nothing), and the deeper stack keeps decode/verify memory-bound so
the analytical cost model prices a burst at ``(k+1) * draft + verify``
against ``E[committed] * decode``.  All times are virtual (cost-model /
measurement-harness) seconds; see DESIGN.md §11.
"""
from __future__ import annotations

import argparse
import dataclasses
import shutil
import tempfile

import jax
import numpy as np

from benchmarks import common
from repro.configs import get_arch, reduced
from repro.configs.base import ShapeConfig
from repro.core.autoscheduler import tune_model, tune_model_into_db
from repro.core.database import ScheduleDB
from repro.core.extract import extract_kernels
from repro.core.resolution import spec_verify_uses
from repro.core.transfer import transfer_tune
from repro.fleet import ServingFleet, TrafficGenerator
from repro.models import build_model
from repro.serving import PagedServingEngine, make_self_draft
from repro.service import ScheduleRegistry

#: ``requests`` sizes the served trace; ``trials`` is the cold tuning
#: budget the transfer path is raced against in gate 3.
PRESETS = {
    "smoke": {"requests": 24, "trials": 256},
    "full": {"requests": 64, "trials": 768},
}

ARCH = "minitron-4b"
N_LAYERS = 8              # deepened: draft/target gap is the whole economics
KEEP_LAYERS = 1           # truncated self-draft depth
DAMP = 0.01               # deep-layer damping: high-but-not-1.0 acceptance
SPEC_K = 4                # draft tokens per burst
REPLICAS = 1
SLOTS = 4
MAX_LEN = 96
DECODE_BATCH = 8
PAGE_SIZE = 4
CHUNK = 16                # == prompt cap: one exact chunk per prompt
ADMIT_CAP = 16
QUEUE_CAP = 128
SEED = 3
#: Donor-pool truncation for the transfer race — the same strongest-first
#: cap the tuning service applies to its probe candidates; an uncapped
#: pool spends more virtual seconds measuring weak donors than the gap to
#: cold tuning is worth.
MAX_CANDIDATES = 6
#: Decode-heavy and bursty: short prompts, long generations, arrivals fast
#: enough that both fleets run work-bound (the makespan measures service
#: rate, not the arrival process).
TRAFFIC = {"arrival_rate": 4.0, "short_lens": (3, 8), "long_lens": (8, 12),
           "long_frac": 0.1, "prompt_cap": 16, "new_tokens": (24, 40),
           "long_new_tokens": (40, 56),
           "class_mix": {"chat": 0.7, "bulk": 0.3}}


def _trace(cfg, tick_s: float, n: int):
    """Fresh generator, fixed seed: both fleets see the identical stream."""
    gen = TrafficGenerator(seed=SEED, vocab_size=cfg.vocab_size,
                           tick_s=tick_s, **TRAFFIC)
    return gen.trace(n)


def _run_fleet(scratch: str, n: int, tick_s: float, *, model, params, cfg,
               draft=None, draft_params=None) -> dict:
    kw = {}
    if draft is not None:
        kw = {"speculative": True, "draft_model": draft,
              "draft_params": draft_params, "spec_k": SPEC_K}
    fleet = ServingFleet(cfg, model, params, replicas=REPLICAS, slots=SLOTS,
                         max_len=MAX_LEN, engine="paged",
                         decode_batch=DECODE_BATCH, page_size=PAGE_SIZE,
                         pool_pages=DECODE_BATCH * MAX_LEN // PAGE_SIZE + 1,
                         chunk=CHUNK, admit_cap=ADMIT_CAP,
                         registry=ScheduleRegistry(
                             tempfile.mkdtemp(dir=scratch)),
                         policy="plan_aware", queue_cap=QUEUE_CAP, **kw)
    try:
        return fleet.serve(_trace(cfg, tick_s, n))
    finally:
        fleet.close()


def _equivalence(model, params, draft, draft_params) -> dict:
    """Committed tokens must equal plain greedy decode, bit for bit."""
    rng = np.random.default_rng(11)
    prompts = [[int(t) for t in rng.integers(1, model.cfg.vocab_size, size=n)]
               for n in (3, 11, 7, 14, 5, 9)]
    mnt = 16

    def run(spec: bool):
        kw = {"draft_model": draft, "draft_params": draft_params,
              "spec_k": SPEC_K} if spec else {}
        eng = PagedServingEngine(model, params, decode_batch=len(prompts),
                                 max_ctx=MAX_LEN, page_size=PAGE_SIZE,
                                 chunk=CHUNK, **kw)
        reqs = [eng.add_request(p, max_new_tokens=mnt) for p in prompts]
        eng.run_to_completion()
        return reqs, eng

    plain_reqs, _ = run(spec=False)
    spec_reqs, eng = run(spec=True)
    mismatches = sum(a.generated != b.generated
                     for a, b in zip(plain_reqs, spec_reqs))
    return {"requests": len(prompts),
            "token_mismatches": int(mismatches),
            "bursts": eng.spec_bursts,
            "proposed": eng.spec_proposed,
            "accepted": eng.spec_accepted,
            "committed": eng.spec_committed,
            "acceptance": eng.spec_accepted / max(eng.spec_proposed, 1)}


def _transfer_race(cfg, trials: int) -> dict:
    """Transfer-seed the verify cells from chunk/decode donors vs cold tune.

    The donor pool is exactly what a *plain* paged serving fleet has
    already tuned — its decode and chunk-prefill cells — so the race
    models flipping ``--speculative`` on over a warm registry.
    """
    verify = spec_verify_uses(cfg, decode_batch=DECODE_BATCH,
                              max_ctx=MAX_LEN, spec_k=SPEC_K)
    donors = list(extract_kernels(
        cfg, ShapeConfig("paged_decode", MAX_LEN, DECODE_BATCH, "decode"),
        dp=1, tp=1))
    donors += list(extract_kernels(
        cfg, ShapeConfig(f"paged_chunk_{CHUNK}", CHUNK, 1, "chunk_prefill",
                         ctx_len=MAX_LEN), dp=1, tp=1))
    db = ScheduleDB()
    tune_model_into_db(db, donors, model_id=ARCH, total_trials=trials,
                       seed=common.SEED)

    res = transfer_tune(verify, db, model_id=f"{ARCH}-spec-verify",
                        mode="adaptive",
                        max_candidates_per_kernel=MAX_CANDIDATES)
    cold = tune_model(verify, model_id=f"{ARCH}-spec-verify-cold",
                      total_trials=trials, seed=common.SEED)
    cold_to_match = None
    for p in cold.trace:
        if p.best_seconds <= res.tuned_seconds:
            cold_to_match = p.search_time_s
            break
    return {"transfer_search_time_s": res.search_time_s,
            "transfer_tuned_seconds": res.tuned_seconds,
            "transfer_speedup": res.speedup,
            "transfer_coverage": res.coverage(),
            "exact_hits": sum(k.exact_hit for k in res.kernels),
            "kernels": len(res.kernels),
            "cold_search_time_s": cold.search_time_s,
            "cold_tuned_seconds": cold.tuned_seconds,
            "cold_time_to_match_s": cold_to_match}


def run(preset: str = "smoke") -> list[tuple]:
    p = PRESETS[preset]
    cfg = dataclasses.replace(reduced(get_arch(ARCH)), n_layers=N_LAYERS)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dcfg, dparams, params = make_self_draft(cfg, params,
                                            keep_layers=KEEP_LAYERS,
                                            damp=DAMP)
    draft = build_model(dcfg)

    scratch = tempfile.mkdtemp(prefix="spec-bench-")
    try:
        probe = ServingFleet(cfg, model, params, replicas=REPLICAS,
                             slots=SLOTS, max_len=MAX_LEN, engine="paged",
                             decode_batch=DECODE_BATCH, page_size=PAGE_SIZE,
                             chunk=CHUNK,
                             registry=ScheduleRegistry(
                                 tempfile.mkdtemp(dir=scratch)))
        tick_s = probe.tick_s
        probe.close()

        plain = _run_fleet(scratch, p["requests"], tick_s,
                           model=model, params=params, cfg=cfg)
        spec = _run_fleet(scratch, p["requests"], tick_s,
                          model=model, params=params, cfg=cfg,
                          draft=draft, draft_params=dparams)
        equiv = _equivalence(model, params, draft, dparams)
        race = _transfer_race(cfg, p["trials"])

        ratio = (spec["throughput_tok_per_s"] /
                 max(plain["throughput_tok_per_s"], 1e-12))
        sc = spec["speculative"]["counters"]
        burst_tokens = sc["committed"] / max(sc["bursts"], 1)
        alpha = sc["accepted"] / max(sc["proposed"], 1)
        ttm = race["cold_time_to_match_s"]
        race_pass = ttm is None or race["transfer_search_time_s"] < ttm
        race_note = ("cold never matched within budget" if ttm is None else
                     f"cold_to_match={ttm:.1f}s "
                     f"(x{ttm / max(race['transfer_search_time_s'], 1e-12):.1f})")

        rows = [
            ("spec/plain_throughput_tok_per_s",
             round(plain["throughput_tok_per_s"], 1),
             f"p95_ticks={plain['latency_ticks']['p95']:.1f}"),
            ("spec/spec_throughput_tok_per_s",
             round(spec["throughput_tok_per_s"], 1),
             f"x{ratio:.2f} vs plain (>=1.5x): "
             f"{'PASS' if ratio >= 1.5 else 'FAIL'} "
             f"alpha={alpha:.2f} committed/burst={burst_tokens:.2f}"),
            ("spec/token_mismatches", equiv["token_mismatches"],
             f"committed stream vs plain greedy decode "
             f"(acceptance={equiv['acceptance']:.2f}, "
             f"{equiv['bursts']} bursts): "
             f"{'PASS' if equiv['token_mismatches'] == 0 else 'FAIL'}"),
            ("spec/transfer_search_time_s",
             round(race["transfer_search_time_s"], 2),
             f"vs cold verify tuning, {race_note}: "
             f"{'PASS' if race_pass else 'FAIL'} "
             f"exact_hits={race['exact_hits']}/{race['kernels']}"),
        ]
        common.save_result("spec", {
            "preset": preset,
            "arch": ARCH,
            "config": {"n_layers": N_LAYERS, "keep_layers": KEEP_LAYERS,
                       "damp": DAMP, "spec_k": SPEC_K,
                       "replicas": REPLICAS, "max_len": MAX_LEN,
                       "decode_batch": DECODE_BATCH, "page_size": PAGE_SIZE,
                       "chunk": CHUNK, "admit_cap": ADMIT_CAP,
                       "queue_cap": QUEUE_CAP, "seed": SEED,
                       "requests": p["requests"], "trials": p["trials"],
                       **{k: list(v) if isinstance(v, tuple) else v
                          for k, v in TRAFFIC.items()}},
            "plain": plain,
            "spec": spec,
            "throughput_ratio": ratio,
            "equivalence": equiv,
            "transfer_race": race,
            "pass": bool(ratio >= 1.5 and equiv["token_mismatches"] == 0
                         and race_pass),
        }, metrics={
            "throughput_ratio": ratio,
            "token_mismatches": equiv["token_mismatches"],
            "spec_throughput_tok_per_s": spec["throughput_tok_per_s"],
            "transfer_search_time_s": race["transfer_search_time_s"],
        }, gated={
            "throughput_ratio": "higher",
            "token_mismatches": "lower",
        })
        return rows
    finally:
        shutil.rmtree(scratch, ignore_errors=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default="smoke")
    args = ap.parse_args()
    common.emit(run(args.preset),
                "Speculative draft-then-verify vs plain paged decode")
