"""Online schedule-registry service: cold-start serve stream benchmark.

A target arch is served cold against a registry holding only a donor arch's
auto-schedules.  Each "request" resolves every kernel of the target through
:class:`~repro.service.TuningService.lookup` and sums the resulting
cost-model kernel seconds; between requests a bounded number of background
transfer-tuning jobs drain and publish, so the stream's kernel seconds
improve as upgrades land (the acceptance trajectory).

Reported:

* per-request kernel seconds (the trajectory) + first/last improvement;
* ``stats()`` telemetry — upgrades, hit tiers, virtual search seconds;
* equivalence: the drained service must serve *identical* schedules to an
  offline :func:`~repro.core.tuner.transfer_arch` run over the same donor
  store, mode, seed, and budget.

``--preset smoke`` (CI) tunes the donor at a small trial budget; ``full``
uses two donors and a larger budget.
"""
from __future__ import annotations

import argparse
import shutil
import tempfile

from benchmarks import common
from repro.core.runner import AnalyticalRunner, CachedRunner
from repro.core.tuner import arch_uses, transfer_arch, tune_arch_registry
from repro.service import ScheduleRegistry, TuningService

TARGET = "stablelm-12b"
PRESETS = {
    # donor archs share every kernel class with the target (internvl2) or a
    # subset (starcoder2), so transfers land on all / most classes.
    "smoke": {"donors": ["internvl2-26b"], "trials": 256, "requests": 6,
              "jobs_per_request": 2},
    "full": {"donors": ["internvl2-26b", "starcoder2-7b"], "trials": 768,
             "requests": 10, "jobs_per_request": 2},
}


def run(preset: str = "smoke") -> list[tuple]:
    p = PRESETS[preset]
    uses = arch_uses(TARGET, common.SHAPE, dp=common.DP, tp=common.TP)
    root = tempfile.mkdtemp(prefix="schedule-registry-")
    try:
        registry = ScheduleRegistry(root)
        for donor in p["donors"]:
            tune_arch_registry(registry, donor, common.SHAPE, dp=common.DP,
                               tp=common.TP, total_trials=p["trials"],
                               seed=common.SEED)
        donor_db = registry.snapshot().db(None)  # frozen for the offline run

        # Cold-start stream: probes disabled so the trajectory isolates the
        # background-upgrade path (first request = untuned, upgrades land
        # between requests).  max_workers=0 defers jobs to drain() — the
        # deterministic stepping; serve.py uses the threaded pool.
        runner = CachedRunner(AnalyticalRunner())
        service = TuningService(registry, model_id=TARGET, runner=runner,
                                donors=list(p["donors"]), seed=common.SEED,
                                max_workers=0, probe_candidates=0)
        trajectory: list[float] = []
        hit_rates: list[float] = []
        for _ in range(p["requests"]):
            lookups = [service.lookup(u.instance) for u in uses]
            trajectory.append(
                sum(u.use_count * r.seconds for u, r in zip(uses, lookups)))
            hit_rates.append(
                sum(1 for r in lookups if r.tier == "exact") / len(lookups))
            service.drain(max_jobs=p["jobs_per_request"])
        service.drain()
        final = {u.instance.workload_key(): service.lookup(u.instance)
                 for u in uses}
        stats = service.stats()

        # Offline equivalence: same donors, mode, seed, unlimited budget.
        offline = transfer_arch(donor_db, TARGET, common.SHAPE, dp=common.DP,
                                tp=common.TP, donors=list(p["donors"]),
                                mode="strict", seed=common.SEED)
        mismatches = sum(
            1 for k in offline.kernels
            if final[k.instance.workload_key()].schedule != k.chosen)

        improvement = trajectory[0] / trajectory[-1]
        untuned = sum(u.use_count * runner.seconds(u.instance, None) for u in uses)
        rows = [
            ("service/first_request_s", round(trajectory[0] * 1e6, 1),
             f"untuned_s={untuned:.4f} exact_hit_rate={hit_rates[0]:.2f}"),
            ("service/last_request_s", round(trajectory[-1] * 1e6, 1),
             f"exact_hit_rate={hit_rates[-1]:.2f} upgrades={stats['upgrades']}"),
            ("service/stream_improvement", round(improvement, 3),
             f"acceptance >1 with rising hits: "
             f"{'PASS' if improvement > 1 and hit_rates[-1] > hit_rates[0] and stats['upgrades'] > 0 else 'FAIL'}"),
            ("service/offline_equivalence", mismatches,
             f"schedules differing from offline transfer_arch: "
             f"{'PASS' if mismatches == 0 else 'FAIL'}"),
            ("service/search_seconds", round(stats["search_seconds_spent"], 1),
             f"offline search_s={offline.search_time_s:.1f} "
             f"jobs={stats['jobs_completed']} deduped={stats['jobs_deduped']}"),
        ]
        common.save_result("service", {
            "preset": preset,
            "target": TARGET,
            "donors": p["donors"],
            "trials": p["trials"],
            "untuned_seconds": untuned,
            "trajectory_seconds": trajectory,
            "exact_hit_rates": hit_rates,
            "stream_improvement": improvement,
            "offline_mismatches": mismatches,
            "offline_search_s": offline.search_time_s,
            "stats": stats,
            "registry": registry.stats(),
            "pass": bool(improvement > 1 and hit_rates[-1] > hit_rates[0]
                         and stats["upgrades"] > 0 and mismatches == 0),
        }, metrics={
            "stream_improvement": improvement,
            "offline_mismatches": mismatches,
            "final_exact_hit_rate": hit_rates[-1],
            "search_seconds": stats["search_seconds_spent"],
        }, gated={
            "stream_improvement": "higher",
            "offline_mismatches": "lower",
        })
        return rows
    finally:
        shutil.rmtree(root, ignore_errors=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default="smoke")
    args = ap.parse_args()
    common.emit(run(args.preset), "Schedule-registry service — cold-start serve stream")
