"""Serving-fleet benchmark: routing policy + demand-driven tuning payoff.

Three configurations serve the *same* seeded Poisson trace against
identical copies of a donor-seeded schedule registry:

1. **single**  — one engine replica, round-robin, no prefetch (the
   pre-fleet `launch/serve.py` shape);
2. **rr**      — N replicas, ``round_robin`` dispatch, no prefetch;
3. **pa**      — N replicas, ``plan_aware`` dispatch + demand-driven
   prefetch (hot prefill buckets tuned first).

Claims checked:

* the fleet beats the single engine on throughput for the same trace;
* ``plan_aware``+prefetch beats ``round_robin`` on p95 latency *and* on the
  final traffic-weighted exact-tier share — same trace, same background
  drain pacing, the only differences are dispatch policy and tuning order;
* shared-registry propagation leaves 0 cross-replica schedule
  byte-mismatches in every fleet run; shed rates are reported.

Latency/throughput are virtual (cost-model) seconds — schedule quality is
the *only* speed signal, so the benchmark isolates exactly the effect the
fleet subsystem claims.
"""
from __future__ import annotations

import argparse
import os
import shutil
import tempfile

import jax

from benchmarks import common
from repro.configs import get_arch, reduced
from repro.core.tuner import tune_arch_registry
from repro.fleet import ServingFleet, TrafficGenerator
from repro.models import build_model
from repro.service import ScheduleRegistry

#: The traffic mix is long-prompt heavy (``long_frac`` 0.7): the hot prefill
#: bucket is the *largest*, i.e. the last one plan-construction order would
#: reach — so FIFO background tuning (round_robin run) spends its bounded
#: drain budget on cold small buckets while demand-driven prefetch jumps the
#: hot bucket to the front.  Drain pacing (``drain_jobs`` per burst, a burst
#: every ``drain_every`` events) is identical across runs and deliberately
#: too small to tune everything before the trace ends.
PRESETS = {
    "smoke": {"arch": "minitron-4b", "donors": ["internvl2-26b"],
              "trials": 256, "replicas": 2, "slots": 2, "max_len": 32,
              "requests": 32, "arrival_rate": 0.85, "queue_cap": 8,
              "new_tokens": (3, 6), "short_lens": (3, 6),
              "long_lens": (10, 16), "long_frac": 0.7,
              "deadline_ticks": None, "drain_jobs": 1, "drain_every": 12,
              "seed": 0},
    "full": {"arch": "minitron-4b", "donors": ["internvl2-26b",
                                               "starcoder2-7b"],
             "trials": 768, "replicas": 3, "slots": 2, "max_len": 64,
             "requests": 64, "arrival_rate": 1.0, "queue_cap": 12,
             "new_tokens": (3, 8), "short_lens": (3, 8),
             "long_lens": (20, 32), "long_frac": 0.7,
             "deadline_ticks": None, "drain_jobs": 1, "drain_every": 8,
             "seed": 0},
}


def _run_fleet(p: dict, base_registry: str, scratch: str, *, replicas: int,
               policy: str, prefetch: bool, model, params, cfg) -> dict:
    """One configuration over a fresh copy of the donor registry and a
    freshly regenerated (identical: same seed) trace."""
    root = os.path.join(scratch, f"{policy}-{replicas}-{int(prefetch)}")
    shutil.copytree(base_registry, root)
    fleet = ServingFleet(cfg, model, params, replicas=replicas,
                         slots=p["slots"], max_len=p["max_len"],
                         registry=ScheduleRegistry(root), policy=policy,
                         queue_cap=p["queue_cap"], prefetch=prefetch,
                         drain_jobs=p["drain_jobs"],
                         drain_every=p["drain_every"], seed=p["seed"])
    gen = TrafficGenerator(seed=p["seed"], vocab_size=cfg.vocab_size,
                           arrival_rate=p["arrival_rate"],
                           tick_s=fleet.tick_s,
                           short_lens=tuple(p["short_lens"]),
                           long_lens=tuple(p["long_lens"]),
                           long_frac=p["long_frac"],
                           new_tokens=tuple(p["new_tokens"]),
                           deadline_ticks=p["deadline_ticks"],
                           prompt_cap=p["max_len"] // 2)
    try:
        summary = fleet.serve(gen.trace(p["requests"]))
    finally:
        fleet.close()
    summary["config"] = {"replicas": replicas, "policy": policy,
                         "prefetch": prefetch}
    return summary


def run(preset: str = "smoke") -> list[tuple]:
    p = PRESETS[preset]
    cfg = reduced(get_arch(p["arch"]))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    scratch = tempfile.mkdtemp(prefix="fleet-bench-")
    base = os.path.join(scratch, "base-registry")
    try:
        registry = ScheduleRegistry(base)
        for donor in p["donors"]:
            tune_arch_registry(registry, donor, common.SHAPE, dp=common.DP,
                               tp=common.TP, total_trials=p["trials"],
                               seed=common.SEED)

        single = _run_fleet(p, base, scratch, replicas=1,
                            policy="round_robin", prefetch=False,
                            model=model, params=params, cfg=cfg)
        rr = _run_fleet(p, base, scratch, replicas=p["replicas"],
                        policy="round_robin", prefetch=False,
                        model=model, params=params, cfg=cfg)
        pa = _run_fleet(p, base, scratch, replicas=p["replicas"],
                        policy="plan_aware", prefetch=True,
                        model=model, params=params, cfg=cfg)

        scale = (rr["throughput_tok_per_s"] /
                 max(single["throughput_tok_per_s"], 1e-12))
        p95_rr = rr["latency_ticks"]["p95"]
        p95_pa = pa["latency_ticks"]["p95"]
        mismatches = rr["schedule_mismatches"] + pa["schedule_mismatches"]
        policy_ok = (p95_pa < p95_rr
                     and pa["final_exact_share"] > rr["final_exact_share"]
                     and mismatches == 0)
        rows = [
            ("fleet/single_throughput_tok_per_s",
             round(single["throughput_tok_per_s"], 1),
             f"shed_rate={single['shed_rate']:.2f} "
             f"p95_ticks={single['latency_ticks']['p95']:.1f}"),
            ("fleet/fleet_throughput_tok_per_s",
             round(rr["throughput_tok_per_s"], 1),
             f"{p['replicas']} replicas, x{scale:.2f} vs single: "
             f"{'PASS' if scale > 1 else 'FAIL'}"),
            ("fleet/round_robin_p95_ticks", round(p95_rr, 1),
             f"shed_rate={rr['shed_rate']:.2f} "
             f"exact_share={rr['final_exact_share']:.2f}"),
            ("fleet/plan_aware_prefetch_p95_ticks", round(p95_pa, 1),
             f"shed_rate={pa['shed_rate']:.2f} "
             f"exact_share={pa['final_exact_share']:.2f} "
             f"prefetched={pa['prefetched']}"),
            ("fleet/policy_win", round(p95_rr / max(p95_pa, 1e-9), 2),
             f"plan_aware+prefetch vs round_robin on p95 and exact share, "
             f"mismatches={mismatches}: "
             f"{'PASS' if policy_ok else 'FAIL'}"),
        ]
        common.save_result("fleet", {
            "preset": preset,
            "arch": p["arch"],
            "donors": p["donors"],
            "trials": p["trials"],
            "trace": {"requests": p["requests"],
                      "arrival_rate": p["arrival_rate"],
                      "seed": p["seed"]},
            "single": single,
            "round_robin": rr,
            "plan_aware_prefetch": pa,
            "fleet_vs_single_throughput": scale,
            "pass": bool(scale > 1 and policy_ok),
        }, metrics={
            "fleet_vs_single_throughput": scale,
            "plan_aware_p95_ticks": p95_pa,
            "round_robin_p95_ticks": p95_rr,
            "policy_win": p95_rr / max(p95_pa, 1e-9),
        }, gated={
            "fleet_vs_single_throughput": "higher",
            "plan_aware_p95_ticks": "lower",
        })
        return rows
    finally:
        shutil.rmtree(scratch, ignore_errors=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default="smoke")
    args = ap.parse_args()
    common.emit(run(args.preset),
                "Serving fleet — router policies + demand-driven tuning")
