"""Paper Fig. 8 / §5.5: one-to-one vs mixed schedule pool.

Standalone ranking picks the fastest schedule per kernel; the contextual
model (inter-kernel cache-residency coupling, cost_model.contextual_model_
seconds) then evaluates the *full-program* time of those choices.  The
paper's observation: a bigger pool always helps standalone, but can REGRESS
in context — reproduced here as (one2one vs mixed) × (standalone vs
contextual) for all 10 archs.
"""
from __future__ import annotations

from benchmarks import common
from repro.configs import ARCH_IDS
from repro.core.cost_model import contextual_model_seconds
from repro.core.tuner import arch_uses, transfer_arch


def run() -> list[tuple]:
    db = common.full_db()
    rows = []
    payload = {}
    regressions = 0
    for arch in ARCH_IDS:
        uses = arch_uses(arch, common.SHAPE, dp=common.DP, tp=common.TP)
        one = transfer_arch(db, arch, common.SHAPE, dp=common.DP, tp=common.TP,
                            donors="auto", seed=common.SEED)
        pool = [m for m in db.models() if m != arch]  # paper §5.5: every
        # OTHER tuned model's schedules (self-schedules would be exact hits)
        mixed = transfer_arch(db, arch, common.SHAPE, dp=common.DP, tp=common.TP,
                              donors=pool, seed=common.SEED)
        ctx_untuned = contextual_model_seconds(uses, None)
        ctx_one = contextual_model_seconds(uses, one.schedule_map())
        ctx_mixed = contextual_model_seconds(uses, mixed.schedule_map())
        reg = ctx_mixed > ctx_one * 1.0005
        regressions += bool(reg)
        rows.append((
            f"fig8/{arch}",
            round(mixed.tuned_seconds * 1e6, 1),
            f"one2one={one.speedup:.2f}x mixed={mixed.speedup:.2f}x "
            f"ctx_one2one={ctx_untuned / ctx_one:.2f}x ctx_mixed={ctx_untuned / ctx_mixed:.2f}x "
            f"search_ratio={mixed.search_time_s / max(one.search_time_s, 1e-9):.1f}x "
            f"context_regression={'YES' if reg else 'no'}",
        ))
        payload[arch] = {
            "one2one_speedup": one.speedup, "mixed_speedup": mixed.speedup,
            "ctx_one2one_speedup": ctx_untuned / ctx_one,
            "ctx_mixed_speedup": ctx_untuned / ctx_mixed,
            "search_one_s": one.search_time_s, "search_mixed_s": mixed.search_time_s,
            "context_regression": bool(reg),
        }
    rows.append(("fig8/context_regressions", regressions,
                 f"archs where the mixed pool regressed in context "
                 f"(paper: 7 of 11 standalone-picked regress)"))
    mixed = [v["ctx_mixed_speedup"] for v in payload.values()]
    common.save_result("fig8_pool", payload, metrics={
        "mean_ctx_mixed_speedup": sum(mixed) / len(mixed) if mixed else 0.0,
        "context_regressions": regressions,
    }, gated={"mean_ctx_mixed_speedup": "higher"})
    return rows


if __name__ == "__main__":
    common.emit(run(), "Fig.8 — mixed pool vs one-to-one")
