"""Paper Fig. 1: full auto-scheduling speedup and search time per model.

For each of the 10 archs: untuned model seconds, full-budget tuned speedup
("maximum speedup"), and the virtual search time the tuner spent — the
upfront cost transfer-tuning attacks.
"""
from __future__ import annotations

from benchmarks import common
from repro.configs import ARCH_IDS


def run() -> list[tuple]:
    rows = []
    payload = {}
    for arch in ARCH_IDS:
        d = common.tune_arch_cached(arch)
        speedup = d["untuned_seconds"] / d["tuned_seconds"]
        rows.append((
            f"fig1/{arch}",
            round(d["tuned_seconds"] * 1e6, 2),
            f"max_speedup={speedup:.2f}x search_time={d['search_time_s']:.0f}s"
            f" trials={d['trials']}",
        ))
        payload[arch] = {"untuned_s": d["untuned_seconds"],
                         "tuned_s": d["tuned_seconds"],
                         "max_speedup": speedup,
                         "search_time_s": d["search_time_s"]}
    speedups = [d["max_speedup"] for d in payload.values()]
    common.save_result("fig1_full_tuning", payload, metrics={
        "mean_max_speedup": sum(speedups) / len(speedups) if speedups else 0.0,
    }, gated={"mean_max_speedup": "higher"})
    return rows


if __name__ == "__main__":
    common.emit(run(), "Fig.1 — full auto-scheduling per model")
