"""MeasureRunner subsystem: wall-time and measurement-count comparison.

Runs the same mixed-donor-pool transfer workload (paper §5.5 setting: every
donor's schedules compete for every target kernel, plus the Fig. 4 matrix
pass over the identical cells) under three measurement backends:

* ``bare``    — AnalyticalRunner per call (the pre-runner behaviour);
* ``cached``  — one CachedRunner shared across the matrix + tune passes;
* ``pruning`` — PruningRunner(CachedRunner(...)) draft-then-verify.

Reports unique cost-model evaluations, cache hits, virtual search seconds,
and wall time; the cached backend must cut unique evaluations by >= 2x
(the acceptance bar for the runner refactor).
"""
from __future__ import annotations

import time

from benchmarks import common
from repro.core.autoscheduler import tune_kernel
from repro.core.database import Record, ScheduleDB
from repro.core.runner import AnalyticalRunner, CachedRunner, PruningRunner
from repro.core.transfer import transfer_matrix, transfer_tune
from repro.core.workload import KernelInstance, KernelUse

#: Donor pool: GEMMs tuned standalone under distinct donor model ids — the
#: mixed-pool setting where every donor's schedules hit every target kernel.
DONOR_SIZES = {"gemm512": 512, "gemm768": 768, "gemm1024": 1024, "gemm1536": 1536}
TARGET_SIZES = (2048, 1280, 640, 256)
TRIALS = 96
VERIFY_TOP_K = 2


def _g(size: int) -> KernelInstance:
    return KernelInstance.make("matmul", M=size, N=size, K=size)


def _donor_db() -> ScheduleDB:
    db = ScheduleDB()
    for model, size in DONOR_SIZES.items():
        res = tune_kernel(_g(size), trials=TRIALS, seed=common.SEED)
        db.add(Record(_g(size), res.best, res.best_seconds, model))
    return db


def _workload(db: ScheduleDB, runner) -> dict:
    """Fig.4 matrix + mixed-pool transfer over the same cells (one runner)."""
    uses = [KernelUse(_g(s)) for s in TARGET_SIZES]
    before = runner.telemetry()
    t0 = time.monotonic()
    transfer_matrix(uses, db, donors=None, seed=common.SEED, runner=runner)
    tt = transfer_tune(uses, db, donors=None, seed=common.SEED, runner=runner)
    wall = time.monotonic() - t0
    after = runner.telemetry()
    return {
        "wall_s": wall,
        "speedup": tt.speedup,
        "tuned_seconds": tt.tuned_seconds,
        "search_time_s": tt.search_time_s,
        "evaluations": int(after["measurements"] - before["measurements"]),
        "requests": int(after["requests"] - before["requests"]),
        "cache_hits": int(after["cache_hits"] - before["cache_hits"]),
        "pruned": int(after["pruned"] - before["pruned"]),
    }


def run() -> list[tuple]:
    db = _donor_db()
    backends = {
        "bare": AnalyticalRunner(),
        "cached": CachedRunner(AnalyticalRunner()),
        "pruning": PruningRunner(CachedRunner(AnalyticalRunner()),
                                 verify_top_k=VERIFY_TOP_K),
    }
    results = {name: _workload(db, r) for name, r in backends.items()}

    base = results["bare"]
    rows = []
    for name, r in results.items():
        reduction = base["evaluations"] / max(r["evaluations"], 1)
        rows.append((
            f"runner_cache/{name}",
            round(r["wall_s"] * 1e6, 1),
            f"evals={r['evaluations']} hits={r['cache_hits']} pruned={r['pruned']}"
            f" eval_reduction={reduction:.2f}x speedup={r['speedup']:.2f}x"
            f" search_s={r['search_time_s']:.1f}",
        ))
    cached_reduction = base["evaluations"] / max(results["cached"]["evaluations"], 1)
    rows.append((
        "runner_cache/cached_eval_reduction",
        round(cached_reduction, 2),
        f"acceptance >=2x: {'PASS' if cached_reduction >= 2.0 else 'FAIL'}",
    ))
    common.save_result("runner_cache", {
        "donors": list(DONOR_SIZES),
        "targets": list(TARGET_SIZES),
        "verify_top_k": VERIFY_TOP_K,
        "backends": results,
        "cached_eval_reduction": cached_reduction,
        "pass": bool(cached_reduction >= 2.0),
    }, metrics={
        "cached_eval_reduction": cached_reduction,
    }, gated={"cached_eval_reduction": "higher"})
    return rows


if __name__ == "__main__":
    common.emit(run(), "MeasureRunner — cached/pruned measurement backends")
