"""Paged continuous batching vs the fixed-slot engine, at equal KV memory.

Two fleets serve the *same* seeded long-tailed Poisson trace (identical
arrival times, prompts, and generation lengths — the trace is generated
once per engine from the same seed against the slot fleet's tick):

1. **slot**  — the fixed-slot :class:`~repro.serving.ServingEngine`:
   ``slots`` lanes per replica, each provisioned for the worst case
   (``max_len`` KV rows), bucketed whole-prompt prefill;
2. **paged** — the :class:`~repro.serving.PagedServingEngine`:
   iteration-level continuous batching over a paged KV pool sized to the
   *same byte budget* (``slots * max_len`` token rows per replica), chunked
   prefill interleaved with oversubscribed decode.

The traffic is long-tailed (rare long prompts coupled with long
generations): the slot engine must provision every lane for the tail while
the paged pool sizes to the actual footprint in flight — that gap is where
the throughput win comes from, and ``stranded_capacity_frac`` /
``padding_waste_frac`` in the JSON quantify it.

Claims checked:

* paged throughput >= 2x slot throughput on the same trace, with p95
  latency equal or better;
* paged serving is *numerically free*: the same prompts produce bit-exact
  tokens and final-chunk logits on a deliberately fragmented pool vs a
  fresh contiguous pool, and bit-exact tokens vs the slot engine with
  exact (unbucketed) prefill — 0 mismatches;
* shared-registry propagation leaves 0 cross-replica schedule mismatches
  in both fleets;
* the paged engine reports exactly zero prefill padding waste.

All latencies/throughputs are virtual (cost-model) seconds; see DESIGN.md.
"""
from __future__ import annotations

import argparse
import shutil
import tempfile

import jax
import numpy as np

from benchmarks import common
from repro.configs import get_arch, reduced
from repro.fleet import ServingFleet, TrafficGenerator
from repro.models import build_model
from repro.serving import PagedServingEngine, ServingEngine
from repro.service import ScheduleRegistry

#: One preset family: the paged engine oversubscribes decode lanes
#: (``decode_batch`` > ``slots``) against the same pool byte budget, with
#: ``chunk`` >= the prompt cap so every prompt prefills in one exact-length
#: call (the flat per-kernel cost model makes many small chunks pure
#: overhead).  ``requests`` is the only smoke/full difference.
PRESETS = {
    "smoke": {"requests": 300},
    "full": {"requests": 600},
}

ARCH = "minitron-4b"
REPLICAS = 2
SLOTS = 4                 # slot engine lanes per replica
MAX_LEN = 112             # per-request context bound (both engines)
DECODE_BATCH = 16         # paged lanes: 4x oversubscribed vs slots
PAGE_SIZE = 2
CHUNK = 48                # == prompt cap: one exact chunk per prompt
CHUNKS_PER_STEP = 6
ADMIT_CAP = 28
QUEUE_CAP = 64
SEED = 2
TRAFFIC = {"arrival_rate": 1.2, "short_lens": (3, 8), "long_lens": (32, 48),
           "long_frac": 0.08, "prompt_cap": 48, "new_tokens": (12, 28),
           "long_new_tokens": (32, 64)}


def _trace(cfg, tick_s: float, n: int):
    """Fresh generator, fixed seed: both fleets see the identical stream."""
    gen = TrafficGenerator(seed=SEED, vocab_size=cfg.vocab_size,
                           tick_s=tick_s, **TRAFFIC)
    return gen.trace(n)


def _run_fleet(engine: str, scratch: str, n: int, tick_s: float,
               *, model, params, cfg) -> dict:
    kw = {}
    if engine == "paged":
        kw = {"decode_batch": DECODE_BATCH, "page_size": PAGE_SIZE,
              "pool_pages": SLOTS * MAX_LEN // PAGE_SIZE + 1,
              "chunk": CHUNK, "chunks_per_step": CHUNKS_PER_STEP,
              "admit_cap": ADMIT_CAP}
    fleet = ServingFleet(cfg, model, params, replicas=REPLICAS, slots=SLOTS,
                         max_len=MAX_LEN, engine=engine,
                         registry=ScheduleRegistry(
                             tempfile.mkdtemp(dir=scratch)),
                         policy="plan_aware", queue_cap=QUEUE_CAP, **kw)
    try:
        return fleet.serve(_trace(cfg, tick_s, n))
    finally:
        fleet.close()


def _equivalence(model, params, cfg) -> dict:
    """Token/logit equivalence: fragmented pool vs fresh pool vs slot engine.

    The fragmented engine's free list is pre-shredded (interleaved dummy
    allocations, odd ones released) so its requests land on scattered
    pages; the gather-based decode must still be bit-exact.
    """
    rng = np.random.default_rng(7)
    prompts = [[int(t) for t in rng.integers(1, cfg.vocab_size, size=n)]
               for n in (3, 17, 48, 5, 33, 8)]
    mnt = 8

    def paged(fragment: bool):
        eng = PagedServingEngine(model, params, decode_batch=len(prompts),
                                 max_ctx=MAX_LEN, page_size=PAGE_SIZE,
                                 chunk=CHUNK, record_logits=True)
        if fragment:
            for i in range(120):
                eng.table.ensure(9000 + i, PAGE_SIZE)
            for i in range(0, 120, 2):
                eng.table.release(9000 + i)
        frag = eng.table.fragmentation()
        reqs = [eng.add_request(p, max_new_tokens=mnt) for p in prompts]
        eng.run_to_completion()
        return reqs, eng.chunk_logits, frag

    contig_reqs, contig_logits, _ = paged(fragment=False)
    frag_reqs, frag_logits, frag0 = paged(fragment=True)

    slot = ServingEngine(model, params, slots=len(prompts), max_len=MAX_LEN,
                         prefill_buckets=False)
    slot_reqs = [slot.add_request(p, max_new_tokens=mnt) for p in prompts]
    while slot.active:
        slot.step()

    token_mismatches = sum(
        a.generated != b.generated
        for a, b in zip(contig_reqs, frag_reqs)) + sum(
        a.generated != b.generated
        for a, b in zip(contig_reqs, slot_reqs))
    logit_mismatches = sum(
        not np.array_equal(contig_logits[a.uid], frag_logits[b.uid])
        for a, b in zip(contig_reqs, frag_reqs))
    return {"requests": len(prompts),
            "initial_fragmentation": frag0,
            "token_mismatches": int(token_mismatches),
            "logit_mismatches": int(logit_mismatches)}


def run(preset: str = "smoke") -> list[tuple]:
    p = PRESETS[preset]
    cfg = reduced(get_arch(ARCH))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    scratch = tempfile.mkdtemp(prefix="paged-bench-")
    try:
        # probe the slot fleet's tick so both traces share one clock
        probe = ServingFleet(cfg, model, params, replicas=REPLICAS,
                             slots=SLOTS, max_len=MAX_LEN,
                             registry=ScheduleRegistry(
                                 tempfile.mkdtemp(dir=scratch)))
        tick_s = probe.tick_s
        probe.close()

        slot = _run_fleet("slot", scratch, p["requests"], tick_s,
                          model=model, params=params, cfg=cfg)
        paged = _run_fleet("paged", scratch, p["requests"], tick_s,
                           model=model, params=params, cfg=cfg)
        equiv = _equivalence(model, params, cfg)

        ratio = (paged["throughput_tok_per_s"] /
                 max(slot["throughput_tok_per_s"], 1e-12))
        p95_s, p95_p = slot["latency_s"]["p95"], paged["latency_s"]["p95"]
        mismatches = (slot["schedule_mismatches"] +
                      paged["schedule_mismatches"])
        preempts = sum(r.get("preemptions", 0) for r in paged["replicas"])
        equiv_bad = equiv["token_mismatches"] + equiv["logit_mismatches"]

        rows = [
            ("paged/slot_throughput_tok_per_s",
             round(slot["throughput_tok_per_s"], 1),
             f"p95_ticks={slot['latency_ticks']['p95']:.1f} "
             f"padding_waste={slot['padding_waste_frac']:.2f} "
             f"stranded={slot['stranded_capacity_frac']:.2f}"),
            ("paged/paged_throughput_tok_per_s",
             round(paged["throughput_tok_per_s"], 1),
             f"x{ratio:.2f} vs slot (>=2x): "
             f"{'PASS' if ratio >= 2.0 else 'FAIL'} preemptions={preempts}"),
            ("paged/p95_ticks", round(paged["latency_ticks"]["p95"], 1),
             f"slot={slot['latency_ticks']['p95']:.1f}, equal-or-better: "
             f"{'PASS' if p95_p <= p95_s else 'FAIL'}"),
            ("paged/padding_waste_frac", paged["padding_waste_frac"],
             f"chunked prefill pads nothing: "
             f"{'PASS' if paged['padding_waste_frac'] == 0.0 else 'FAIL'}"),
            ("paged/equivalence_mismatches", equiv_bad,
             f"fragmented-vs-contiguous + vs slot exact prefill "
             f"(init_frag={equiv['initial_fragmentation']:.2f}): "
             f"{'PASS' if equiv_bad == 0 else 'FAIL'}"),
            ("paged/schedule_mismatches", mismatches,
             f"cross-replica divergence: "
             f"{'PASS' if mismatches == 0 else 'FAIL'}"),
        ]
        common.save_result("paged", {
            "preset": preset,
            "arch": ARCH,
            "config": {"replicas": REPLICAS, "slots": SLOTS,
                       "max_len": MAX_LEN, "decode_batch": DECODE_BATCH,
                       "page_size": PAGE_SIZE, "chunk": CHUNK,
                       "chunks_per_step": CHUNKS_PER_STEP,
                       "admit_cap": ADMIT_CAP, "queue_cap": QUEUE_CAP,
                       "pool_pages": SLOTS * MAX_LEN // PAGE_SIZE + 1,
                       "seed": SEED, "requests": p["requests"],
                       **{k: list(v) if isinstance(v, tuple) else v
                          for k, v in TRAFFIC.items()}},
            "slot": slot,
            "paged": paged,
            "throughput_ratio": ratio,
            "equivalence": equiv,
            "pass": bool(ratio >= 2.0 and p95_p <= p95_s
                         and paged["padding_waste_frac"] == 0.0
                         and equiv_bad == 0 and mismatches == 0),
        }, metrics={
            "throughput_ratio": ratio,
            "paged_p95_ticks": paged["latency_ticks"]["p95"],
            "equivalence_mismatches": equiv_bad,
            "schedule_mismatches": mismatches,
        }, gated={
            "throughput_ratio": "higher",
            "paged_p95_ticks": "lower",
            "equivalence_mismatches": "lower",
        })
        return rows
    finally:
        shutil.rmtree(scratch, ignore_errors=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default="smoke")
    args = ap.parse_args()
    common.emit(run(args.preset),
                "Paged continuous batching vs fixed slots @ equal KV memory")
