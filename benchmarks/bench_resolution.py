"""Execution-plan resolution pipeline benchmark.

Two claims, one module:

1. **Resolution overhead** — at steady state (every workload's upgrade
   published), the per-call path pays one full
   :class:`~repro.service.TuningService.lookup` (service lock, counters,
   snapshot walk, re-``concretize``) per kernel per served token, while the
   plan path resolves each workload once (:func:`plan_model`) and serves
   dict hits afterwards.  We count actual service/stage lookups per served
   token on both paths and require a ≥5x reduction with **byte-identical**
   chosen schedules.

2. **Live upgrades** — a schedule published to the registry *while a
   ServingEngine is serving* reaches that engine without a restart: the
   engine detects the generation bump at the next decode-step boundary,
   re-plans, and serves the upgraded (exact-tier) schedule — never swapping
   a plan mid-step.

``--preset smoke`` (CI) tunes the donor at a small trial budget.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import shutil
import tempfile

import jax

from benchmarks import common
from repro.configs import get_arch, reduced
from repro.core.database import Record
from repro.core.resolution import ResolutionPipeline, plan_model
from repro.core.runner import AnalyticalRunner, CachedRunner
from repro.core.schedule import default_schedule
from repro.core.tuner import arch_uses, tune_arch_registry
from repro.kernels.ops import ScheduleProvider
from repro.models import build_model
from repro.serving import ServingEngine
from repro.service import ScheduleRegistry, TuningService

TARGET = "stablelm-12b"
PRESETS = {
    "smoke": {"donors": ["internvl2-26b"], "trials": 192, "tokens": 8},
    "full": {"donors": ["internvl2-26b", "starcoder2-7b"], "trials": 768,
             "tokens": 32},
}


def _schedule_bytes(sched) -> str:
    return json.dumps(sched.to_json(), sort_keys=True)


def _steady_state_overhead(p: dict, registry: ScheduleRegistry) -> dict:
    """Lookups per served token: per-call path vs pre-resolved plan."""
    uses = arch_uses(TARGET, common.SHAPE, dp=common.DP, tp=common.TP)
    runner = CachedRunner(AnalyticalRunner())
    tokens = p["tokens"]

    # Warm to steady state: one pass enqueues the background jobs, drain
    # publishes every upgrade the donor pool supports.
    warm = TuningService(registry, model_id=TARGET, runner=runner,
                         donors=list(p["donors"]), seed=common.SEED,
                         max_workers=0, probe_candidates=0)
    for u in uses:
        warm.lookup(u.instance)
    warm.drain()

    # Per-call path (the pre-plan provider): every kernel call of every
    # served token is one service lookup + concretize.
    percall = TuningService(registry, model_id=TARGET, runner=runner,
                            donors=list(p["donors"]), seed=common.SEED,
                            max_workers=0, probe_candidates=0)
    percall_chosen = {}
    for _ in range(tokens):
        for u in uses:
            lr = percall.lookup(u.instance)
            percall_chosen[u.instance.workload_key()] = (
                lr.schedule if lr.schedule is not None
                else default_schedule(u.instance))
    percall_lookups = percall.stats()["lookups"]

    # Plan path: resolve once into an ExecutionPlan, then serve dict hits.
    planned = TuningService(registry, model_id=TARGET, runner=runner,
                            donors=list(p["donors"]), seed=common.SEED,
                            max_workers=0, probe_candidates=0)
    pipeline = ResolutionPipeline.build(service=planned, mode="strict")
    plan = plan_model(TARGET, pipeline, common.SHAPE, dp=common.DP, tp=common.TP)
    provider = ScheduleProvider(pipeline=pipeline, plan=plan)
    for _ in range(tokens):
        for u in uses:
            provider.get(u.instance)
    plan_lookups = planned.stats()["lookups"]  # all spent during planning

    mismatches = sum(
        1 for u in uses
        if _schedule_bytes(plan.lookup(u.instance).schedule)
        != _schedule_bytes(percall_chosen[u.instance.workload_key()]))
    return {
        "kernels": len(uses),
        "tokens": tokens,
        "percall_lookups": percall_lookups,
        "percall_lookups_per_token": percall_lookups / tokens,
        "plan_lookups": plan_lookups,
        "plan_lookups_per_token": plan_lookups / tokens,
        "reduction": percall_lookups / max(plan_lookups, 1),
        "schedule_mismatches": mismatches,
        "plan_tiers": plan.tier_counts(),
        "pipeline": pipeline.stats(),
        "plan_hits": provider.plan_hits,
    }


def _live_upgrade(root: str) -> dict:
    """A mid-serve registry publish reaches a running ServingEngine."""
    cfg = reduced(get_arch("minitron-4b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    registry = ScheduleRegistry(root)
    service = TuningService(registry, model_id="serve", max_workers=0,
                            probe_candidates=0,
                            runner=CachedRunner(AnalyticalRunner()))
    provider = ScheduleProvider(service=service)
    engine = ServingEngine(model, params, slots=2, max_len=32,
                           provider=provider)
    engine.add_request([1, 2, 3], max_new_tokens=8)
    engine.add_request([4, 5, 6, 7], max_new_tokens=8)
    engine.step()
    engine.step()
    gen_before = engine.plan.generation

    # Background tuning (simulated: a direct registry publish) lands while
    # the engine is mid-stream.
    inst = next(u.instance for u in engine.plan.uses
                if u.instance.class_id == "matmul")
    upgraded = dataclasses.replace(default_schedule(inst), unroll=4,
                                   source="background")
    registry.publish([Record(instance=inst, schedule=upgraded,
                             seconds=service.runner.seconds(inst, upgraded),
                             model_id="background", target=service.target)])

    engine.run_to_completion()
    entry = engine.plan.lookup(inst)
    generations = [g for _, g in engine.plan_history]
    swaps_at_boundary = (
        generations == sorted(generations)  # generation only ever advances
        and generations[0] == gen_before
        and generations[-1] > gen_before)
    return {
        "replans": engine.replans,
        "plan_generation_before": gen_before,
        "plan_generation_after": engine.plan.generation,
        "plan_history": engine.plan_history,
        "upgraded_tier": entry.tier,
        "upgraded_schedule_matches": (
            _schedule_bytes(entry.schedule) == _schedule_bytes(upgraded)),
        "swaps_at_step_boundary_only": swaps_at_boundary,
        "prefill_traces": engine.prefill_trace_count,
    }


def run(preset: str = "smoke") -> list[tuple]:
    p = PRESETS[preset]
    root = tempfile.mkdtemp(prefix="resolution-registry-")
    live_root = tempfile.mkdtemp(prefix="resolution-live-")
    try:
        registry = ScheduleRegistry(root)
        for donor in p["donors"]:
            tune_arch_registry(registry, donor, common.SHAPE, dp=common.DP,
                               tp=common.TP, total_trials=p["trials"],
                               seed=common.SEED)
        steady = _steady_state_overhead(p, registry)
        live = _live_upgrade(live_root)

        reduction_ok = (steady["reduction"] >= 5
                        and steady["schedule_mismatches"] == 0)
        live_ok = (live["replans"] >= 1 and live["upgraded_tier"] == "exact"
                   and live["upgraded_schedule_matches"]
                   and live["swaps_at_step_boundary_only"])
        rows = [
            ("resolution/percall_lookups_per_token",
             round(steady["percall_lookups_per_token"], 1),
             f"kernels={steady['kernels']} tokens={steady['tokens']}"),
            ("resolution/plan_lookups_per_token",
             round(steady["plan_lookups_per_token"], 1),
             f"plan_hits={steady['plan_hits']} "
             f"tiers={steady['plan_tiers']}"),
            ("resolution/lookup_reduction", round(steady["reduction"], 1),
             f">=5x with byte-identical schedules "
             f"(mismatches={steady['schedule_mismatches']}): "
             f"{'PASS' if reduction_ok else 'FAIL'}"),
            ("resolution/live_upgrade_replans", live["replans"],
             f"tier={live['upgraded_tier']} boundary_only="
             f"{live['swaps_at_step_boundary_only']}: "
             f"{'PASS' if live_ok else 'FAIL'}"),
        ]
        common.save_result("resolution", {
            "preset": preset,
            "target": TARGET,
            "donors": p["donors"],
            "trials": p["trials"],
            "steady_state": steady,
            "live_upgrade": live,
            "pass": bool(reduction_ok and live_ok),
        }, metrics={
            "lookup_reduction": steady["reduction"],
            "plan_lookups_per_token": steady["plan_lookups_per_token"],
            "schedule_mismatches": steady["schedule_mismatches"],
        }, gated={
            "lookup_reduction": "higher",
            "plan_lookups_per_token": "lower",
        })
        return rows
    finally:
        shutil.rmtree(root, ignore_errors=True)
        shutil.rmtree(live_root, ignore_errors=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default="smoke")
    args = ap.parse_args()
    common.emit(run(args.preset),
                "Execution-plan resolution pipeline — overhead + live upgrades")
