"""Diff two benchmark-artifact sets; fail on regression of gated metrics.

Every benchmark writes ``benchmarks/results/<name>.json`` in the common
envelope (:func:`benchmarks.common.save_result`): identity fields, a flat
``metrics`` dict, and a ``gated`` map naming the metrics whose regression
should fail CI together with which direction is *better* (``"lower"`` for
latencies, ``"higher"`` for speedups).  This tool compares a baseline set
against a candidate set without any per-bench knowledge:

    python -m benchmarks.compare baseline_dir/ candidate_dir/
    python -m benchmarks.compare baseline_dir/ candidate_dir/ --tolerance 0.05

A gated metric regresses when it moves more than ``--tolerance`` (default
10%) in the *worse* direction; a bench whose ``pass`` flips true -> false
always fails.  Artifacts present on only one side are reported but do not
fail the run (a new bench has no baseline yet; a retired one has no
candidate).  Non-envelope JSON files (e.g. the cached tuning results under
``results/tuning/``) are ignored.  Exit status: 0 clean, 1 regressions.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys


def load_artifacts(dirname: str) -> dict:
    """``name -> envelope`` for every envelope-shaped JSON in ``dirname``."""
    out = {}
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        try:
            with open(path) as f:
                d = json.load(f)
        except (OSError, json.JSONDecodeError):
            continue
        if (isinstance(d, dict) and isinstance(d.get("metrics"), dict)
                and "name" in d):
            out[d["name"]] = d
    return out


def compare_one(base: dict, cand: dict, tolerance: float) -> list[dict]:
    """Regression rows for one benchmark (empty list: clean)."""
    bad = []
    if base.get("pass") is True and cand.get("pass") is False:
        bad.append({"bench": cand["name"], "metric": "pass",
                    "baseline": True, "candidate": False,
                    "change": "verdict flipped to FAIL"})
    for metric, direction in sorted(cand.get("gated", {}).items()):
        b = base.get("metrics", {}).get(metric)
        c = cand.get("metrics", {}).get(metric)
        if b is None or c is None:
            continue  # metric added/removed: nothing to regress against
        if b == 0:
            worse = (c > 0) if direction == "lower" else (c < 0)
            rel = float("inf") if worse else 0.0
        else:
            rel = (c - b) / abs(b)
            if direction == "higher":
                rel = -rel  # normalize: positive rel == worse
        if rel > tolerance:
            bad.append({"bench": cand["name"], "metric": metric,
                        "baseline": b, "candidate": c,
                        "change": f"{rel:+.1%} worse ({direction} is better)"})
    return bad


def compare_dirs(baseline_dir: str, candidate_dir: str,
                 tolerance: float = 0.10) -> dict:
    """Full comparison: regressions plus coverage notes, JSON-ready."""
    base = load_artifacts(baseline_dir)
    cand = load_artifacts(candidate_dir)
    regressions = []
    for name in sorted(set(base) & set(cand)):
        regressions.extend(compare_one(base[name], cand[name], tolerance))
    return {
        "tolerance": tolerance,
        "compared": sorted(set(base) & set(cand)),
        "baseline_only": sorted(set(base) - set(cand)),
        "candidate_only": sorted(set(cand) - set(base)),
        "regressions": regressions,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="diff two benchmark-artifact directories; exit 1 on "
                    ">tolerance regression of any gated metric")
    ap.add_argument("baseline", help="directory of baseline artifacts")
    ap.add_argument("candidate", help="directory of candidate artifacts")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed relative regression (default 0.10 = 10%%)")
    ap.add_argument("--json", action="store_true",
                    help="emit the comparison object as JSON")
    args = ap.parse_args(argv)

    result = compare_dirs(args.baseline, args.candidate, args.tolerance)
    if args.json:
        print(json.dumps(result, indent=1, sort_keys=True))
    else:
        print(f"compared {len(result['compared'])} benches "
              f"(tolerance {args.tolerance:.0%})")
        for name in result["baseline_only"]:
            print(f"  note: {name} only in baseline")
        for name in result["candidate_only"]:
            print(f"  note: {name} only in candidate (no baseline yet)")
        for r in result["regressions"]:
            print(f"  REGRESSION {r['bench']}.{r['metric']}: "
                  f"{r['baseline']} -> {r['candidate']}  ({r['change']})")
        if not result["regressions"]:
            print("  no regressions")
    return 1 if result["regressions"] else 0


if __name__ == "__main__":
    sys.exit(main())
