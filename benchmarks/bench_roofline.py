"""Roofline report: read the dry-run artifacts and emit the per-cell table
(EXPERIMENTS.md §Roofline).  Single-pod mesh per the assignment; multi-pod
cells are summarized separately as the pod-axis sharding proof."""
from __future__ import annotations

import json
import os

from benchmarks import common
from repro.hw.specs import TPU_V5E


def load_cells(mesh: str = "16x16") -> list[dict]:
    cells = []
    if not os.path.isdir(common.DRYRUN_DIR):
        return cells
    for name in sorted(os.listdir(common.DRYRUN_DIR)):
        if not name.endswith(f"__{mesh}.json"):
            continue
        with open(os.path.join(common.DRYRUN_DIR, name)) as f:
            cells.append(json.load(f))
    return cells


def run() -> list[tuple]:
    rows = []
    for mesh in ("16x16", "2x16x16"):
        ok = skipped = failed = 0
        for cell in load_cells(mesh):
            if cell["status"] == "skipped":
                skipped += 1
                continue
            if cell["status"] != "ok":
                failed += 1
                continue
            ok += 1
            if mesh != "16x16":
                continue  # the roofline table is single-pod per the brief
            r = cell["roofline"]
            dom_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
            total = r["compute_s"] + 0  # terms are independent bounds
            step_bound = dom_s
            frac = {
                "compute": r["compute_s"] / max(step_bound, 1e-30),
                "memory": r["memory_s"] / max(step_bound, 1e-30),
                "collective": r["collective_s"] / max(step_bound, 1e-30),
            }
            rows.append((
                f"roofline/{cell['arch']}/{cell['shape']}",
                round(step_bound * 1e6, 1),
                f"compute={r['compute_s'] * 1e3:.2f}ms memory={r['memory_s'] * 1e3:.2f}ms "
                f"collective={r['collective_s'] * 1e3:.2f}ms dominant={r['dominant']} "
                f"useful_flops={r['useful_flops_ratio']:.2f} "
                f"params/dev={cell['param_bytes_per_device'] / 2**30:.2f}GiB",
            ))
        rows.append((f"roofline/summary_{mesh}", ok,
                     f"ok={ok} skipped={skipped} failed={failed}"))
    return rows


if __name__ == "__main__":
    common.emit(run(), "Roofline — per (arch × shape), single-pod mesh")
