"""Paper §4.1: GEMM 512³ ↔ 1024³ auto-schedule cross-transfer.

Tunes both sizes, applies each schedule to the other kernel, and reports
speedup-over-unscheduled and the transferred/native ratio (paper: valid code
both ways, within ~5% of native, ~270× over the unscheduled loop nest).
"""
from __future__ import annotations

from benchmarks import common
from repro.core.autoscheduler import tune_kernel
from repro.core.cost_model import kernel_seconds, measure
from repro.core.schedule import default_schedule
from repro.core.workload import KernelInstance


def run() -> list[tuple]:
    rows = []
    sizes = (512, 1024)
    g = {s: KernelInstance.make("matmul", M=s, N=s, K=s) for s in sizes}
    tuned = {s: tune_kernel(g[s], trials=256, seed=common.SEED) for s in sizes}
    untuned = {s: kernel_seconds(g[s], default_schedule(g[s])) for s in sizes}
    payload = {}
    for s in sizes:
        rows.append((f"gemm/native_{s}", round(tuned[s].best_seconds * 1e6, 3),
                     f"speedup_vs_untuned={untuned[s] / tuned[s].best_seconds:.1f}x"))
    for src, dst in ((512, 1024), (1024, 512)):
        for mode in ("strict", "adaptive"):
            m = measure(g[dst], tuned[src].best, mode=mode, noise_sigma=0.0)
            if not m.valid:
                rows.append((f"gemm/transfer_{src}to{dst}_{mode}", -1, "INVALID"))
                payload[f"{src}->{dst}/{mode}"] = None
                continue
            ratio = m.seconds / tuned[dst].best_seconds
            rows.append((
                f"gemm/transfer_{src}to{dst}_{mode}",
                round(m.seconds * 1e6, 3),
                f"vs_native={ratio:.3f}x vs_untuned={untuned[dst] / m.seconds:.1f}x"
                f" adapted={m.adapted}",
            ))
            payload[f"{src}->{dst}/{mode}"] = {
                "seconds": m.seconds, "native_ratio": ratio,
                "untuned_speedup": untuned[dst] / m.seconds}
    cells = [v for v in payload.values() if isinstance(v, dict)
             and "untuned_speedup" in v]
    ups = [v["untuned_speedup"] for v in cells]
    common.save_result("gemm_transfer", payload, metrics={
        "mean_untuned_speedup": sum(ups) / len(ups) if ups else 0.0,
        "valid_transfers": len(cells),
    }, gated={"mean_untuned_speedup": "higher"})
    return rows


if __name__ == "__main__":
    common.emit(run(), "§4.1 — GEMM cross-transfer")
