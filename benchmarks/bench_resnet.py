"""Paper §4.3, literal reproduction: tune ResNet50, transfer to ResNet18.

The paper's own experiment on the paper's own models (TPU-adapted as
implicit-GEMM kernel classes, core/cnn_workloads.py): per-kernel transfer
matrix (Fig. 4), full-model speedup vs Ansor given the same search time
(Fig. 5a leftmost bars: paper 1.2× vs 1.01×), and Ansor's time-to-match
(paper: 4.8×).
"""
from __future__ import annotations

from benchmarks import common
from repro.core.autoscheduler import tune_model
from repro.core.cnn_workloads import cnn_uses
from repro.core.cost_model import kernel_seconds
from repro.core.database import ScheduleDB
from repro.core.heuristic import donor_scores
from repro.core.transfer import transfer_tune

TRIALS = 1024


def run() -> list[tuple]:
    rows = []
    db = ScheduleDB()
    donors = {}
    for donor in ("resnet50", "vgg16", "alexnet"):
        res = tune_model(cnn_uses(donor), model_id=donor, total_trials=TRIALS,
                         seed=common.SEED)
        for r in res.records:
            db.add(r)
        donors[donor] = res
        rows.append((f"resnet/tune_{donor}", round(res.tuned_seconds * 1e6, 1),
                     f"max_speedup={res.speedup:.2f}x search={res.search_time_s:.0f}s"))

    uses = cnn_uses("resnet18")
    ranked = donor_scores(uses, db)
    rows.append(("resnet/heuristic", 0,
                 " ".join(f"{d.model_id}={d.score:.3f}" for d in ranked)))

    tt = transfer_tune(uses, db, model_id="resnet18", donors=["resnet50"],
                       seed=common.SEED)
    res18 = tune_model(uses, model_id="resnet18", total_trials=TRIALS,
                       seed=common.SEED)
    # Ansor at the same (virtual) search time / time-to-match, from the trace
    same = res18.untuned_seconds
    for p in res18.trace:
        if p.search_time_s <= tt.search_time_s:
            same = min(same, p.best_seconds)
    match_t = next((p.search_time_s for p in res18.trace
                    if p.best_seconds <= tt.tuned_seconds), None)

    n_valid = sum(1 for k in tt.kernels if k.chosen is not None)
    n_inval = sum(k.invalid for k in tt.kernels)
    rows.append((
        "resnet/18_from_50",
        round(tt.tuned_seconds * 1e6, 1),
        f"tt_speedup={tt.speedup:.2f}x (paper 1.2x) "
        f"ansor_same_time={res18.untuned_seconds / same:.2f}x (paper 1.01x) "
        f"ansor_match={'%.1fx_more_time' % (match_t / tt.search_time_s) if match_t else 'never'}"
        f" (paper 4.8x) covered={n_valid}/{len(tt.kernels)} invalid_cands={n_inval}",
    ))
    common.save_result("resnet", {
        "tt_speedup": tt.speedup,
        "search_time_s": tt.search_time_s,
        "ansor_same_time": res18.untuned_seconds / same,
        "ansor_match_ratio": (match_t / tt.search_time_s) if match_t else None,
        "max_speedup_18": res18.speedup,
        "covered": n_valid, "kernels": len(tt.kernels), "invalid": n_inval,
    }, metrics={
        "tt_speedup": tt.speedup,
        "search_time_s": tt.search_time_s,
        "covered": n_valid,
    }, gated={"tt_speedup": "higher", "search_time_s": "lower"})
    return rows


if __name__ == "__main__":
    common.emit(run(), "§4.3 — ResNet18 from ResNet50 (the paper's own models)")
