"""Closed-loop observability benchmark: SLO burn-down under tuning priority.

One seeded request stream is served twice against identical copies of a
donor-seeded schedule registry, with a latency SLO attached and background
transfer-tuning racing to bring the fleet into compliance:

1. **demand** — the PR-6 ordering: tune whatever arrives most (decode
   first, then the hottest prefill buckets by arrival count);
2. **advisor** — the closed-loop ordering: every executed workload ranked
   by observed critical-path seconds x remaining speedup headroom
   (:class:`~repro.fleet.TuningAdvisor`), fed to
   :meth:`~repro.service.TuningService.prefetch` as queue priority.

The serving scenario is the speculative paged fleet from PR 9 — and that
choice is the point.  The demand heuristic predates speculation: it can
only name the cells it was written for (the batched decode step and the
prefill buckets), so it spends its whole priority budget on workloads a
speculating fleet barely executes, while the cells that actually carry the
latency — ``verify`` and ``draft_decode``, whose batched flash-attention
kernels hold nearly all the donor headroom at this geometry — wait at the
back of the queue at priority zero.  The advisor never names cells at all:
it reads the replicas' live cell counters, so whatever cells the engine of
the day executes are exactly the ones it ranks.  Telemetry-driven priority
generalizes; hand-listed hot paths do not.

Gates (the PR's acceptance criteria):

* **profiler fidelity** — the critical-path profiler's per-request latency
  percentiles, rebuilt offline from the trace, reproduce
  ``FleetMetrics.summary()``'s p50/p95 *exactly* (same intervals, same
  :func:`~repro.obs.percentile`), with 100% of replica busy-time attributed
  to kernel workloads;
* **priority win** — the advisor arm reaches SLO compliance (the last
  burn-rate alert clears, never to return) spending at most
  ``advantage`` x the demand arm's virtual tuning seconds, with zero
  served-token mismatches between the arms (tuning order must never change
  *what* is served, only how fast it gets fast);
* **ledger truth** — after the advisor fleet fully drains its tuning
  queues, the speedup ledger's realized speedup over the reference
  replica's plan equals an offline
  :func:`~repro.core.transfer.transfer_tune` run against the same donor
  registry (same donors, mode, seed), and its realized fraction is 1.0 —
  the live metric agrees with the paper's offline one.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import shutil
import tempfile

import jax

from benchmarks import common
from repro.configs import get_arch, reduced
from repro.core.runner import AnalyticalRunner, CachedRunner
from repro.core.transfer import transfer_tune
from repro.core.tuner import tune_arch_registry
from repro.fleet import ServingFleet, TrafficGenerator
from repro.models import build_model
from repro.obs import SLO, Tracer, profiler, report
from repro.obs.export import _records
from repro.serving import make_self_draft
from repro.service import ScheduleRegistry

#: The SLO threshold sits in the dead zone between the untuned and the
#: fully-transferred latency distributions (measured endpoints at this
#: geometry: untuned 51-62 ticks, tuned 39-48 ticks), so the run *starts*
#: in violation and tuning is what brings it into compliance — the race the
#: two orderings compete on.  Geometry notes: ``decode_batch`` 32 is where
#: the donor pool's flash-attention headroom peaks (burst speedup 1.29 vs
#: 1.10 at batch 8 — the lm_head matmul amortizes away); utilisation is
#: kept low (~20%) so latency is deterministic service time, not queueing
#: noise; long generations (~28 draft/verify bursts per request) integrate
#: the per-burst saving into a ~10-tick latency gap.
PRESETS = {
    "smoke": {"arch": "minitron-4b", "donors": ["internvl2-26b"],
              "trials": 256, "n_layers": 8, "keep_layers": 1, "damp": 0.01,
              "spec_k": 4, "decode_batch": 32, "page_size": 4, "chunk": 16,
              "admit_cap": 48,
              "max_len": 160, "requests": 32, "queue_cap": 64,
              "arrival_rate": 0.12, "short_lens": (3, 8),
              "long_lens": (9, 14), "long_frac": 0.25,
              "new_tokens": (120, 128),
              "objective": 0.75, "threshold_ticks": 49.5,
              "slo_window_ticks": 8.0, "slow_windows": 4,
              "drain_jobs": 1, "drain_every": 4, "seed": 0,
              "advantage": 0.75},
    "full": {"arch": "minitron-4b", "donors": ["internvl2-26b",
                                               "starcoder2-7b"],
             "trials": 768, "n_layers": 8, "keep_layers": 1, "damp": 0.01,
             "spec_k": 4, "decode_batch": 32, "page_size": 4, "chunk": 16,
             "admit_cap": 48,
             "max_len": 160, "requests": 48, "queue_cap": 64,
             "arrival_rate": 0.12, "short_lens": (3, 8),
             "long_lens": (9, 14), "long_frac": 0.25,
             "new_tokens": (120, 128),
             "objective": 0.75, "threshold_ticks": 49.5,
             "slo_window_ticks": 8.0, "slow_windows": 4,
             "drain_jobs": 1, "drain_every": 4, "seed": 0,
             "advantage": 0.75},
}


def _slos(p: dict):
    """The latency objective, thresholds scaled by the fleet's tick."""
    return lambda tick_s: [SLO("p95_latency", "latency",
                               objective=p["objective"],
                               threshold_s=p["threshold_ticks"] * tick_s,
                               slow_windows=p["slow_windows"])]


def _make_fleet(p: dict, base: str, scratch: str, name: str, *,
                prefetch, tracer, model, params, cfg,
                draft, draft_params) -> ServingFleet:
    root = os.path.join(scratch, name)
    shutil.copytree(base, root)
    fleet = ServingFleet(
        cfg, model, params, replicas=1, engine="paged",
        decode_batch=p["decode_batch"], page_size=p["page_size"],
        pool_pages=p["decode_batch"] * p["max_len"] // p["page_size"] + 1,
        chunk=p["chunk"], admit_cap=p["admit_cap"], max_len=p["max_len"],
        speculative=True, draft_model=draft, draft_params=draft_params,
        spec_k=p["spec_k"],
        registry=ScheduleRegistry(root),
        policy="least_loaded", queue_cap=p["queue_cap"],
        prefetch=prefetch, donors=list(p["donors"]),
        drain_jobs=p["drain_jobs"], drain_every=p["drain_every"],
        seed=p["seed"], tracer=tracer, slos=_slos(p))
    fleet.set_slo_window(p["slo_window_ticks"] * fleet.tick_s)
    return fleet


def _trace(p: dict, cfg, tick_s: float) -> list:
    gen = TrafficGenerator(seed=p["seed"], vocab_size=cfg.vocab_size,
                           arrival_rate=p["arrival_rate"], tick_s=tick_s,
                           short_lens=tuple(p["short_lens"]),
                           long_lens=tuple(p["long_lens"]),
                           long_frac=p["long_frac"],
                           new_tokens=tuple(p["new_tokens"]),
                           prompt_cap=p["chunk"])
    return gen.trace(p["requests"])


def _run_arm(p: dict, base: str, scratch: str, name: str, *, prefetch,
             model, params, cfg, draft, draft_params) -> dict:
    """Serve one arm; returns summary + profiler/tuning/token evidence."""
    tracer = Tracer()
    fleet = _make_fleet(p, base, scratch, name, prefetch=prefetch,
                        tracer=tracer, model=model, params=params, cfg=cfg,
                        draft=draft, draft_params=draft_params)
    reqs = _trace(p, cfg, fleet.tick_s)
    try:
        summary = fleet.serve(reqs)
        records = _records(tracer)
        cp = profiler.critical_path(records)
        jobs = report.tuning_jobs(records)

        # Virtual tuning seconds spent up to SLO compliance (the instant
        # the last alert cleared for good; 0 -> never alerted).
        slo = summary["slo"]["p95_latency"]
        t_comply = slo["last_alert_end_s"]
        spent = sum(j["duration_s"] for j in jobs
                    if j["t0"] <= t_comply + 1e-12)
        return {
            "fleet": fleet,  # advisor arm keeps serving for the ledger gate
            "summary": summary,
            "critical_path": cp,
            "slo": slo,
            # Ending compliant is what counts; never having alerted at all
            # (possible for the advisor arm: tuning lands before the first
            # breaching finisher) is the ideal outcome, not a failure.
            "compliant": (slo["evaluations"] > 0
                          and not slo["alerting_now"]),
            "tuning_s_to_comply": spent,
            "tuning_s_total": sum(j["duration_s"] for j in jobs),
            "jobs": len(jobs),
            "tokens": {r.uid: list(r.generated or []) for r in reqs
                       if r.finished_s is not None},
        }
    except BaseException:
        fleet.close()
        raise


def _clears(arm: dict) -> str:
    """Row annotation: when the arm's alerts cleared for good."""
    if arm["slo"]["alerting_windows"] == 0:
        return "never alerted (tuned before the first breaching finisher)"
    return f"alerts cleared at t={arm['slo']['last_alert_end_s']:.4g}"


def _cp_matches(arm: dict) -> bool:
    """Gate a: trace-rebuilt percentiles == fleet metrics, bit-exact."""
    cp, s = arm["critical_path"], arm["summary"]
    return (cp["latency_s"]["p50"] == s["latency_s"]["p50"]
            and cp["latency_s"]["p95"] == s["latency_s"]["p95"]
            and cp["attributed_frac"] == 1.0)


def run(preset: str = "smoke") -> list[tuple]:
    p = PRESETS[preset]
    cfg = dataclasses.replace(reduced(get_arch(p["arch"])),
                              n_layers=p["n_layers"])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dcfg, dparams, params = make_self_draft(cfg, params,
                                            keep_layers=p["keep_layers"],
                                            damp=p["damp"])
    draft = build_model(dcfg)

    scratch = tempfile.mkdtemp(prefix="slo-bench-")
    base = os.path.join(scratch, "base-registry")
    advisor = demand = None
    try:
        registry = ScheduleRegistry(base)
        for donor in p["donors"]:
            tune_arch_registry(registry, donor, common.SHAPE, dp=common.DP,
                               tp=common.TP, total_trials=p["trials"],
                               seed=common.SEED)
        donor_db = registry.snapshot().db(None)  # frozen pre-serve pool

        demand = _run_arm(p, base, scratch, "demand", prefetch=True,
                          model=model, params=params, cfg=cfg,
                          draft=draft, draft_params=dparams)
        advisor = _run_arm(p, base, scratch, "advisor", prefetch="advisor",
                           model=model, params=params, cfg=cfg,
                           draft=draft, draft_params=dparams)

        # Gate a: profiler fidelity, both arms.
        cp_ok = _cp_matches(demand) and _cp_matches(advisor)

        # Gate b: the advisor reaches compliance on a fraction of the
        # tuning spend, serving byte-identical tokens.
        mismatches = sum(
            1 for uid, toks in demand["tokens"].items()
            if advisor["tokens"].get(uid) != toks)
        mismatches += sum(1 for uid in advisor["tokens"]
                          if uid not in demand["tokens"])
        ratio = (advisor["tuning_s_to_comply"]
                 / max(demand["tuning_s_to_comply"], 1e-12))
        # The demand arm must actually have alerted — otherwise the
        # threshold had no teeth and the race was vacuous.
        race_ok = (demand["compliant"] and advisor["compliant"]
                   and demand["slo"]["alerting_windows"] > 0
                   and ratio <= p["advantage"] and mismatches == 0)

        # Gate c: drain the advisor fleet's tuning queues to exhaustion;
        # the live ledger must then agree with the offline transfer number
        # for the same donors / mode / seed over the same workloads.
        fleet = advisor["fleet"]
        for svc in fleet.services.values():
            svc.drain()
        final = fleet.summary()  # re-syncs plans, re-prices the ledger
        ref = fleet.replicas[0]
        uses = [u for cell in sorted(ref.cell_counts)
                for u in ref.cell_uses(cell)
                if (u.instance.workload_key(), ref.target) in
                fleet.ledger.entries]
        svc = fleet.services[ref.target]
        led = fleet.ledger.speedup_for(uses, ref.target)
        offline = transfer_tune(
            uses, donor_db, model_id=svc.model_id,
            donors=list(p["donors"]), mode="strict", seed=p["seed"],
            runner=CachedRunner(AnalyticalRunner(ref.target)),
            target=ref.target)
        led_err = abs(led["realized_speedup"] - offline.speedup) / \
            offline.speedup
        ledger_ok = (led_err <= 1e-9 and led["realized_fraction"] == 1.0
                     and not led["missing"])

        ok = cp_ok and race_ok and ledger_ok
        rows = [
            ("slo/critical_path_exact", int(cp_ok),
             f"trace p50/p95 == FleetMetrics, 100% attributed: "
             f"{'PASS' if cp_ok else 'FAIL'}"),
            ("slo/demand_tuning_s_to_comply",
             round(demand["tuning_s_to_comply"], 2),
             f"{_clears(demand)} ({demand['jobs']} jobs, "
             f"{demand['tuning_s_total']:.1f}s total)"),
            ("slo/advisor_tuning_s_to_comply",
             round(advisor["tuning_s_to_comply"], 2),
             f"{_clears(advisor)} ({advisor['jobs']} jobs, "
             f"{advisor['tuning_s_total']:.1f}s total)"),
            ("slo/advisor_vs_demand_ratio", round(ratio, 3),
             f"<= {p['advantage']} with {mismatches} token mismatches: "
             f"{'PASS' if race_ok else 'FAIL'}"),
            ("slo/ledger_realized_speedup",
             round(led["realized_speedup"], 4),
             f"offline transfer_tune={offline.speedup:.4f} "
             f"(err={led_err:.2g}), fraction="
             f"{led['realized_fraction']:.3f}: "
             f"{'PASS' if ledger_ok else 'FAIL'}"),
        ]
        common.save_result("slo", {
            "preset": preset,
            "arch": p["arch"],
            "donors": p["donors"],
            "slo": {"objective": p["objective"],
                    "threshold_ticks": p["threshold_ticks"],
                    "window_ticks": p["slo_window_ticks"]},
            "demand": {k: v for k, v in demand.items()
                       if k not in ("fleet", "tokens")},
            "advisor": {k: v for k, v in advisor.items()
                        if k not in ("fleet", "tokens")},
            "token_mismatches": mismatches,
            "tuning_ratio": ratio,
            "ledger": led,
            "offline_speedup": offline.speedup,
            "ledger_err": led_err,
            "final_ledger": final["speedup_ledger"],
            "pass": ok,
        }, metrics={
            "tuning_ratio": ratio,
            "advisor_tuning_s_to_comply": advisor["tuning_s_to_comply"],
            "token_mismatches": mismatches,
            "ledger_err": led_err,
            "ledger_realized_speedup": led["realized_speedup"],
        }, gated={
            "tuning_ratio": "lower",
            "token_mismatches": "lower",
            "ledger_err": "lower",
        })
        return rows
    finally:
        for arm in (demand, advisor):
            if arm is not None and "fleet" in arm:
                arm["fleet"].close()
        shutil.rmtree(scratch, ignore_errors=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default="smoke")
    args = ap.parse_args()
    common.emit(run(args.preset),
                "Closed-loop observability — SLO burn-down, tuning priority, "
                "speedup ledger")
