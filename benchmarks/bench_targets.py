"""Multi-target subsystem: the paper's server-vs-edge experiment.

The paper's key scaling finding (§5.3) is that transfer-tuning's advantage
*widens* on a constrained device: Ansor needs 10.8× more search time than
transfer-tuning on the edge CPU vs 6.5× on the server CPU.  This benchmark
reproduces the phenomenon across two registered hardware targets:

* per target (``tpu-v5e`` server, ``tpu-v5e-lite`` edge): auto-tune a donor
  arch on that chip, transfer-tune the target arch from it, then run full
  auto-scheduling until it *matches* transfer-tuning's model seconds (the
  paper's time-to-match metric).  The ratio ``full_search_s / tt_search_s``
  must be strictly larger on the constrained chip — tight VMEM makes much of
  the schedule space invalid, so from-scratch search wastes trials exactly
  where reusing already-feasible donor schedules is cheapest;
* cross-target transfer (:func:`~repro.core.transfer.cross_target_transfer`):
  server-tuned donors re-validated under the edge spec — edge-infeasible
  donors must surface as invalid transfers (Fig. 4's −1 bars), not crashes;
* namespace integrity: every DB / registry query for target A returns only
  target-A records (zero cross-target leakage).
"""
from __future__ import annotations

import argparse
import shutil
import tempfile

from benchmarks import common
from repro.core import ScheduleDB, cross_target_transfer, tune_model
from repro.core.tuner import arch_uses, transfer_arch, tune_arch
from repro.service import ScheduleRegistry

TARGET_ARCH = "stablelm-12b"
DONOR = "internvl2-26b"       # shares every kernel class with the target
SERVER, EDGE = "tpu-v5e", "tpu-v5e-lite"
PRESETS = {
    "smoke": {"trials": 256, "match_cap_trials": 2048},
    "full": {"trials": 768, "match_cap_trials": 8192},
}


def _count_leaks(db: ScheduleDB, uses, targets) -> int:
    """Records returned from one target's queries but measured on another."""
    leaks = 0
    for tname in targets:
        for u in uses:
            for r in db.by_class(u.instance.class_id, target=tname):
                leaks += r.target != tname
            e = db.exact(u.instance, target=tname)
            if e is not None:
                leaks += e.target != tname
    return leaks


def run(preset: str = "smoke") -> list[tuple]:
    p = PRESETS[preset]
    uses = arch_uses(TARGET_ARCH, common.SHAPE, dp=common.DP, tp=common.TP)
    db = ScheduleDB()  # one shared store; namespacing keeps the chips apart

    per_target: dict[str, dict] = {}
    for tname in (SERVER, EDGE):
        tune_arch(db, DONOR, common.SHAPE, dp=common.DP, tp=common.TP,
                  total_trials=p["trials"], seed=common.SEED, target=tname)
        tt = transfer_arch(db, TARGET_ARCH, common.SHAPE, dp=common.DP,
                           tp=common.TP, donors=[DONOR], target=tname,
                           seed=common.SEED)
        # Time-to-match: full auto-scheduling from scratch until it reaches
        # transfer-tuning's model seconds (fresh runner — no cache sharing
        # with the transfer pass, the search times must be independent).
        full = tune_model(uses, model_id=TARGET_ARCH,
                          total_trials=p["match_cap_trials"], seed=common.SEED,
                          target=tname,
                          stop_when=lambda st, ms: ms <= tt.tuned_seconds)
        matched = full.tuned_seconds <= tt.tuned_seconds
        per_target[tname] = {
            "tt_search_s": tt.search_time_s,
            "tt_speedup": tt.speedup,
            "tt_invalid": tt.invalid_transfers,
            "full_search_s": full.search_time_s,
            "full_trials": full.total_trials,
            "matched": matched,
            "ratio": full.search_time_s / tt.search_time_s,
        }

    # Cross-target: server-tuned donors as the edge pool.  Server tiles that
    # overflow the edge VMEM must be rejected as invalid, and the run must
    # still complete with whatever survivors fit.
    x = cross_target_transfer(uses, db, source_target=SERVER, target=EDGE,
                              donors=[DONOR], model_id=TARGET_ARCH,
                              seed=common.SEED)

    # Namespace integrity, both through the in-memory DB and a registry
    # round-trip (publish → snapshot → query).
    leaks = _count_leaks(db, uses, (SERVER, EDGE))
    root = tempfile.mkdtemp(prefix="targets-registry-")
    try:
        registry = ScheduleRegistry(root)
        registry.merge_db(db)
        leaks += _count_leaks(registry.snapshot().db(None), uses, (SERVER, EDGE))
    finally:
        shutil.rmtree(root, ignore_errors=True)

    srv, edge = per_target[SERVER], per_target[EDGE]
    exacerbation = edge["ratio"] / srv["ratio"]
    rows = [
        (f"targets/ratio_{SERVER}", round(srv["ratio"], 2),
         f"full_s={srv['full_search_s']:.0f} tt_s={srv['tt_search_s']:.0f} "
         f"matched={srv['matched']}"),
        (f"targets/ratio_{EDGE}", round(edge["ratio"], 2),
         f"full_s={edge['full_search_s']:.0f} tt_s={edge['tt_search_s']:.0f} "
         f"matched={edge['matched']}"),
        ("targets/edge_exacerbation", round(exacerbation, 2),
         f"edge ratio strictly larger (paper: 10.8x vs 6.5x): "
         f"{'PASS' if edge['ratio'] > srv['ratio'] else 'FAIL'}"),
        ("targets/cross_target_invalid", x.invalid_transfers,
         f"server donors infeasible on edge surface as invalid (speedup="
         f"{x.speedup:.3f}): {'PASS' if x.invalid_transfers > 0 else 'FAIL'}"),
        ("targets/cross_target_leaks", leaks,
         f"target-A queries returning target-B records: "
         f"{'PASS' if leaks == 0 else 'FAIL'}"),
    ]
    common.save_result("targets", {
        "preset": preset,
        "target_arch": TARGET_ARCH,
        "donor": DONOR,
        "trials": p["trials"],
        "match_cap_trials": p["match_cap_trials"],
        "per_target": per_target,
        "edge_exacerbation": exacerbation,
        "cross_target": {
            "source": SERVER,
            "dest": EDGE,
            "invalid_transfers": x.invalid_transfers,
            "speedup": x.speedup,
            "search_time_s": x.search_time_s,
        },
        "cross_target_leaks": leaks,
        "pass": bool(edge["ratio"] > srv["ratio"]
                     and x.invalid_transfers > 0 and leaks == 0),
    }, metrics={
        "server_ratio": srv["ratio"],
        "edge_ratio": edge["ratio"],
        "edge_exacerbation": exacerbation,
        "cross_target_leaks": leaks,
    }, gated={
        "server_ratio": "higher",
        "edge_ratio": "higher",
        "cross_target_leaks": "lower",
    })
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default="smoke")
    args = ap.parse_args()
    common.emit(run(args.preset),
                "Multi-target: server-vs-edge search-time gap + cross-target transfer")
