"""Paper Fig. 7 / §5.4: transfer across input sizes of the SAME model.

Every kernel changes when the sequence length changes (new workload IDs →
Ansor must retune), but transfer-tuning reuses the schedules.  We tune each
arch at seq 4096 and transfer to seq 2048 and 8192 (and the reverse for the
long→short vs short→long asymmetry the paper observed).
"""
from __future__ import annotations

import dataclasses

from benchmarks import common
from repro.configs import get_arch, get_shape
from repro.core.database import Record, ScheduleDB
from repro.core.extract import extract_kernels
from repro.core.transfer import transfer_tune
from repro.core.autoscheduler import tune_model

ARCHS = ("gemma2-2b", "rwkv6-1.6b", "starcoder2-7b")


def _uses(arch: str, seq: int):
    shape = dataclasses.replace(get_shape("train_4k"), seq_len=seq)
    return extract_kernels(get_arch(arch), shape, dp=common.DP, tp=common.TP)


def run() -> list[tuple]:
    rows = []
    payload = {}
    for arch in ARCHS:
        results = {}
        tuned = {}
        for seq in (2048, 4096):
            db = ScheduleDB()
            res = tune_model(_uses(arch, seq), model_id=f"{arch}@{seq}",
                             total_trials=512, seed=common.SEED)
            for r in res.records:
                db.add(r)
            tuned[seq] = (db, res)
        for src, dst in ((4096, 2048), (2048, 4096), (4096, 8192)):
            db, _ = tuned[src] if src in tuned else tuned[4096]
            tt = transfer_tune(_uses(arch, dst), db, model_id=f"{arch}@{dst}",
                               seed=common.SEED)
            results[f"{src}->{dst}"] = tt.speedup
            rows.append((
                f"fig7/{arch}/{src}to{dst}",
                round(tt.tuned_seconds * 1e6, 1),
                f"speedup={tt.speedup:.2f}x coverage={tt.coverage():.0%} "
                f"search={tt.search_time_s:.0f}s",
            ))
        payload[arch] = results
    speeds = [s for arch in payload.values() for s in arch.values()]
    common.save_result("fig7_seqlen", payload, metrics={
        "mean_speedup": sum(speeds) / len(speeds) if speeds else 0.0,
        "min_speedup": min(speeds) if speeds else 0.0,
    }, gated={"mean_speedup": "higher"})
    return rows


if __name__ == "__main__":
    common.emit(run(), "Fig.7 — sequence-length transfer")
