"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (the harness contract) and writes
structured JSON under benchmarks/results/ for EXPERIMENTS.md.
"""
from __future__ import annotations

import sys
import time

from benchmarks import common


def main() -> None:
    from benchmarks import (
        bench_autoscale,
        bench_fleet,
        bench_full_tuning,
        bench_gemm_transfer,
        bench_headline,
        bench_heuristic,
        bench_kernel_matrix,
        bench_obs,
        bench_paged,
        bench_pool,
        bench_resnet,
        bench_resolution,
        bench_roofline,
        bench_runner_cache,
        bench_seqlen,
        bench_service,
        bench_slo,
        bench_spec,
        bench_targets,
    )

    suites = [
        ("Fig.1 full auto-scheduling", bench_full_tuning),
        ("§4.1 GEMM cross-transfer", bench_gemm_transfer),
        ("Fig.4 per-kernel transfer matrix", bench_kernel_matrix),
        ("Fig.5/Table 4 headline", bench_headline),
        ("Tables 2/3 donor heuristic", bench_heuristic),
        ("Fig.7 sequence-length transfer", bench_seqlen),
        ("Fig.8 mixed pool", bench_pool),
        ("§4.3 ResNet18 from ResNet50 (paper's own models)", bench_resnet),
        ("Roofline (dry-run artifacts)", bench_roofline),
        ("MeasureRunner cached/pruned backends", bench_runner_cache),
        ("Schedule-registry service cold-start stream", bench_service),
        ("§5.3 server-vs-edge multi-target", bench_targets),
        ("Execution-plan resolution pipeline", bench_resolution),
        ("Serving fleet: router + demand-driven tuning", bench_fleet),
        ("Paged continuous batching vs fixed slots", bench_paged),
        ("Elastic autoscaling fleet vs fixed sizes", bench_autoscale),
        ("Observability overhead + trace fidelity", bench_obs),
        ("Speculative draft-then-verify vs plain paged decode", bench_spec),
        ("Closed-loop observability: SLO burn-down + tuning priority",
         bench_slo),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    t0 = time.monotonic()
    for title, mod in suites:
        if only and only not in mod.__name__:
            continue
        print(f"\n# === {title} ===", flush=True)
        t = time.monotonic()
        common.emit(mod.run())
        print(f"# ({mod.__name__} took {time.monotonic() - t:.1f}s)", flush=True)
    print(f"\n# total benchmark wall time: {time.monotonic() - t0:.1f}s")


if __name__ == "__main__":
    main()
