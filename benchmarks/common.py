"""Shared benchmark infrastructure.

The benchmarks reproduce the paper's tables/figures over the 10 assigned
architectures.  Full auto-scheduling of every arch (the "Ansor 20k-trials"
analogue, scaled to FULL_TRIALS) is expensive, so each arch's tuning result
— records, untuned/tuned seconds, and the full search trace — is cached
under benchmarks/results/tuning/ and reused across benchmark modules.

Conventions: all times are *cost-model seconds* (kernel runtimes) or
*virtual search seconds* (the simulated measurement harness); see DESIGN.md.
Mesh-local extents use the production single-pod mesh (dp=16, tp=16).
"""
from __future__ import annotations

import json
import os
import time

from repro.configs import ARCH_IDS
from repro.core.autoscheduler import TracePoint, tune_model
from repro.core.database import Record, ScheduleDB
from repro.core.extract import extract_kernels
from repro.core.tuner import arch_uses
# The one quantile implementation (repro.obs) — benchmarks and the fleet
# metrics share it, so bench numbers and serving summaries always agree.
from repro.obs import percentile  # noqa: F401  (re-export)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
TUNING_DIR = os.path.join(RESULTS_DIR, "tuning")
DRYRUN_DIR = os.path.join(RESULTS_DIR, "dryrun")

FULL_TRIALS = 1536     # "recommended full budget" analogue (scaled from 20k)
DP, TP = 16, 16        # production single-pod mesh
SHAPE = "train_4k"
SEED = 0


def _tuning_path(arch: str, shape: str = SHAPE) -> str:
    os.makedirs(TUNING_DIR, exist_ok=True)
    return os.path.join(TUNING_DIR, f"{arch}__{shape}.json")


def tune_arch_cached(arch: str, shape: str = SHAPE, trials: int = FULL_TRIALS,
                     seed: int = SEED) -> dict:
    """Full-budget tuning of one arch; cached to disk with its search trace."""
    path = _tuning_path(arch, shape)
    if os.path.exists(path):
        with open(path) as f:
            d = json.load(f)
        if d["trials"] >= trials:
            return d
    uses = arch_uses(arch, shape, dp=DP, tp=TP)
    t0 = time.monotonic()
    res = tune_model(uses, model_id=arch, total_trials=trials, seed=seed)
    out = {
        "arch": arch,
        "shape": shape,
        "trials": res.total_trials,
        "untuned_seconds": res.untuned_seconds,
        "tuned_seconds": res.tuned_seconds,
        "search_time_s": res.search_time_s,
        "wall_time_s": round(time.monotonic() - t0, 2),
        "records": [r.to_json() for r in res.records],
        "trace": [[p.search_time_s, p.best_seconds, p.trials] for p in res.trace],
    }
    with open(path, "w") as f:
        json.dump(out, f)
    return out


def full_db(shape: str = SHAPE) -> ScheduleDB:
    """ScheduleDB holding every arch's full-budget tuning records."""
    db = ScheduleDB()
    for arch in ARCH_IDS:
        d = tune_arch_cached(arch, shape)
        for r in d["records"]:
            db.add(Record.from_json(r))
    return db


def trace_points(d: dict) -> list[TracePoint]:
    return [TracePoint(t, s, n) for t, s, n in d["trace"]]


def speedup_at_time(d: dict, budget_s: float) -> float:
    """Ansor's speedup given `budget_s` virtual search seconds (trace lookup)."""
    best = d["untuned_seconds"]
    for t, s, _ in d["trace"]:
        if t <= budget_s:
            best = min(best, s)
        else:
            break
    return d["untuned_seconds"] / best


def time_to_reach(d: dict, target_seconds: float) -> float | None:
    """Virtual search seconds Ansor needs to reach `target_seconds` model time."""
    for t, s, _ in d["trace"]:
        if s <= target_seconds:
            return t
    return None


def emit(rows: list[tuple], header: str | None = None) -> None:
    """CSV lines: name,us_per_call,derived (the harness contract)."""
    if header:
        print(f"# {header}")
    for name, us, derived in rows:
        print(f"{name},{us},{derived}")


def save_result(name: str, payload: dict, *,
                metrics: "dict[str, float] | None" = None,
                gated: "dict[str, str] | None" = None) -> None:
    """Write ``benchmarks/results/<name>.json`` in the common envelope.

    Every benchmark artifact shares one schema so CI uploads are stable
    (``BENCH_<name>.json``) and :mod:`benchmarks.compare` can diff any two
    runs without per-bench knowledge:

    * ``name`` / ``preset`` / ``pass`` / ``timestamp`` — identity and the
      bench's own verdict (``preset``/``pass`` lifted from the payload);
    * ``metrics`` — flat ``name -> float`` of the numbers worth tracking
      across runs;
    * ``gated`` — ``metric -> "lower" | "higher"`` (which direction is
      *better*): the subset of ``metrics`` whose >10% regression fails CI;
    * ``detail`` — the full bench-specific payload, unchanged.

    Callers that predate the envelope pass only ``payload``; they get
    identity + detail with empty metrics, still schema-valid.
    """
    os.makedirs(RESULTS_DIR, exist_ok=True)
    metrics = dict(metrics or {})
    gated = dict(gated or {})
    bad = set(gated) - set(metrics)
    if bad:
        raise ValueError(f"gated metrics missing from metrics: {sorted(bad)}")
    bad_dir = {m: d for m, d in gated.items() if d not in ("lower", "higher")}
    if bad_dir:
        raise ValueError(f"gated direction must be lower|higher: {bad_dir}")
    envelope = {
        "name": name,
        "preset": payload.get("preset"),
        "pass": payload.get("pass"),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "metrics": {k: float(v) for k, v in sorted(metrics.items())},
        "gated": {k: gated[k] for k in sorted(gated)},
        "detail": payload,
    }
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(envelope, f, indent=1)
