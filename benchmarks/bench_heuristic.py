"""Paper Tables 2 & 3: kernel-class census and donor heuristic top-3.

Table 2 analogue: per arch, the kernel classes with counts and untuned-time
shares, plus the heuristic's chosen donor.  Table 3 analogue: TT speedup for
the heuristic's top-3 donor choices (expect decreasing with rank).
"""
from __future__ import annotations

from benchmarks import common
from repro.configs import ARCH_IDS
from repro.core.cost_model import class_proportions
from repro.core.tuner import arch_uses, donor_ranking, transfer_arch


def run() -> list[tuple]:
    db = common.full_db()
    rows = []
    payload = {}
    rank_hits = []
    for arch in ARCH_IDS:
        uses = arch_uses(arch, common.SHAPE, dp=common.DP, tp=common.TP)
        props = class_proportions(uses)
        top_classes = ", ".join(
            f"{c}:{p:.0%}" for c, p in sorted(props.items(), key=lambda kv: -kv[1])[:3])
        ranked = donor_ranking(db, arch, common.SHAPE, dp=common.DP, tp=common.TP, k=3)
        choices = []
        for i, ds in enumerate(ranked):
            tt = transfer_arch(db, arch, common.SHAPE, dp=common.DP, tp=common.TP,
                               donors=[ds.model_id], seed=common.SEED)
            choices.append({"donor": ds.model_id, "score": ds.score,
                            "speedup": tt.speedup})
        speeds = [c["speedup"] for c in choices]
        rank_hits.append(1.0 if speeds and speeds[0] == max(speeds) else 0.0)
        rows.append((
            f"table3/{arch}",
            round(len(uses), 0),
            " ".join(f"choice{i + 1}={c['donor']}({c['speedup']:.2f}x)"
                     for i, c in enumerate(choices)) + f" classes=[{top_classes}]",
        ))
        payload[arch] = {"classes": props, "choices": choices}
    rows.append(("table3/rank1_best_fraction", round(100 * sum(rank_hits) / len(rank_hits), 1),
                 "how often the heuristic's first choice gives the best speedup"))
    common.save_result("table3_heuristic", payload, metrics={
        "rank1_best_fraction": sum(rank_hits) / len(rank_hits)
                               if rank_hits else 0.0,
    }, gated={"rank1_best_fraction": "higher"})
    return rows


if __name__ == "__main__":
    common.emit(run(), "Tables 2/3 — donor selection heuristic")
