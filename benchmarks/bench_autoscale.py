"""Elastic-fleet benchmark: autoscaling vs fixed replica counts on bursts.

Four configurations serve the *same* seeded bursty trace (square-wave load:
a low base rate punctuated by periodic bursts) against identical copies of
a donor-seeded schedule registry:

1. **elastic** — starts at 1 replica, an :class:`~repro.fleet.Autoscaler`
   warm-joins up to ``max_replicas`` during bursts and drain-retires back
   down between them;
2. **fixed-1 / fixed-2** — the fixed fleets the elastic one is formally
   compared against;
3. **fixed-max** — always at the elastic ceiling: the over-provisioned
   reference (burst-grade quality paid for all the time).

Claims checked (the PR's acceptance criteria):

* the elastic fleet beats every compared fixed size on p99 latency AND
  shed rate, while spending no more *replica-seconds* than fixed-2 — the
  equal-capacity-cost comparison;
* >= 2 scale-ups and >= 2 scale-downs fire across the bursts, with zero
  dropped requests (every submitted request completes or is accounted
  shed) and zero cross-replica schedule byte-mismatches;
* every warm-joined replica boots at >= the fleet's pre-join exact-tier
  share — the shared registry is what makes scale-up cheap (a cold-booted
  replica would serve default-tier schedules until tuning caught up).

Per-phase windows (burst vs base, via
:meth:`~repro.fleet.FleetMetrics.window_summaries` +
:meth:`~repro.fleet.BurstyTraffic.phase_at`) land in the JSON so the report
shows *where* the win comes from: the burst phases.
"""
from __future__ import annotations

import argparse
import os
import shutil
import tempfile

import jax

from benchmarks import common
from repro.configs import get_arch, reduced
from repro.core.tuner import tune_arch_registry
from repro.fleet import Autoscaler, BurstyTraffic, ServingFleet
from repro.models import build_model
from repro.service import ScheduleRegistry

#: Burst geometry is in ticks (1 tick = one untuned decode step).  The burst
#: rate is sized to overwhelm one replica (queue -> shed) and strain two,
#: while the autoscaler's window/cooldown let it ride up and back down twice
#: within the trace.  ``compare`` lists the fixed sizes the elastic run must
#: beat; ``max_replicas`` doubles as the over-provisioned reference size.
PRESETS = {
    "smoke": {"arch": "minitron-4b", "donors": ["internvl2-26b"],
              "trials": 256, "slots": 2, "max_len": 32,
              "requests": 56, "queue_cap": 8,
              "base_rate": 0.25, "burst_rate": 1.8,
              "burst_every_ticks": 48.0, "burst_len_ticks": 10.0,
              "offset_ticks": 6.0,
              "short_lens": (3, 6), "long_lens": (10, 16),
              "long_frac": 0.35, "new_tokens": (2, 4),
              "compare": [1, 2], "max_replicas": 3,
              "window_ticks": 2.0, "cooldown_ticks": 3.0,
              "up_windows": 1, "down_windows": 4,
              "queue_high": 0.75, "util_low": 0.55, "queue_low": 0.75,
              "drain_jobs": 1, "drain_every": 8, "seed": 0},
    "full": {"arch": "minitron-4b", "donors": ["internvl2-26b",
                                               "starcoder2-7b"],
             "trials": 768, "slots": 2, "max_len": 64,
             "requests": 120, "queue_cap": 10,
             "base_rate": 0.25, "burst_rate": 2.0,
             "burst_every_ticks": 56.0, "burst_len_ticks": 12.0,
             "offset_ticks": 6.0,
             "short_lens": (3, 8), "long_lens": (16, 24),
             "long_frac": 0.35, "new_tokens": (2, 5),
             "compare": [1, 2], "max_replicas": 3,
             "window_ticks": 2.0, "cooldown_ticks": 3.0,
             "up_windows": 1, "down_windows": 4,
             "queue_high": 0.75, "util_low": 0.55, "queue_low": 0.75,
             "drain_jobs": 1, "drain_every": 8, "seed": 0},
}


def _make_fleet(p: dict, base_registry: str, scratch: str, name: str, *,
                replicas: int, model, params, cfg) -> ServingFleet:
    root = os.path.join(scratch, name)
    shutil.copytree(base_registry, root)
    return ServingFleet(cfg, model, params, replicas=replicas,
                        slots=p["slots"], max_len=p["max_len"],
                        registry=ScheduleRegistry(root),
                        policy="least_loaded", queue_cap=p["queue_cap"],
                        prefetch=True, drain_jobs=p["drain_jobs"],
                        drain_every=p["drain_every"], seed=p["seed"])


def _trace_gen(p: dict, cfg, tick_s: float) -> BurstyTraffic:
    return BurstyTraffic(seed=p["seed"], vocab_size=cfg.vocab_size,
                         arrival_rate=p["base_rate"],
                         burst_rate=p["burst_rate"],
                         burst_every_ticks=p["burst_every_ticks"],
                         burst_len_ticks=p["burst_len_ticks"],
                         offset_ticks=p["offset_ticks"], tick_s=tick_s,
                         short_lens=tuple(p["short_lens"]),
                         long_lens=tuple(p["long_lens"]),
                         long_frac=p["long_frac"],
                         new_tokens=tuple(p["new_tokens"]),
                         prompt_cap=p["max_len"] // 2)


def _phase_windows(fleet: ServingFleet, gen: BurstyTraffic) -> dict:
    """p95/shed aggregated per traffic phase (burst vs base windows)."""
    out = {"burst": {"p95_s": 0.0, "shed": 0, "completed": 0},
           "base": {"p95_s": 0.0, "shed": 0, "completed": 0}}
    for w in fleet.metrics.window_summaries(4.0 * fleet.tick_s):
        phase = gen.phase_at((w["t0"] + w["t1"]) / 2.0)
        out[phase]["shed"] += w["shed"]
        out[phase]["completed"] += w["completed"]
        out[phase]["p95_s"] = max(out[phase]["p95_s"], w["latency_s"]["p95"])
    return out


def _run(p: dict, base: str, scratch: str, name: str, *, replicas: int,
         elastic: bool, model, params, cfg) -> dict:
    fleet = _make_fleet(p, base, scratch, name, replicas=replicas,
                        model=model, params=params, cfg=cfg)
    if elastic:
        fleet.attach_autoscaler(Autoscaler(
            min_replicas=1, max_replicas=p["max_replicas"],
            window_s=p["window_ticks"] * fleet.tick_s,
            cooldown_s=p["cooldown_ticks"] * fleet.tick_s,
            up_windows=p["up_windows"], down_windows=p["down_windows"],
            queue_high=p["queue_high"], util_low=p["util_low"],
            queue_low=p["queue_low"]))
    gen = _trace_gen(p, cfg, fleet.tick_s)
    try:
        summary = fleet.serve(gen.trace(p["requests"]))
        summary["phases"] = _phase_windows(fleet, gen)
    finally:
        fleet.close()
    summary["config"] = {"replicas": replicas, "elastic": elastic}
    return summary


def run(preset: str = "smoke") -> list[tuple]:
    p = PRESETS[preset]
    cfg = reduced(get_arch(p["arch"]))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = p["requests"]

    scratch = tempfile.mkdtemp(prefix="autoscale-bench-")
    base = os.path.join(scratch, "base-registry")
    try:
        registry = ScheduleRegistry(base)
        for donor in p["donors"]:
            tune_arch_registry(registry, donor, common.SHAPE, dp=common.DP,
                               tp=common.TP, total_trials=p["trials"],
                               seed=common.SEED)

        elastic = _run(p, base, scratch, "elastic", replicas=1, elastic=True,
                       model=model, params=params, cfg=cfg)
        fixed = {k: _run(p, base, scratch, f"fixed-{k}", replicas=k,
                         elastic=False, model=model, params=params, cfg=cfg)
                 for k in sorted(set(p["compare"]) | {p["max_replicas"]})}

        joins = [e for e in elastic["scale_events"] if e["action"] == "join"]
        retires = [e for e in elastic["scale_events"]
                   if e["action"] == "retire"]
        warm = all(e["join_exact_share"] >= e["pre_join_exact_share"]
                   for e in joins)
        drops = sum(n - (s["completed"] + s["shed"])
                    for s in [elastic, *fixed.values()])
        mismatches = sum(s["schedule_mismatches"]
                         for s in [elastic, *fixed.values()])
        budget_ref = fixed[max(p["compare"])]
        beats = all(
            elastic["latency_ticks"]["p99"] < fixed[k]["latency_ticks"]["p99"]
            and elastic["shed_rate"] <= fixed[k]["shed_rate"]
            for k in p["compare"])
        sheds_less = elastic["shed_rate"] < fixed[min(p["compare"])]["shed_rate"]
        within_budget = (elastic["replica_seconds"]
                         <= budget_ref["replica_seconds"] * 1.001)
        ok = (beats and sheds_less and within_budget and warm
              and len(joins) >= 2 and len(retires) >= 2
              and drops == 0 and mismatches == 0)

        rows = [("autoscale/elastic_p99_ticks",
                 round(elastic["latency_ticks"]["p99"], 1),
                 f"shed_rate={elastic['shed_rate']:.2f} "
                 f"ups={len(joins)} downs={len(retires)} "
                 f"replica_s={elastic['replica_seconds']:.3g}")]
        for k, s in sorted(fixed.items()):
            ref = " (reference)" if k not in p["compare"] else ""
            rows.append((f"autoscale/fixed{k}_p99_ticks",
                         round(s["latency_ticks"]["p99"], 1),
                         f"shed_rate={s['shed_rate']:.2f} "
                         f"replica_s={s['replica_seconds']:.3g}{ref}"))
        worst = max(p["compare"],
                    key=lambda k: fixed[k]["latency_ticks"]["p99"])
        rows.append(
            ("autoscale/elastic_win",
             round(fixed[worst]["latency_ticks"]["p99"]
                   / max(elastic["latency_ticks"]["p99"], 1e-9), 2),
             f"beats fixed {p['compare']} on p99+shed at <= fixed-"
             f"{max(p['compare'])} replica-seconds, warm_joins={warm}, "
             f"drops={drops}, mismatches={mismatches}: "
             f"{'PASS' if ok else 'FAIL'}"))
        common.save_result("autoscale", {
            "preset": preset,
            "arch": p["arch"],
            "donors": p["donors"],
            "trace": {"requests": n, "base_rate": p["base_rate"],
                      "burst_rate": p["burst_rate"],
                      "burst_every_ticks": p["burst_every_ticks"],
                      "burst_len_ticks": p["burst_len_ticks"],
                      "seed": p["seed"]},
            "elastic": elastic,
            "fixed": {str(k): v for k, v in fixed.items()},
            "scale_ups": len(joins),
            "scale_downs": len(retires),
            "warm_joins_ok": warm,
            "dropped_requests": drops,
            "schedule_mismatches": mismatches,
            "pass": ok,
        }, metrics={
            "elastic_p99_ticks": elastic["latency_ticks"]["p99"],
            "elastic_shed_rate": elastic["shed_rate"],
            "elastic_replica_seconds": elastic["replica_seconds"],
            "scale_ups": len(joins),
            "scale_downs": len(retires),
        }, gated={
            "elastic_p99_ticks": "lower",
            "elastic_shed_rate": "lower",
        })
        return rows
    finally:
        shutil.rmtree(scratch, ignore_errors=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default="smoke")
    args = ap.parse_args()
    common.emit(run(args.preset),
                "Elastic fleet — autoscaling vs fixed sizes on bursty load")
