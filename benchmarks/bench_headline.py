"""Paper Fig. 5 + Table 4 — the headline comparison.

Per architecture:
  * transfer-tuning speedup (donor = Eq. 1 heuristic) and its search time;
  * Ansor's speedup *given the same search time* (Fig. 5a);
  * the search time Ansor needs to *match* transfer-tuning (Fig. 5b);
  * TT's fraction of the full-budget maximum speedup and of the full search
    time (Table 4).

Both Ansor curves come from the cached full-budget search trace, so the
comparison uses one tuning run per arch.
"""
from __future__ import annotations

from benchmarks import common
from repro.configs import ARCH_IDS
from repro.core.tuner import transfer_arch


def run() -> list[tuple]:
    db = common.full_db()
    rows = []
    payload = {}
    agg = {"pct_max": [], "pct_time": [], "match_ratio": []}
    for arch in ARCH_IDS:
        d = common.tune_arch_cached(arch)
        tt = transfer_arch(db, arch, common.SHAPE, dp=common.DP, tp=common.TP,
                           donors="auto", seed=common.SEED)
        max_speedup = d["untuned_seconds"] / d["tuned_seconds"]
        ansor_same_time = common.speedup_at_time(d, tt.search_time_s)
        match_t = common.time_to_reach(d, tt.tuned_seconds)
        match_ratio = (match_t / tt.search_time_s) if (match_t and tt.search_time_s > 0) else None
        pct_max = (tt.speedup - 1) / max(max_speedup - 1, 1e-9) * 100
        pct_time = tt.search_time_s / max(d["search_time_s"], 1e-9) * 100
        donor = tt.kernels and next((k.chosen_from for k in tt.kernels if k.chosen_from), "-")
        rows.append((
            f"headline/{arch}",
            round(tt.tuned_seconds * 1e6, 1),
            f"tt_speedup={tt.speedup:.2f}x ansor_same_time={ansor_same_time:.2f}x "
            f"ansor_match={'%.1fx_more_time' % match_ratio if match_ratio else 'never'} "
            f"pct_of_max={pct_max:.1f}% pct_of_search_time={pct_time:.2f}% donor={donor}",
        ))
        payload[arch] = {
            "tt_speedup": tt.speedup, "tt_search_s": tt.search_time_s,
            "tt_coverage": tt.coverage(), "donor": donor,
            "max_speedup": max_speedup, "ansor_same_time": ansor_same_time,
            "ansor_match_time_s": match_t, "match_ratio": match_ratio,
            "pct_of_max_speedup": pct_max, "pct_of_search_time": pct_time,
        }
        agg["pct_max"].append(pct_max)
        agg["pct_time"].append(pct_time)
        if match_ratio:
            agg["match_ratio"].append(match_ratio)
    mean = lambda xs: sum(xs) / max(len(xs), 1)
    rows.append(("headline/MEAN", 0,
                 f"pct_of_max={mean(agg['pct_max']):.1f}% "
                 f"pct_of_search_time={mean(agg['pct_time']):.2f}% "
                 f"ansor_needs={mean(agg['match_ratio']):.1f}x_more_time "
                 f"(paper: 49.12%, 2.08%, 6.5x)"))
    payload["mean"] = {k: mean(v) for k, v in agg.items()}

    # Beyond-paper: compatibility-aware donor selection (heuristic v2 —
    # the paper's §4.4.2 future-work direction).
    v2_pct, v2_pct_capped = [], []
    for arch in ARCH_IDS:
        d = common.tune_arch_cached(arch)
        max_speedup = d["untuned_seconds"] / d["tuned_seconds"]
        tt2 = transfer_arch(db, arch, common.SHAPE, dp=common.DP, tp=common.TP,
                            donors="auto2", seed=common.SEED)
        pct = (tt2.speedup - 1) / max(max_speedup - 1, 1e-9) * 100
        v2_pct.append(pct)
        v2_pct_capped.append(min(pct, 100.0))
        payload[arch]["v2_speedup"] = tt2.speedup
        payload[arch]["v2_pct_of_max"] = pct
        rows.append((f"headline_v2/{arch}", round(tt2.tuned_seconds * 1e6, 1),
                     f"tt2_speedup={tt2.speedup:.2f}x pct_of_max={pct:.1f}%"))
    rows.append(("headline_v2/MEAN", 0,
                 f"pct_of_max={mean(v2_pct):.1f}% (capped@100: {mean(v2_pct_capped):.1f}%) "
                 f"vs Eq.1 {mean(agg['pct_max']):.1f}% — compat-aware donor selection"))
    payload["mean"]["v2_pct_max"] = mean(v2_pct)
    payload["mean"]["v2_pct_max_capped"] = mean(v2_pct_capped)
    common.save_result("headline", payload, metrics={
        "pct_of_max": payload["mean"]["pct_max"],
        "pct_of_search_time": payload["mean"]["pct_time"],
        "ansor_match_ratio": payload["mean"]["match_ratio"],
        "v2_pct_of_max_capped": payload["mean"]["v2_pct_max_capped"],
    }, gated={
        "pct_of_max": "higher",
        "v2_pct_of_max_capped": "higher",
    })
    return rows


if __name__ == "__main__":
    common.emit(run(), "Fig.5 / Table 4 — transfer-tuning vs Ansor")
