"""Segmented schedule registry: durability, atomicity, compaction, versioning."""
import json
import os

import pytest

from repro.core.autoscheduler import tune_kernel
from repro.core.database import Record, SCHEMA_VERSION, ScheduleDB, UnknownSchemaVersion
from repro.core.schedule import default_schedule
from repro.core.workload import KernelInstance
from repro.service import RegistryError, ScheduleRegistry
from repro.service.registry import MANIFEST_NAME, SEGMENT_DIR


def g(m, n=None, k=None):
    return KernelInstance.make("matmul", M=m, N=n or m, K=k or m)


def rec(inst, secs, model="m"):
    return Record(inst, default_schedule(inst), secs, model)


@pytest.fixture
def root(tmp_path):
    return str(tmp_path / "registry")


def segment_files(root):
    return sorted(os.listdir(os.path.join(root, SEGMENT_DIR)))


def test_publish_reopen_roundtrip(root):
    reg = ScheduleRegistry(root)
    assert reg.generation == 0
    g1 = reg.publish([rec(g(512), 2.0), rec(g(256), 1.0)])
    g2 = reg.publish([rec(g(512), 1.5, "other")])
    assert (g1, g2) == (1, 2)

    reopened = ScheduleRegistry(root)
    assert reopened.generation == 2
    db = reopened.snapshot().db()
    assert len(db) == 3
    assert db.exact(g(512)).seconds == 1.5


def test_snapshot_is_immutable_and_lock_free(root):
    reg = ScheduleRegistry(root)
    reg.publish([rec(g(512), 2.0)])
    snap = reg.snapshot()
    reg.publish([rec(g(512), 1.0)])
    # the held snapshot still sees the old world; the fresh one the new
    assert snap.db().exact(g(512)).seconds == 2.0
    assert reg.snapshot().db().exact(g(512)).seconds == 1.0
    assert reg.snapshot().generation == snap.generation + 1


def test_each_publish_is_one_segment(root):
    reg = ScheduleRegistry(root)
    reg.publish([rec(g(512), 2.0), rec(g(256), 1.0)])
    reg.publish([rec(g(128), 1.0)])
    assert len(segment_files(root)) == 2


def test_partial_trailing_write_recovers(root):
    reg = ScheduleRegistry(root)
    reg.publish([rec(g(512), 2.0), rec(g(256), 1.0)])
    [seg] = segment_files(root)
    path = os.path.join(root, SEGMENT_DIR, seg)
    # crash mid-append: chop the file inside the last record's JSON
    data = open(path).read().rstrip("\n")
    with open(path, "w") as f:
        f.write(data[: len(data) - 25])

    reopened = ScheduleRegistry(root)
    db = reopened.snapshot().db()
    assert len(db) == 1                      # complete prefix survives
    assert db.exact(g(512)).seconds == 2.0   # first record intact
    assert reopened.recovered_partial_lines == 1


def test_mid_segment_corruption_is_an_error(root):
    reg = ScheduleRegistry(root)
    reg.publish([rec(g(512), 2.0), rec(g(256), 1.0)])
    [seg] = segment_files(root)
    path = os.path.join(root, SEGMENT_DIR, seg)
    lines = open(path).read().rstrip("\n").split("\n")
    lines[1] = lines[1][:-20]                # corrupt a NON-tail record
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    with pytest.raises(RegistryError):
        ScheduleRegistry(root)


def test_unreferenced_partial_segment_is_ignored(root):
    """A crash between segment write and manifest swap leaves an orphan file
    the manifest never references — reopen must not read it."""
    reg = ScheduleRegistry(root)
    reg.publish([rec(g(512), 2.0)])
    with open(os.path.join(root, SEGMENT_DIR, "seg-999999.jsonl"), "w") as f:
        f.write('{"version": 1, "kind": "segm')   # torn header
    reopened = ScheduleRegistry(root)
    assert len(reopened.snapshot()) == 1


def test_compaction_keeps_best_per_instance_and_mode(root):
    reg = ScheduleRegistry(root)
    reg.publish([rec(g(512), 2.0, "a"), rec(g(256), 1.0, "a")])
    reg.publish([rec(g(512), 1.5, "b")])
    reg.publish([rec(g(512), 3.0, "c")], mode="adaptive")
    gen_before = reg.generation

    gen = reg.compact()
    assert gen == gen_before + 1
    assert len(segment_files(root)) == 1     # old segments deleted
    snap = reg.snapshot()
    assert len(snap) == 3                    # (512,strict) (256,strict) (512,adaptive)
    assert snap.db("strict").exact(g(512)).seconds == 1.5
    assert snap.db("adaptive").exact(g(512)).seconds == 3.0
    # reopen agrees with the in-process view
    assert len(ScheduleRegistry(root).snapshot()) == 3


def test_merge_concurrent_schedule_dbs(root):
    db_a = ScheduleDB([rec(g(512), 2.0, "a")])
    db_b = ScheduleDB([rec(g(512), 1.0, "b"), rec(g(256), 1.0, "b")])
    reg = ScheduleRegistry(root)
    reg.merge_db(db_a)
    reg.merge_db(db_b)
    assert reg.generation == 2
    merged = reg.snapshot().db()
    assert len(merged) == 3
    assert merged.exact(g(512)).model_id == "b"


def test_publish_absorbs_other_writers_segments(root):
    """Publishing over a stale in-memory snapshot must pick up segments other
    processes landed in between — not bury them under a matching generation."""
    a = ScheduleRegistry(root)
    b = ScheduleRegistry(root)
    b.publish([rec(g(512), 2.0, "b")])
    a.publish([rec(g(256), 1.0, "a")])     # a's snapshot was stale
    db = a.snapshot().db()
    assert len(db) == 2
    assert db.exact(g(512)) is not None    # b's record is visible
    assert a.generation == 2
    assert len(b.refresh()) == 2


def test_refresh_sees_other_writers(root):
    reader = ScheduleRegistry(root)
    writer = ScheduleRegistry(root)          # second handle = other process
    writer.publish([rec(g(512), 2.0)])
    assert len(reader.snapshot()) == 0       # stale until refreshed
    reader.refresh()
    assert len(reader.snapshot()) == 1
    assert reader.generation == 1


def test_manifest_version_is_validated(root):
    ScheduleRegistry(root)
    mpath = os.path.join(root, MANIFEST_NAME)
    manifest = json.load(open(mpath))
    manifest["version"] = 99
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(UnknownSchemaVersion):
        ScheduleRegistry(root)


def test_segment_version_is_validated(root):
    reg = ScheduleRegistry(root)
    reg.publish([rec(g(512), 2.0)])
    [seg] = segment_files(root)
    path = os.path.join(root, SEGMENT_DIR, seg)
    lines = open(path).read().rstrip("\n").split("\n")
    lines[0] = json.dumps({"version": SCHEMA_VERSION + 1, "kind": "segment"})
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    with pytest.raises(UnknownSchemaVersion):
        ScheduleRegistry(root)


def test_registry_roundtrips_tuned_schedules(root):
    inst = g(512)
    res = tune_kernel(inst, trials=64)
    reg = ScheduleRegistry(root)
    reg.publish([Record(inst, res.best, res.best_seconds, "donor")])
    back = ScheduleRegistry(root).snapshot().db().exact(inst)
    assert back.schedule == res.best and back.seconds == res.best_seconds
