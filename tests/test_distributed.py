"""Distribution tests under 8 host devices (subprocess: jax locks the device
count at first init, so multi-device scenarios each run in a fresh process).
Covers: sharded train step on a (4,2) mesh, pipeline parallelism over a pod
axis, elastic checkpoint restore onto a different mesh, straggler monitor."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_sharded_train_step_runs():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_arch, reduced
        from repro.models import build_model
        from repro.launch import steps as steps_mod
        from repro.launch.mesh import make_test_mesh
        from repro.distributed import sharding as shd
        from repro.distributed.context import activation_sharding
        from repro.optim.adamw import AdamWConfig

        cfg = reduced(get_arch("stablelm-12b"))
        mesh = make_test_mesh(model=2)   # (4, 2) over 8 host devices
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        p_sh = shd.param_shardings(jax.eval_shape(lambda: params), cfg, mesh)
        params = jax.device_put(params, p_sh)
        opt = steps_mod.init_opt_state(params)
        o_sh = shd.opt_state_shardings(p_sh, mesh)
        opt = jax.device_put(opt, o_sh)
        step = steps_mod.make_train_step(model, AdamWConfig(warmup_steps=1, total_steps=4))
        jitted = jax.jit(step, in_shardings=(p_sh, o_sh, None),
                         out_shardings=(p_sh, o_sh, None), donate_argnums=(0, 1))
        batch = {"tokens": jnp.ones((8, 16), jnp.int32)}
        with activation_sharding(shd.activation_sharding(mesh, cfg)):
            params, opt, m = jitted(params, opt, batch)
            params, opt, m = jitted(params, opt, batch)
        assert bool(jnp.isfinite(m["loss"])), m
        # a TP-sharded leaf is genuinely distributed
        leaf = params["groups"]["0"]["attn"]["wq"]
        assert len(leaf.sharding.device_set) > 1
        print("LOSS", float(m["loss"]))
        print("OK")
    """)
    assert "OK" in out


def test_pipeline_parallel_matches_sequential():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipeline_apply, bubble_fraction
        mesh = jax.make_mesh((4,), ("pod",))
        n_stages, d = 4, 16
        r = np.random.default_rng(0)
        ws = jnp.asarray(r.normal(size=(n_stages, d, d)) * 0.3, jnp.float32)
        x = jnp.asarray(r.normal(size=(8, d)), jnp.float32)
        def stage(w, h):
            return jnp.tanh(h @ w)
        y_pipe = pipeline_apply(stage, ws, x, mesh=mesh, axis="pod", n_microbatches=4)
        y_seq = x
        for i in range(n_stages):
            y_seq = stage(ws[i], y_seq)
        np.testing.assert_allclose(np.asarray(y_pipe), np.asarray(y_seq), rtol=1e-5, atol=1e-5)
        assert 0 < bubble_fraction(4, 4) < 1
        print("OK")
    """)
    assert "OK" in out


def test_elastic_restore_across_meshes(tmp_path):
    out = run_with_devices(f"""
        import jax, jax.numpy as jnp
        from repro.configs import get_arch, reduced
        from repro.models import build_model
        from repro.checkpoint import CheckpointManager
        from repro.distributed import sharding as shd
        from repro.distributed.fault import elastic_restore

        cfg = reduced(get_arch("minitron-4b"))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        mesh8 = jax.make_mesh((4, 2), ("data", "model"))
        p8 = shd.param_shardings(jax.eval_shape(lambda: params), cfg, mesh8)
        params8 = jax.device_put(params, p8)
        m = CheckpointManager({str(tmp_path)!r})
        m.save(3, {{"params": params8}})

        # "failure": restore onto a smaller 4-device mesh (elastic downscale)
        mesh4 = jax.make_mesh((2, 2), ("data", "model"),
                              devices=jax.devices()[:4])
        step, restored = elastic_restore(m, {{"params": jax.eval_shape(lambda: params)}},
                                         cfg, mesh4)
        assert step == 3
        leaf = restored["params"]["groups"]["0"]["attn"]["wq"]
        assert leaf.sharding.device_set <= set(jax.devices()[:4])
        import numpy as np
        np.testing.assert_array_equal(
            np.asarray(jax.device_get(leaf), np.float32),
            np.asarray(jax.device_get(params8["groups"]["0"]["attn"]["wq"]), np.float32))
        print("OK")
    """)
    assert "OK" in out


def test_multipod_mesh_constructs():
    out = run_with_devices("""
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=True)
        assert mesh.shape == {"pod": 2, "data": 16, "model": 16}
        mesh1 = make_production_mesh()
        assert mesh1.shape == {"data": 16, "model": 16}
        print("OK")
    """, n=512)
    assert "OK" in out


def test_straggler_monitor():
    from repro.distributed import StragglerMonitor

    m = StragglerMonitor(threshold=2.0, warmup=2)
    for step in range(6):
        assert not m.record(step, 1.0)
    assert m.record(6, 5.0)          # flagged
    assert not m.record(7, 1.05)     # baseline not poisoned
    assert len(m.flagged) == 1 and m.flagged[0][0] == 6


def test_preemption_handler():
    from repro.distributed import PreemptionHandler

    h = PreemptionHandler(install_signal=False)
    assert not h.requested
    h.request()
    assert h.requested
