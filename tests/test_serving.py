"""Serving engine: continuous batching semantics."""
import jax
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import build_model
from repro.serving import ServingEngine


@pytest.fixture(scope="module")
def small_lm():
    cfg = reduced(get_arch("minitron-4b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_fills_slots_and_rejects_overflow(small_lm):
    cfg, model, params = small_lm
    eng = ServingEngine(model, params, slots=2, max_len=32)
    assert eng.add_request([1, 2, 3]) is not None
    assert eng.add_request([4, 5]) is not None
    assert eng.add_request([6]) is None  # full
    eng.run_to_completion()
    assert not eng.active


def test_slot_reuse_after_completion(small_lm):
    cfg, model, params = small_lm
    eng = ServingEngine(model, params, slots=1, max_len=32)
    r1 = eng.add_request([1, 2], max_new_tokens=2)
    eng.run_to_completion()
    assert r1.done
    r2 = eng.add_request([3, 4], max_new_tokens=2)
    assert r2 is not None
    eng.run_to_completion()
    assert r2.done


def test_continuous_equals_solo(small_lm):
    """A request joining mid-flight sees the same distribution it would see
    alone.  Token trajectories can diverge from fp near-ties across batch
    shapes, so the contract is logit-level: first token identical (same
    prefill computation), joint-decode logits allclose to solo logits."""
    import numpy as np

    cfg, model, params = small_lm
    eng = ServingEngine(model, params, slots=3, max_len=48)
    eng.add_request([5, 6, 7, 8], max_new_tokens=6)
    eng.step()
    eng.step()
    late = eng.add_request([9, 10, 11], max_new_tokens=5)
    late_slot = next(s for s, r in eng.active.items() if r is late)
    eng.step()
    joint_logits = np.asarray(eng.last_logits)[late_slot]

    solo_eng = ServingEngine(model, params, slots=1, max_len=48)
    solo = solo_eng.add_request([9, 10, 11], max_new_tokens=5)
    assert late.generated[0] == solo.generated[0]  # prefill is identical math
    solo_eng.step()
    solo_logits = np.asarray(solo_eng.last_logits)[0]
    np.testing.assert_allclose(joint_logits, solo_logits, rtol=2e-4, atol=2e-4)


def test_eos_stops_early(small_lm):
    cfg, model, params = small_lm
    eng = ServingEngine(model, params, slots=1, max_len=32)
    r = eng.add_request([1, 2, 3], max_new_tokens=30)
    # force EOS = whatever it generates next
    eos = None
    while not r.done:
        if eos is None and r.generated:
            eos = r.generated[-1]
            r.eos_id = eos
        eng.step()
    assert len(r.generated) <= 31


def test_windowed_arch_serving():
    cfg = reduced(get_arch("mixtral-8x22b"))  # SWA ring caches
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    eng = ServingEngine(model, params, slots=2, max_len=64)
    # prompt + generation longer than the (reduced, 8) window: ring must wrap
    r = eng.add_request(list(np.arange(1, 13)), max_new_tokens=12)
    eng.run_to_completion(max_steps=64)
    assert r.done and len(r.generated) == 13
