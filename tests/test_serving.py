"""Serving engine: continuous batching semantics."""
import jax
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import build_model
from repro.serving import ServingEngine, SlotsFull


@pytest.fixture(scope="module")
def small_lm():
    cfg = reduced(get_arch("minitron-4b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_fills_slots_and_rejects_overflow(small_lm):
    cfg, model, params = small_lm
    eng = ServingEngine(model, params, slots=2, max_len=32)
    assert eng.free_slots == 2 and eng.utilization() == 0.0
    assert eng.add_request([1, 2, 3]) is not None
    assert eng.add_request([4, 5]) is not None
    assert eng.free_slots == 0 and eng.utilization() == 1.0
    with pytest.raises(SlotsFull):
        eng.add_request([6])  # full batch: explicit backpressure signal
    eng.run_to_completion()
    assert not eng.active
    assert eng.free_slots == 2


def test_prompt_longer_than_max_len_rejected(small_lm):
    cfg, model, params = small_lm
    eng = ServingEngine(model, params, slots=1, max_len=8)
    with pytest.raises(ValueError, match="max_len"):
        eng.add_request(list(range(1, 10)))
    assert not eng.active  # nothing was admitted


def test_zero_new_tokens_finishes_at_admission(small_lm):
    cfg, model, params = small_lm
    eng = ServingEngine(model, params, slots=1, max_len=32)
    r = eng.add_request([1, 2, 3], max_new_tokens=0)
    # prefill emits the one (free) token; no decode slot is ever held
    assert r.done and len(r.generated) == 1
    assert not eng.active and eng.free_slots == 1
    # the slot is immediately reusable
    r2 = eng.add_request([4, 5], max_new_tokens=2)
    eng.run_to_completion()
    assert r2.done


def test_eos_on_prefill_token_finishes_at_admission(small_lm):
    cfg, model, params = small_lm
    probe = ServingEngine(model, params, slots=1, max_len=32)
    first = probe.add_request([1, 2, 3], max_new_tokens=4).generated[0]

    eng = ServingEngine(model, params, slots=1, max_len=32)
    r = eng.add_request([1, 2, 3], max_new_tokens=4, eos_id=first)
    assert r.done and r.generated == [first]
    assert not eng.active


def test_slot_reuse_after_completion(small_lm):
    cfg, model, params = small_lm
    eng = ServingEngine(model, params, slots=1, max_len=32)
    r1 = eng.add_request([1, 2], max_new_tokens=2)
    eng.run_to_completion()
    assert r1.done
    r2 = eng.add_request([3, 4], max_new_tokens=2)
    assert r2 is not None
    eng.run_to_completion()
    assert r2.done


def test_continuous_equals_solo(small_lm):
    """A request joining mid-flight sees the same distribution it would see
    alone.  Token trajectories can diverge from fp near-ties across batch
    shapes, so the contract is logit-level: first token identical (same
    prefill computation), joint-decode logits allclose to solo logits."""
    import numpy as np

    cfg, model, params = small_lm
    eng = ServingEngine(model, params, slots=3, max_len=48)
    eng.add_request([5, 6, 7, 8], max_new_tokens=6)
    eng.step()
    eng.step()
    late = eng.add_request([9, 10, 11], max_new_tokens=5)
    late_slot = next(s for s, r in eng.active.items() if r is late)
    eng.step()
    joint_logits = np.asarray(eng.last_logits)[late_slot]

    solo_eng = ServingEngine(model, params, slots=1, max_len=48)
    solo = solo_eng.add_request([9, 10, 11], max_new_tokens=5)
    assert late.generated[0] == solo.generated[0]  # prefill is identical math
    solo_eng.step()
    solo_logits = np.asarray(solo_eng.last_logits)[0]
    np.testing.assert_allclose(joint_logits, solo_logits, rtol=2e-4, atol=2e-4)


def test_max_new_tokens_is_exact(small_lm):
    """``max_new_tokens=N`` yields exactly N tokens, counting the free
    prefill token — pins the historical off-by-one that emitted N+1."""
    cfg, model, params = small_lm
    eng = ServingEngine(model, params, slots=1, max_len=32)
    for n in (1, 2, 5):
        r = eng.add_request([1, 2, 3], max_new_tokens=n)
        eng.run_to_completion()
        assert r.done and len(r.generated) == n


def test_eos_stops_early(small_lm):
    cfg, model, params = small_lm
    eng = ServingEngine(model, params, slots=1, max_len=32)
    r = eng.add_request([1, 2, 3], max_new_tokens=30)
    # force EOS = whatever it generates next
    eos = None
    while not r.done:
        if eos is None and r.generated:
            eos = r.generated[-1]
            r.eos_id = eos
        eng.step()
    assert len(r.generated) <= 31


def test_prefill_buckets_bound_traces_and_preserve_output(small_lm):
    """Prompts of many distinct lengths share O(log max_len) prefill traces,
    and right-padding + true_len is exact: same tokens as unbucketed."""
    cfg, model, params = small_lm
    for prompt in ([1, 2, 3], [9, 10, 11, 12, 13], [4] * 7):
        bucketed = ServingEngine(model, params, slots=1, max_len=32)
        exact = ServingEngine(model, params, slots=1, max_len=32,
                              prefill_buckets=False)
        rb = bucketed.add_request(list(prompt), max_new_tokens=3)
        re_ = exact.add_request(list(prompt), max_new_tokens=3)
        bucketed.step()
        exact.step()
        np.testing.assert_allclose(np.asarray(bucketed.last_logits)[0],
                                   np.asarray(exact.last_logits)[0],
                                   rtol=1e-5, atol=1e-5)
        bucketed.run_to_completion()
        exact.run_to_completion()
        assert rb.generated == re_.generated

    eng = ServingEngine(model, params, slots=1, max_len=32)
    for n in range(1, 9):  # 8 distinct prompt lengths -> buckets {1,2,4,8}
        r = eng.add_request(list(range(1, n + 1)), max_new_tokens=1)
        eng.run_to_completion()
        assert r.done
    assert eng.prefill_trace_count <= 4


def test_recurrent_arch_skips_bucketing():
    """Padding a recurrent scan would fold pad steps into the state — the
    engine must fall back to exact-length prefill for R-layer archs."""
    cfg = reduced(get_arch("recurrentgemma-2b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, slots=1, max_len=32)
    assert not eng.prefill_buckets
    r = eng.add_request([1, 2, 3], max_new_tokens=3)
    eng.run_to_completion()
    assert r.done


def test_plan_replans_at_step_boundary_and_serves_upgrade(small_lm, tmp_path):
    """A schedule published mid-serve reaches the live engine: the plan is
    swapped at a decode-step boundary (never mid-step) and the upgraded
    schedule becomes the plan's exact-tier entry."""
    import dataclasses

    from repro.core.database import Record
    from repro.core.schedule import default_schedule
    from repro.kernels.ops import ScheduleProvider
    from repro.service import ScheduleRegistry, TuningService

    cfg, model, params = small_lm
    registry = ScheduleRegistry(str(tmp_path / "reg"))
    service = TuningService(registry, model_id="serve", max_workers=0,
                            probe_candidates=0)
    provider = ScheduleProvider(service=service)
    eng = ServingEngine(model, params, slots=2, max_len=32, provider=provider)
    assert eng.plan is not None and len(eng.plan) > 0

    eng.add_request([1, 2, 3], max_new_tokens=8)
    eng.add_request([4, 5, 6, 7], max_new_tokens=8)
    eng.step()
    eng.step()
    g0 = eng.plan.generation
    assert eng.replans == 0

    inst = next(u.instance for u in eng.plan.uses
                if u.instance.class_id == "matmul")
    assert eng.plan.lookup(inst).tier == "default"
    upgraded = dataclasses.replace(default_schedule(inst), unroll=4,
                                   source="background")
    registry.publish([Record(instance=inst, schedule=upgraded,
                             seconds=service.runner.seconds(inst, upgraded),
                             model_id="background", target=service.target)])
    # nothing swaps until the next step boundary
    assert eng.plan.generation == g0

    eng.run_to_completion()
    assert eng.replans == 1
    entry = eng.plan.lookup(inst)
    assert entry.tier == "exact" and entry.schedule == upgraded
    assert not eng.active  # the stream kept serving through the swap

    gens = [g for _, g in eng.plan_history]
    # plan_history records transition points: one generation at the start,
    # one swap, monotone — the upgrade landed at a boundary, never mid-step
    assert gens == sorted(gens)
    assert gens[0] == g0 and gens[-1] > g0
    assert len(gens) == len(set(gens)) == 2


def test_windowed_arch_serving():
    cfg = reduced(get_arch("mixtral-8x22b"))  # SWA ring caches
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    eng = ServingEngine(model, params, slots=2, max_len=64)
    # prompt + generation longer than the (reduced, 8) window: ring must wrap
    r = eng.add_request(list(np.arange(1, 13)), max_new_tokens=12)
    eng.run_to_completion(max_steps=64)
    assert r.done and len(r.generated) == 12
