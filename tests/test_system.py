"""End-to-end behaviour: the paper's workflow over the full framework."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.core.database import ScheduleDB
from repro.core.tuner import arch_uses, donor_ranking, transfer_arch, tune_arch
from repro.kernels import ops
from repro.kernels.ops import ScheduleProvider
from repro.models import build_model


@pytest.fixture(scope="module")
def tuned_db():
    """Tune two donor archs (small trial budgets) into one DB."""
    db = ScheduleDB()
    tune_arch(db, "minitron-4b", "train_4k", dp=16, tp=16, total_trials=192, seed=0)
    tune_arch(db, "starcoder2-7b", "train_4k", dp=16, tp=16, total_trials=192, seed=0)
    return db


def test_paper_workflow_end_to_end(tuned_db):
    """Tune donors -> heuristic picks one -> transfer-tuning speeds up the
    target at a fraction of the donor search time (the paper's headline)."""
    ranked = donor_ranking(tuned_db, "gemma2-2b", "train_4k", dp=16, tp=16)
    assert ranked and ranked[0].score > 0
    tt = transfer_arch(tuned_db, "gemma2-2b", "train_4k", dp=16, tp=16, donors="auto")
    assert tt.speedup > 1.0
    assert 0 < tt.coverage() <= 1.0
    # transfer search is several times cheaper than one donor's tuning
    # (192 trials x >=1.2s compile each > 230s of virtual search)
    assert tt.search_time_s < 0.5 * 192 * 1.2


def test_transfer_result_drives_execution(tuned_db):
    """Chosen schedules plumb into the Pallas ops via ScheduleProvider.
    (adaptive mode so every class transfer concretizes — this test is about
    the execution plumbing, not strict-mode validity rates)."""
    tt = transfer_arch(tuned_db, "gemma2-2b", "train_4k", dp=16, tp=16,
                       donors="auto", mode="adaptive")
    provider = ScheduleProvider(tt.schedule_map(), mode="adaptive")
    # replay one transferred matmul through the pallas backend
    chosen = [k for k in tt.kernels if k.chosen is not None
              and k.instance.family == "matmul"]
    assert chosen, "no transferred matmul schedules"
    k = chosen[0]
    m_, n_, k_ = (min(k.instance.extent(a), 64) for a in ("M", "N", "K"))
    r = np.random.default_rng(0)
    x = jnp.asarray(r.normal(size=(m_, k_)), jnp.float32)
    w = jnp.asarray(r.normal(size=(k_, n_)), jnp.float32)
    with ops.use_backend("pallas"):
        y = ops.matmul(x, w, class_id="matmul", provider=provider)
    yr = ops.matmul(x, w, class_id="matmul", backend="ref")
    np.testing.assert_allclose(y, yr, rtol=2e-4, atol=2e-4)
    assert provider.hits + provider.misses >= 1


def test_training_loss_decreases():
    from repro.launch.train import main as train_main

    res = train_main(["--arch", "gemma2-2b", "--steps", "15", "--batch", "4",
                      "--seq", "24", "--log-every", "0"])
    assert res["steps"] == 15
    assert res["last_loss"] < res["first_loss"]


def test_serving_driver():
    from repro.launch.serve import main as serve_main

    res = serve_main(["--arch", "minitron-4b", "--slots", "2", "--requests", "4",
                      "--new-tokens", "4"])
    assert res["requests"] == 4
    assert res["tokens"] > 0


def test_train_checkpoint_resume(tmp_path):
    from repro.launch.train import main as train_main

    d = str(tmp_path / "ckpt")
    res1 = train_main(["--arch", "minitron-4b", "--steps", "6", "--batch", "2",
                       "--seq", "16", "--ckpt-dir", d, "--log-every", "0"])
    res2 = train_main(["--arch", "minitron-4b", "--steps", "10", "--batch", "2",
                       "--seq", "16", "--ckpt-dir", d, "--resume", "--log-every", "0"])
    assert res2["steps"] == 4  # resumed at 6, ran to 10
    assert res2["last_loss"] < res1["first_loss"]


def test_tuning_db_feeds_training(tmp_path, tuned_db):
    """--tuning-db integrates transfer-tuned schedules into the train driver."""
    from repro.launch.train import main as train_main

    path = str(tmp_path / "db.json")
    tuned_db.save(path)
    res = train_main(["--arch", "minitron-4b", "--steps", "3", "--batch", "2",
                      "--seq", "16", "--tuning-db", path, "--log-every", "0"])
    assert res["steps"] == 3


def test_arch_uses_nonempty_for_all_cells():
    for arch in ("dbrx-132b", "rwkv6-1.6b", "whisper-medium"):
        assert arch_uses(arch, "prefill_32k", dp=16, tp=16)
