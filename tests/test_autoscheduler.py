"""Auto-scheduler (Ansor analogue) behaviour."""
import random

from repro.core.autoscheduler import (
    KernelTask,
    Surrogate,
    featurize,
    mutate,
    random_schedule,
    tune_kernel,
    tune_model,
)
from repro.core.cost_model import kernel_seconds, measure
from repro.core.schedule import default_schedule, is_valid
from repro.core.workload import KernelInstance, KernelUse


def g(m=1024, n=1024, k=1024):
    return KernelInstance.make("matmul", M=m, N=n, K=k)


def test_random_schedules_valid_on_source():
    rng = random.Random(0)
    inst = g(768, 768, 768)
    for _ in range(50):
        s = random_schedule(inst, rng)
        assert is_valid(s, inst), s


def test_mutation_preserves_validity():
    rng = random.Random(1)
    inst = g(512, 512, 512)
    s = random_schedule(inst, rng)
    for _ in range(50):
        s = mutate(s, inst, rng)
        assert is_valid(s, inst), s


def test_tuning_improves_over_default():
    inst = g()
    res = tune_kernel(inst, trials=96, seed=0)
    untuned = kernel_seconds(inst, default_schedule(inst))
    # the default is a sensible generic schedule (TVM-analogue), so the
    # headroom is real but bounded
    assert res.best_seconds < untuned / 1.5


def test_trace_monotone_nonincreasing():
    res = tune_kernel(g(512, 512, 512), trials=64, seed=1)
    best = [p.best_seconds for p in res.trace]
    assert all(a >= b for a, b in zip(best, best[1:]))
    times = [p.search_time_s for p in res.trace]
    assert all(a <= b for a, b in zip(times, times[1:]))


def test_reproducible_given_seed():
    a = tune_kernel(g(512, 512, 512), trials=48, seed=3)
    b = tune_kernel(g(512, 512, 512), trials=48, seed=3)
    assert a.best_seconds == b.best_seconds and a.best == b.best


def test_task_scheduler_prioritizes_expensive_kernel():
    """Ansor-style allocation: the dominant kernel gets more trials."""
    cheap = KernelUse(g(128, 128, 128), use_count=1)
    costly = KernelUse(g(4096, 4096, 4096), use_count=8)
    res = tune_model([cheap, costly], "m", total_trials=128, seed=0)
    trials = {r.instance.workload_key(): r.trials for r in res.records}
    assert trials[costly.instance.workload_key()] > trials[cheap.instance.workload_key()]
    assert res.speedup > 1.0


def test_surrogate_learns_ranking():
    inst = g()
    rng = random.Random(0)
    sur = Surrogate()
    pool = [random_schedule(inst, rng) for _ in range(60)]
    measured = [(s, measure(inst, s, seed=0)) for s in pool]
    measured = [(s, m.seconds) for s, m in measured if m.valid]
    train, test = measured[:40], measured[40:]
    assert len(test) >= 5
    for s, sec in train:
        sur.add(featurize(s, inst), sec)
    import numpy as np

    pred = sur.predict([featurize(s, inst) for s, _ in test])
    actual = np.array([sec for _, sec in test])
    # rank correlation must be positive (the model guides search usefully)
    rho = np.corrcoef(np.argsort(np.argsort(pred)), np.argsort(np.argsort(actual)))[0, 1]
    assert rho > 0.2


def test_search_time_accounted():
    task = KernelTask(g(512, 512, 512), seed=0)
    task.step(16)
    assert task.trials == 16
    assert task.search_time_s > 16 * 1.0  # >= compile time per trial
