"""Data pipeline: determinism, sharding, restart semantics."""
import numpy as np

from repro.data import DataConfig, Pipeline, SyntheticSource, make_source


def _cfg(**kw):
    base = dict(vocab_size=1000, seq_len=16, global_batch=8, seed=7)
    base.update(kw)
    return DataConfig(**base)


def test_batch_deterministic():
    a = SyntheticSource(_cfg()).batch_at(12)
    b = SyntheticSource(_cfg()).batch_at(12)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticSource(_cfg()).batch_at(13)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_tokens_in_range():
    batch = SyntheticSource(_cfg()).batch_at(0)
    assert batch["tokens"].min() >= 1
    assert batch["tokens"].max() < 1000
    assert batch["tokens"].shape == (8, 16)


def test_shards_differ_and_partition_batch():
    s0 = SyntheticSource(_cfg(num_shards=2, shard_index=0)).batch_at(5)
    s1 = SyntheticSource(_cfg(num_shards=2, shard_index=1)).batch_at(5)
    assert s0["tokens"].shape == (4, 16)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_pipeline_prefetch_and_restart():
    p = Pipeline(_cfg(), start_step=3)
    step, batch = next(p)
    assert step == 3
    step2, batch2 = next(p)
    assert step2 == 4
    p.close()
    # restart at the same step reproduces the stream exactly
    p2 = Pipeline(_cfg(), start_step=3)
    s, b = next(p2)
    p2.close()
    assert s == 3
    np.testing.assert_array_equal(b["tokens"], batch["tokens"])


def test_memmap_source(tmp_path):
    tokens = np.arange(1000, dtype=np.int32)
    path = str(tmp_path / "corpus.bin")
    tokens.tofile(path)
    src = make_source(_cfg(source="memmap", corpus_path=path))
    a = src.batch_at(2)
    b = src.batch_at(2)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (8, 16)
    assert a["tokens"].max() < 1000
