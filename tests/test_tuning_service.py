"""TuningService: tiered lookup, background upgrades, dedup, no-downgrade."""
import threading

import pytest

from repro.core.autoscheduler import tune_kernel
from repro.core.database import Record, ScheduleDB
from repro.core.runner import AnalyticalRunner, CachedRunner
from repro.core.schedule import Schedule
from repro.core.transfer import transfer_tune
from repro.core.workload import KernelInstance, KernelUse
from repro.service import ScheduleRegistry, TuningService

DONOR_SIZES = {"donor_a": 512, "donor_b": 768}
TARGET = KernelInstance.make("matmul", M=256, N=1024, K=512)


def g(size):
    return KernelInstance.make("matmul", M=size, N=size, K=size)


@pytest.fixture(scope="module")
def donor_records():
    out = []
    for model, size in DONOR_SIZES.items():
        res = tune_kernel(g(size), trials=96, seed=0)
        out.append(Record(g(size), res.best, res.best_seconds, model))
    return out


@pytest.fixture
def registry(tmp_path, donor_records):
    reg = ScheduleRegistry(str(tmp_path / "reg"))
    reg.publish(donor_records)
    return reg


def make_service(registry, **kw):
    kw.setdefault("model_id", "target")
    kw.setdefault("runner", CachedRunner(AnalyticalRunner()))
    kw.setdefault("max_workers", 0)
    kw.setdefault("seed", 0)
    return TuningService(registry, **kw)


def test_exact_tier_for_donor_workload(registry, donor_records):
    svc = make_service(registry)
    res = svc.lookup(g(512))
    assert res.tier == "exact"
    assert res.schedule == donor_records[0].schedule
    assert res.source_model == "donor_a"
    assert svc.stats()["jobs_enqueued"] == 0     # exact hits don't search


def test_transfer_tier_probes_same_class(registry):
    svc = make_service(registry)
    res = svc.lookup(TARGET)
    assert res.tier == "transfer"
    assert res.seconds < res.untuned_seconds
    assert res.source_model in DONOR_SIZES
    assert svc.stats()["jobs_enqueued"] == 1     # miss still queues the upgrade


def test_default_tier_without_candidates(registry):
    svc = make_service(registry, donors=[])      # empty donor pool
    res = svc.lookup(TARGET)
    assert res.tier == "default" and res.schedule is None
    assert res.seconds == res.untuned_seconds


def test_background_job_upgrades_to_exact(registry):
    svc = make_service(registry, probe_candidates=0)
    first = svc.lookup(TARGET)
    assert first.tier == "default"
    assert svc.drain() == 1
    second = svc.lookup(TARGET)
    stats = svc.stats()
    assert second.tier == "exact"
    assert second.seconds < first.seconds
    assert stats["upgrades"] == 1
    assert stats["search_seconds_spent"] > 0
    assert stats["generation"] > 1
    # upgrade is persistent: a fresh service over the same dir serves it
    svc2 = make_service(ScheduleRegistry(registry.root))
    assert svc2.lookup(TARGET).tier == "exact"


def test_jobs_dedupe_by_workload_key(registry):
    svc = make_service(registry)
    for _ in range(5):
        svc.lookup(TARGET)
    stats = svc.stats()
    assert stats["jobs_enqueued"] == 1
    assert stats["jobs_deduped"] == 4
    assert svc.drain() == 1
    # attempted keys are not re-enqueued even when the job published nothing
    svc.lookup(TARGET)
    assert svc.stats()["jobs_enqueued"] == 1


def test_concurrent_misses_one_job(registry):
    svc = make_service(registry, max_workers=2)
    barrier = threading.Barrier(8)

    def hit():
        barrier.wait()
        svc.lookup(TARGET)

    threads = [threading.Thread(target=hit) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    svc.drain()
    stats = svc.stats()
    assert stats["jobs_enqueued"] == 1
    assert stats["jobs_deduped"] == 7
    assert stats["jobs_completed"] == 1
    assert svc.lookup(TARGET).tier == "exact"
    svc.close()


def test_budget_enforced_for_already_queued_jobs(registry):
    """Jobs admitted while the budget was unspent must not run once a
    previous job exhausts it."""
    svc = make_service(registry, budget_s=1e-6, probe_candidates=0)
    svc.lookup(TARGET)
    svc.lookup(KernelInstance.make("matmul", M=128, N=512, K=1024))
    assert svc.stats()["jobs_enqueued"] == 2     # both admitted at spent=0
    svc.drain()
    stats = svc.stats()
    assert stats["jobs_completed"] == 1          # first job spends past budget
    assert stats["jobs_rejected_budget"] == 1    # second refused at run time
    assert stats["in_flight"] == 0


def test_exact_tier_falls_back_to_own_mode_record(registry):
    """A faster mode-incompatible record must not shadow a valid same-mode
    exact record for the workload."""
    svc = make_service(registry, probe_candidates=0)
    svc.lookup(TARGET)
    svc.drain()
    good = svc.lookup(TARGET)
    assert good.tier == "exact"
    # K=96 does not divide TARGET's K=512: strict-invalid, adaptive-valid —
    # and recorded faster, so it wins db(None).exact()
    shadow = Schedule.make("matmul", {"M": 64, "N": 128, "K": 96},
                           order=("M", "N", "K"))
    registry.publish([Record(TARGET, shadow, good.seconds / 10, "adaptive_prod")],
                     mode="adaptive")
    after = svc.lookup(TARGET)
    assert after.tier == "exact"
    assert after.schedule == good.schedule and after.seconds == good.seconds


def test_snapshot_db_views_are_frozen(registry):
    db = registry.snapshot().db()
    with pytest.raises(RuntimeError, match="frozen"):
        db.add(Record(TARGET, db.records()[0].schedule, 1.0, "x"))


def test_budget_bounds_background_search(registry):
    svc = make_service(registry, budget_s=0.0, probe_candidates=0)
    assert svc.lookup(TARGET).tier == "default"
    stats = svc.stats()
    assert stats["jobs_rejected_budget"] == 1
    assert stats["jobs_enqueued"] == 0
    assert svc.drain() == 0
    assert svc.stats()["search_seconds_spent"] == 0.0


def test_never_downgrades_published_schedule(registry):
    svc = make_service(registry, probe_candidates=0)
    svc.lookup(TARGET)
    svc.drain()
    best = svc.lookup(TARGET)
    # a stale/worse publish (e.g. a slower concurrent producer) must not win
    worse = Record(TARGET, best.schedule, best.seconds * 10, "slow_producer")
    registry.publish([worse])
    after = svc.lookup(TARGET)
    assert after.tier == "exact"
    assert after.seconds == best.seconds
    # and the service itself skips publishing non-improvements
    assert svc._publish(TARGET, best.schedule, best.seconds * 2, "x") is False
    assert svc.stats()["publish_skipped"] == 1


def test_drained_service_matches_offline_transfer(registry, donor_records):
    """The online path converges to the offline transfer_tune answer."""
    targets = [TARGET, KernelInstance.make("matmul", M=128, N=512, K=1024)]
    svc = make_service(registry, probe_candidates=0,
                       donors=list(DONOR_SIZES))
    for inst in targets:
        svc.lookup(inst)
    svc.drain()

    offline = transfer_tune([KernelUse(i) for i in targets],
                            ScheduleDB(donor_records), model_id="target",
                            donors=list(DONOR_SIZES), mode="strict", seed=0)
    for inst, k in zip(targets, offline.kernels):
        served = svc.lookup(inst)
        assert served.schedule == k.chosen
        if k.chosen is not None:
            assert served.tier == "exact"
            assert served.seconds == k.seconds


def test_close_drains_deferred_jobs(registry):
    """serve.py promises queued jobs are drained at exit even with
    --tuning-workers 0 — close() must run deferred jobs, not drop them."""
    svc = make_service(registry, probe_candidates=0)   # max_workers=0
    svc.lookup(TARGET)
    assert svc.stats()["in_flight"] == 1
    svc.close()
    stats = svc.stats()
    assert stats["in_flight"] == 0
    assert stats["jobs_completed"] == 1
    assert svc.lookup(TARGET).tier == "exact"


def test_stats_shape(registry):
    svc = make_service(registry)
    svc.lookup(g(512))
    svc.lookup(TARGET)
    s = svc.stats()
    assert s["lookups"] == 2
    assert s["exact_hits"] == 1 and s["transfer_hits"] == 1
    assert s["exact_hit_rate"] == 0.5
    assert s["in_flight"] == 1
    assert s["probe_search_s"] > 0


def test_threaded_pool_claims_highest_priority_first(registry):
    """max_workers=1 with the lone worker blocked: jobs enqueued while the
    pool is busy are claimed priority-first (FIFO within a priority), not
    submission order — ``completed_order`` makes the claim order observable."""
    svc = make_service(registry, max_workers=1, probe_candidates=0)
    gate = threading.Event()
    svc._pool.submit(gate.wait)          # occupy the only worker
    a = KernelInstance.make("matmul", M=192, N=192, K=192)
    b = KernelInstance.make("matmul", M=224, N=224, K=224)
    c = KernelInstance.make("matmul", M=288, N=288, K=288)
    assert svc.prefetch(a, priority=0.0)
    assert svc.prefetch(b, priority=0.0)
    assert svc.prefetch(c, priority=5.0)  # enqueued last, must run first
    gate.set()
    svc.close()
    keys = [a.workload_key(), b.workload_key(), c.workload_key()]
    order = [k for k in svc.completed_order if k in keys]
    assert order == [keys[2], keys[0], keys[1]]


# ---------------------------------------------------------------------------
# Queue health telemetry (stats) and starvation accounting
# ---------------------------------------------------------------------------


def test_stats_queue_health_uses_owner_clock(registry):
    """Queue ages are measured on the owner's clock (fleets pass their
    virtual now), surfaced in stats() and sampled into registry gauges."""
    t = {"v": 0.0}
    svc = make_service(registry, probe_candidates=0, clock=lambda: t["v"])
    a = KernelInstance.make("matmul", M=192, N=192, K=192)
    b = KernelInstance.make("matmul", M=224, N=224, K=224)
    assert svc.prefetch(a, priority=0.0)
    t["v"] = 5.0
    assert svc.prefetch(b, priority=1.0)
    t["v"] = 9.0
    s = svc.stats()
    assert s["queue_depth_unstarted"] == 2
    assert s["queue_age_mean_s"] == pytest.approx((9.0 + 4.0) / 2)
    assert s["oldest_unstarted_age_s"] == pytest.approx(9.0)
    rows = s["queue_jobs"]                    # oldest first
    assert [r["key"] for r in rows] == [a.workload_key(), b.workload_key()]
    assert rows[0]["age_s"] == pytest.approx(9.0)
    assert rows[1]["priority"] == 1.0
    assert not rows[0]["starved"] and rows[0]["skips"] == 0
    g = svc.metrics.get(f"tuning.{svc.target}.queue_age_mean_s")
    assert g.samples[-1] == (9.0, pytest.approx(6.5))
    g2 = svc.metrics.get(f"tuning.{svc.target}.oldest_unstarted_age_s")
    assert g2.samples[-1][1] == pytest.approx(9.0)

    svc.drain()
    s2 = svc.stats()
    assert s2["queue_depth_unstarted"] == 0
    assert s2["queue_age_mean_s"] == 0.0 and s2["queue_jobs"] == []
    svc.close()


def test_starvation_accounting_marks_passed_over_jobs(registry):
    """A low-priority job passed over more than STARVATION_SKIPS times by
    higher-priority claims is counted starved exactly once — the audit the
    advisor's anti-starvation headroom floor is checked against."""
    svc = make_service(registry, probe_candidates=0, clock=lambda: 0.0)
    low = KernelInstance.make("matmul", M=176, N=176, K=176)
    assert svc.prefetch(low, priority=0.0)
    for i in range(TuningService.STARVATION_SKIPS + 1):
        size = 320 + 32 * i
        hot = KernelInstance.make("matmul", M=size, N=size, K=size)
        assert svc.prefetch(hot, priority=10.0)
        assert svc.drain(max_jobs=1) == 1     # claims hot, passes over low
    s = svc.stats()
    row = next(r for r in s["queue_jobs"] if r["key"] == low.workload_key())
    assert row["skips"] == TuningService.STARVATION_SKIPS + 1
    assert row["starved"] is True
    assert s["jobs_starved"] == 1             # counted once, not per skip
    svc.close()
