"""Property tests for the schedule IR (paper §4.1-4.2 semantics)."""
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.schedule import (
    Schedule,
    ScheduleInvalid,
    concretize,
    default_schedule,
    is_valid,
    nearest_divisor,
)
from repro.core.workload import KERNEL_CLASSES, KernelInstance

MATMUL_EXTENTS = st.sampled_from([8, 16, 64, 96, 128, 512, 768, 1024, 4096])
TILES = st.sampled_from([1, 4, 8, 16, 32, 128, 256, 512])


def mk_inst(m, n, k):
    return KernelInstance.make("matmul", M=m, N=n, K=k)


@given(m=MATMUL_EXTENTS, n=MATMUL_EXTENTS, k=MATMUL_EXTENTS,
       tm=TILES, tn=TILES, tk=TILES)
@settings(max_examples=80, deadline=None)
def test_strict_concretize_divides_or_raises(m, n, k, tm, tn, tk):
    inst = mk_inst(m, n, k)
    sched = Schedule.make("matmul", {"M": tm, "N": tn, "K": tk})
    try:
        cs = concretize(sched, inst, mode="strict")
    except ScheduleInvalid:
        # strict invalid iff the reduction tile oversizes or fails to divide
        # (M/N are maskable row/column axes on TPU)
        assert tk > k or k % tk
        return
    # reduction axis divides exactly; maskable axes are clamped to the extent
    assert k % cs.t["K"] == 0
    for axis, extent in (("M", m), ("N", n)):
        assert 1 <= cs.t[axis] <= extent
    assert not cs.adapted


@given(m=MATMUL_EXTENTS, n=MATMUL_EXTENTS, k=MATMUL_EXTENTS,
       tm=TILES, tn=TILES, tk=TILES)
@settings(max_examples=80, deadline=None)
def test_adaptive_concretize_always_valid(m, n, k, tm, tn, tk):
    """Beyond-paper reformulation: adaptive mode never produces invalid code.
    Maskable axes (M, N) may keep non-dividing tiles (partial blocks are
    masked); the reduction axis must divide exactly."""
    inst = mk_inst(m, n, k)
    sched = Schedule.make("matmul", {"M": tm, "N": tn, "K": tk})
    cs = concretize(sched, inst, mode="adaptive")
    assert k % cs.t["K"] == 0 and 1 <= cs.t["K"] <= k
    for axis, extent in (("M", m), ("N", n)):
        assert 1 <= cs.t[axis] <= extent


@given(n=st.integers(1, 4096), target=st.integers(1, 4096))
@settings(max_examples=100, deadline=None)
def test_nearest_divisor_properties(n, target):
    d = nearest_divisor(n, target)
    assert n % d == 0 and d >= 1


def test_self_transfer_is_identity():
    """Applying a schedule to the instance it was tuned for never adapts."""
    inst = mk_inst(512, 512, 512)
    sched = Schedule.make("matmul", {"M": 128, "N": 256, "K": 64})
    cs = concretize(sched, inst)
    assert cs.t == {"M": 128, "N": 256, "K": 64}
    assert not cs.adapted


def test_cross_class_transfer_always_invalid():
    """Paper §4.2: schedules never transfer across kernel classes."""
    sched = Schedule.make("matmul", {"M": 8, "N": 128, "K": 128})
    inst = KernelInstance.make("matmul_bias", M=512, N=512, K=512)
    with pytest.raises(ScheduleInvalid):
        concretize(sched, inst)


@pytest.mark.parametrize("class_id", sorted(KERNEL_CLASSES))
def test_default_schedule_valid_for_every_class(class_id):
    axes = KERNEL_CLASSES[class_id][0]
    inst = KernelInstance.make(class_id, **{a: 384 for a in axes})
    assert is_valid(default_schedule(inst), inst)


def test_json_roundtrip():
    sched = Schedule.make("matmul", {"M": 8, "N": 128, "K": 128},
                          order=("N", "M", "K"), parallel=2, unroll=64,
                          vec=256, cache_write=False, source="abc")
    assert Schedule.from_json(sched.to_json()) == sched


def test_oversized_tile_invalid_strict():
    """Paper: 'a loop splitting factor larger than the loop itself' -> invalid
    (on the reduction axis; row/column axes are masked on TPU)."""
    inst = mk_inst(64, 64, 64)
    sched = Schedule.make("matmul", {"M": 64, "N": 64, "K": 128})
    assert not is_valid(sched, inst, mode="strict")
    assert is_valid(sched, inst, mode="adaptive")
    # maskable axis oversize is fine
    sched_m = Schedule.make("matmul", {"M": 128, "N": 64, "K": 64})
    assert is_valid(sched_m, inst, mode="strict")


def test_glu_odd_n_tile_invalid():
    inst = KernelInstance.make("matmul_silu_glu", M=64, N=64, K=64)
    sched = Schedule.make("matmul_silu_glu", {"M": 8, "N": 5, "K": 8})
    assert not is_valid(sched, inst, mode="strict")
    assert is_valid(sched, inst, mode="adaptive")
