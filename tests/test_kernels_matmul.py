"""Pallas matmul kernel vs pure-jnp oracle: shape/dtype/schedule sweeps."""
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core.schedule import Schedule, concretize
from repro.core.workload import KernelInstance
from repro.kernels import matmul as mk
from repro.kernels import ref

DIMS = st.sampled_from([16, 32, 48, 64, 96])
TILES = st.sampled_from([8, 16, 32])
ORDERS = st.sampled_from([("M", "N", "K"), ("N", "M", "K"), ("M", "K", "N"),
                          ("K", "M", "N"), ("N", "K", "M")])


def _data(m, n, k, dtype):
    r = np.random.default_rng(m * 131 + n * 17 + k)
    x = jnp.asarray(r.normal(size=(m, k)), dtype)
    w = jnp.asarray(r.normal(size=(k, n)), dtype)
    return x, w


@given(m=DIMS, n=DIMS, k=DIMS, tm=TILES, tn=TILES, tk=TILES, order=ORDERS,
       cw=st.booleans())
@settings(max_examples=25, deadline=None)
def test_matmul_matches_oracle(m, n, k, tm, tn, tk, order, cw):
    x, w = _data(m, n, k, jnp.float32)
    inst = KernelInstance.make("matmul", M=m, N=n, K=k, dtype="float32")
    sched = Schedule.make("matmul", {"M": tm, "N": tn, "K": tk}, order=order,
                          cache_write=cw)
    cs = concretize(sched, inst, mode="adaptive")
    y = mk.matmul(x, w, cs, interpret=True)
    np.testing.assert_allclose(y, ref.matmul(x, w, "matmul"), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("class_id,needs", [
    ("matmul_bias", "bias"),
    ("matmul_bias_gelu", "bias"),
    ("matmul_silu_glu", None),
    ("matmul_gelu_glu", None),
    ("matmul_residual", "residual"),
    ("matmul_lmhead", None),
    ("matmul_lmhead_softcap", None),
])
def test_epilogues_match_oracle(class_id, needs):
    m, n, k = 32, 64, 48
    x, w = _data(m, n, k, jnp.float32)
    r = np.random.default_rng(5)
    bias = jnp.asarray(r.normal(size=(n,)), jnp.float32) if needs == "bias" else None
    out_n = n // 2 if "glu" in class_id else n
    residual = jnp.asarray(r.normal(size=(m, out_n)), jnp.float32) if needs == "residual" else None
    softcap = 30.0 if "softcap" in class_id else 0.0
    inst = KernelInstance.make(class_id, M=m, N=n, K=k, dtype="float32")
    cs = concretize(Schedule.make(class_id, {"M": 16, "N": 16, "K": 16}), inst)
    y = mk.matmul(x, w, cs, class_id=class_id, bias=bias, residual=residual,
                  softcap=softcap, interpret=True)
    yr = ref.matmul(x, w, class_id, bias=bias, residual=residual, softcap=softcap)
    np.testing.assert_allclose(y, yr, rtol=2e-4, atol=2e-4)


def test_bfloat16_tolerance():
    m, n, k = 64, 64, 64
    x, w = _data(m, n, k, jnp.bfloat16)
    inst = KernelInstance.make("matmul", M=m, N=n, K=k, dtype="bfloat16")
    cs = concretize(Schedule.make("matmul", {"M": 16, "N": 32, "K": 16}), inst)
    y = mk.matmul(x, w, cs, interpret=True)
    yr = ref.matmul(x, w, "matmul")
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(yr, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_grouped_matmul_matches_vmapped_oracle():
    e, m, n, k = 4, 32, 48, 32
    r = np.random.default_rng(9)
    x = jnp.asarray(r.normal(size=(e, m, k)), jnp.float32)
    w = jnp.asarray(r.normal(size=(e, k, n)), jnp.float32)
    inst = KernelInstance.make("moe_gemm", M=m, N=n, K=k, E=e, dtype="float32")
    cs = concretize(Schedule.make("moe_gemm", {"M": 16, "N": 16, "K": 16, "E": 1},
                                  order=("E", "M", "N", "K")), inst)
    y = mk.grouped_matmul(x, w, cs, interpret=True)
    yr = jax.vmap(lambda a, b: ref.matmul(a, b, "moe_gemm"))(x, w)
    np.testing.assert_allclose(y, yr, rtol=2e-4, atol=2e-4)


def test_glu_forces_scratch_on_bad_order():
    """GLU epilogues silently canonicalize to K-inner scratch accumulation."""
    m, n, k = 32, 32, 32
    x, w = _data(m, n, k, jnp.float32)
    inst = KernelInstance.make("matmul_silu_glu", M=m, N=n, K=k, dtype="float32")
    cs = concretize(Schedule.make("matmul_silu_glu", {"M": 16, "N": 16, "K": 16},
                                  order=("K", "M", "N"), cache_write=False), inst)
    y = mk.matmul(x, w, cs, class_id="matmul_silu_glu", interpret=True)
    np.testing.assert_allclose(y, ref.matmul(x, w, "matmul_silu_glu"),
                               rtol=2e-4, atol=2e-4)
