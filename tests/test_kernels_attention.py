"""Flash-attention kernel + chunked oracle vs naive attention."""
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core.schedule import Schedule, concretize
from repro.core.workload import KernelInstance
from repro.kernels import flash_attention as fa
from repro.kernels import ref


def _data(b, hq, hkv, sq, skv, d, seed=0, dtype=jnp.float32):
    r = np.random.default_rng(seed)
    q = jnp.asarray(r.normal(size=(b, hq, sq, d)), dtype)
    k = jnp.asarray(r.normal(size=(b, hkv, skv, d)), dtype)
    v = jnp.asarray(r.normal(size=(b, hkv, skv, d)), dtype)
    return q, k, v


def _cs(sq, skv, bq, bkv, cls="flash_attention_causal", **p):
    inst = KernelInstance.make(cls, Q=sq, KV=skv, dtype="float32", **p)
    return concretize(Schedule.make(cls, {"Q": bq, "KV": bkv}), inst, mode="adaptive")


@given(sq=st.sampled_from([8, 16, 32]), bq=st.sampled_from([4, 8, 16]),
       bkv=st.sampled_from([4, 8, 16]), causal=st.booleans(),
       window=st.sampled_from([0, 8]), softcap=st.sampled_from([0.0, 20.0]),
       group=st.sampled_from([1, 2]))
@settings(max_examples=24, deadline=None)
def test_kernel_matches_naive(sq, bq, bkv, causal, window, softcap, group):
    b, hkv, d = 2, 2, 16
    hq = hkv * group
    q, k, v = _data(b, hq, hkv, sq, sq, d)
    cs = _cs(sq, sq, bq, bkv)
    y = fa.flash_attention(q, k, v, cs, causal=causal, window=window, softcap=softcap)
    yr = ref.attention(q, k, v, causal=causal, window=window, softcap=softcap)
    np.testing.assert_allclose(y, yr, rtol=2e-4, atol=2e-4)


@given(chunk=st.sampled_from([4, 8, 16, 32]), causal=st.booleans(),
       window=st.sampled_from([0, 8]))
@settings(max_examples=16, deadline=None)
def test_chunked_oracle_matches_naive(chunk, causal, window):
    """The XLA fallback path must be numerically identical to softmax attn."""
    q, k, v = _data(2, 4, 2, 24, 24, 16, seed=3)
    yr = ref.attention(q, k, v, causal=causal, window=window)
    yc = ref.chunked_attention(q, k, v, causal=causal, window=window, chunk=chunk)
    np.testing.assert_allclose(yc, yr, rtol=2e-5, atol=2e-5)


def test_decode_q1_with_offset():
    q, k, v = _data(2, 4, 2, 1, 32, 16, seed=4)
    cs = _cs(1, 32, 1, 8)
    for off in (0, 7, 31):
        y = fa.flash_attention(q, k, v, cs, causal=True, q_offset=off)
        yr = ref.attention(q, k, v, causal=True, q_offset=off)
        np.testing.assert_allclose(y, yr, rtol=2e-4, atol=2e-4)


def test_cross_attention_lengths_differ():
    q, k, v = _data(1, 4, 4, 8, 40, 16, seed=5)
    cs = _cs(8, 40, 4, 8, cls="flash_attention_cross")
    y = fa.flash_attention(q, k, v, cs, causal=False)
    yr = ref.attention(q, k, v, causal=False)
    np.testing.assert_allclose(y, yr, rtol=2e-4, atol=2e-4)


def test_fully_masked_rows_are_finite():
    """Window smaller than block: rows with no visible kv must not NaN."""
    q, k, v = _data(1, 2, 2, 16, 16, 8, seed=6)
    cs = _cs(16, 16, 8, 8)
    y = fa.flash_attention(q, k, v, cs, causal=True, window=2)
    assert bool(jnp.isfinite(y).all())


def test_bf16_kernel():
    q, k, v = _data(1, 2, 2, 16, 16, 16, seed=7, dtype=jnp.bfloat16)
    cs = _cs(16, 16, 8, 8)
    y = fa.flash_attention(q, k, v, cs, causal=True)
    yr = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(yr, np.float32),
                               rtol=3e-2, atol=3e-2)
