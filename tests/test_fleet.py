"""Serving fleet: router queueing/backpressure, dispatch-policy invariants,
cross-replica upgrade propagation, and demand-driven prefetch ordering."""
import dataclasses

import jax
import pytest

from repro.configs import get_arch, reduced
from repro.core.database import Record
from repro.core.schedule import default_schedule
from repro.core.tuner import tune_arch_registry
from repro.fleet import (
    DemandTracker,
    FleetRequest,
    QueueFull,
    RequestRouter,
    ServingFleet,
    TrafficGenerator,
    make_policy,
)
from repro.models import build_model
from repro.service import ScheduleRegistry
from repro.targets import DEFAULT_TARGET


# ---------------------------------------------------------------------------
# Router + policies (fake replicas: no engines needed)
# ---------------------------------------------------------------------------


class FakeReplica:
    def __init__(self, free=1, score=0.0):
        self.free_slots = free
        self.score = score
        self.admitted = []

    def prefill_tier_score(self, prompt_len):
        return self.score

    def admit(self, req, now):
        assert self.free_slots > 0
        self.free_slots -= 1
        self.admitted.append(req)


def _req(uid, arrival=0.0, deadline=None, plen=3):
    return FleetRequest(uid=uid, prompt=[1] * plen, max_new_tokens=2,
                        arrival_s=arrival, deadline_s=deadline)


def test_queue_backpressure_sheds_at_cap():
    router = RequestRouter([FakeReplica(free=0)], queue_cap=2)
    router.submit(_req(1))
    router.submit(_req(2))
    overflow = _req(3)
    with pytest.raises(QueueFull):
        router.submit(overflow)
    assert overflow.shed == "queue_full"
    assert router.counters["shed_queue_full"] == 1
    assert router.counters["submitted"] == 3
    assert router.max_queue_depth == 2
    # no replica has a free slot: everything stays queued
    assert router.dispatch(0.0) == []
    assert router.depth == 2


def test_deadline_expired_requests_shed_at_dispatch():
    router = RequestRouter([FakeReplica(free=2)])
    expired = _req(1, arrival=0.0, deadline=1.0)
    alive = _req(2, arrival=0.0, deadline=100.0)
    router.submit(expired)
    router.submit(alive)
    out = router.dispatch(now=5.0)
    assert [(r.uid, idx) for r, idx in out] == [(2, 0)]
    assert expired.shed == "deadline"
    assert router.counters["shed_deadline"] == 1
    assert router.last_shed_deadline == [expired]


def test_round_robin_cycles_and_skips_full():
    reps = [FakeReplica(free=4), FakeReplica(free=0), FakeReplica(free=4)]
    router = RequestRouter(reps, policy="round_robin", queue_cap=16)
    for i in range(4):
        router.submit(_req(i))
    out = router.dispatch()
    assert [idx for _, idx in out] == [0, 2, 0, 2]  # replica 1 has no slot


def test_least_loaded_picks_most_free_slots():
    reps = [FakeReplica(free=1), FakeReplica(free=3), FakeReplica(free=2)]
    router = RequestRouter(reps, policy="least_loaded", queue_cap=16)
    for i in range(3):
        router.submit(_req(i))
    out = router.dispatch()
    # 3 free wins, then the 2/2 tie goes to the lower index
    assert [idx for _, idx in out] == [1, 1, 2]


def test_plan_aware_prefers_best_tier_score():
    reps = [FakeReplica(free=2, score=0.0), FakeReplica(free=2, score=3.0),
            FakeReplica(free=2, score=2.0)]
    router = RequestRouter(reps, policy="plan_aware", queue_cap=16)
    for i in range(3):
        router.submit(_req(i))
    out = router.dispatch()
    assert [idx for _, idx in out] == [1, 1, 2]


def test_plan_aware_deadline_fit_overrides_tier_score():
    """A replica whose expected next-step time cannot finish the request
    before its deadline loses to a slower-scheduled one that can; replicas
    without the gauge (plain slot engines) are assumed to fit."""
    fast = FakeReplica(free=2, score=0.0)
    fast.expected_step_s = lambda: 1.0
    slow = FakeReplica(free=2, score=5.0)
    slow.expected_step_s = lambda: 100.0
    router = RequestRouter([slow, fast], policy="plan_aware", queue_cap=4)
    # mnt=2, deadline 50s out: slow projects 200s (misses), fast 2s (fits)
    router.submit(_req(1, arrival=0.0, deadline=50.0))
    assert [idx for _, idx in router.dispatch(now=0.0)] == [1]
    # no deadline: the tier score decides again, as before
    router.submit(_req(2))
    assert [idx for _, idx in router.dispatch(now=0.0)] == [0]


def test_unknown_policy_rejected():
    with pytest.raises(KeyError, match="unknown dispatch policy"):
        make_policy("best_effort")


# ---------------------------------------------------------------------------
# Traffic generator
# ---------------------------------------------------------------------------


def test_traffic_is_seed_deterministic_and_bounded():
    kw = dict(vocab_size=64, arrival_rate=0.5, tick_s=2.0, prompt_cap=10,
              deadline_ticks=8.0)
    a = TrafficGenerator(seed=7, **kw).trace(20)
    b = TrafficGenerator(seed=7, **kw).trace(20)
    c = TrafficGenerator(seed=8, **kw).trace(20)
    assert [(r.arrival_s, r.prompt, r.max_new_tokens) for r in a] == \
           [(r.arrival_s, r.prompt, r.max_new_tokens) for r in b]
    assert [r.prompt for r in a] != [r.prompt for r in c]
    for r in a:
        assert 1 <= len(r.prompt) <= 10
        assert r.deadline_s == pytest.approx(r.arrival_s + 16.0)
    arrivals = [r.arrival_s for r in a]
    assert arrivals == sorted(arrivals) and arrivals[0] > 0


def test_demand_tracker_ranks_hottest_first():
    d = DemandTracker(bucket_for=lambda n: 1 << (n - 1).bit_length())
    for plen, times in ((3, 5), (9, 2), (30, 1)):
        for _ in range(times):
            d.record(_req(0, plen=plen))
    assert d.hottest() == [(4, 5), (16, 2), (32, 1)]
    assert d.total == 8
    assert d.weighted(lambda b: 1.0 if b == 4 else 0.0) == pytest.approx(5 / 8)


# ---------------------------------------------------------------------------
# Real-engine fleet behaviour
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_lm():
    cfg = reduced(get_arch("minitron-4b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_cross_replica_upgrade_propagation(small_lm, tmp_path):
    """A publish triggered anywhere reaches every replica through the shared
    registry at its next step boundary — zero schedule divergence."""
    cfg, model, params = small_lm
    registry = ScheduleRegistry(str(tmp_path / "reg"))
    fleet = ServingFleet(cfg, model, params, replicas=2, slots=2, max_len=32,
                         registry=registry)
    service = fleet.services[DEFAULT_TARGET]
    plans = [r.engine.plan for r in fleet.replicas]
    assert all(p is not None and len(p) > 0 for p in plans)

    inst = next(u.instance for u in plans[0].uses
                if u.instance.class_id == "matmul")
    assert all(p.lookup(inst).tier == "default" for p in plans)
    upgraded = dataclasses.replace(default_schedule(inst), unroll=4,
                                   source="background")
    registry.publish([Record(instance=inst, schedule=upgraded,
                             seconds=service.runner.seconds(inst, upgraded),
                             model_id="background", target=service.target)])

    assert fleet.schedule_mismatches() == 0  # syncs every replica first
    for r in fleet.replicas:
        entry = r.engine.plan.lookup(inst)
        assert entry.tier == "exact" and entry.schedule == upgraded
        assert r.engine.replans >= 1
    fleet.close()


def test_heterogeneous_targets_keep_namespaces_apart(small_lm, tmp_path):
    """An upgrade published for one chip never leaks into another target's
    replicas; same-target propagation still holds."""
    cfg, model, params = small_lm
    registry = ScheduleRegistry(str(tmp_path / "reg"))
    fleet = ServingFleet(cfg, model, params, replicas=3, slots=2, max_len=32,
                         registry=registry,
                         targets=["tpu-v5e", "tpu-v5e", "tpu-v5e-lite"])
    assert sorted(fleet.services) == ["tpu-v5e", "tpu-v5e-lite"]
    service = fleet.services["tpu-v5e"]

    inst = next(u.instance for u in fleet.replicas[0].engine.plan.uses
                if u.instance.class_id == "matmul")
    upgraded = dataclasses.replace(default_schedule(inst), unroll=4,
                                   source="background")
    registry.publish([Record(instance=inst, schedule=upgraded,
                             seconds=service.runner.seconds(inst, upgraded),
                             model_id="background", target="tpu-v5e")])
    assert fleet.schedule_mismatches() == 0
    for r in fleet.replicas:
        tier = r.engine.plan.lookup(inst).tier
        assert tier == ("exact" if r.target == "tpu-v5e" else "default")
    fleet.close()


def test_demand_prefetch_orders_hottest_first(small_lm, tmp_path):
    """Prefetch promotes the hottest bucket's kernels to the front of the
    background queue: they are tuned (drained) before any cold shape."""
    cfg, model, params = small_lm
    registry = ScheduleRegistry(str(tmp_path / "reg"))
    tune_arch_registry(registry, "internvl2-26b", "train_4k", dp=16, tp=16,
                       total_trials=128, seed=0)
    fleet = ServingFleet(cfg, model, params, replicas=1, slots=2, max_len=32,
                         registry=registry, prefetch=True, prefetch_buckets=1)
    # hot bucket 4 (five arrivals), cold bucket 16 (one arrival)
    for uid in range(5):
        fleet.demand.record(_req(uid, plen=3))
    fleet.demand.record(_req(9, plen=9))
    fleet._prefetch_hot()

    svc = fleet.services[DEFAULT_TARGET]
    decode = {u.instance.workload_key()
              for u in fleet.replicas[0].decode_uses}
    hot = {u.instance.workload_key()
           for u in fleet.replicas[0].prefill_uses(4)}
    cold = {u.instance.workload_key()
            for u in fleet.replicas[0].prefill_uses(16)}
    pending = svc.pending_jobs()
    # plan construction queued everything at priority 0; prefetch promoted
    # the decode kernels (every request's demand) then the hot bucket's
    assert set(pending[:len(decode)]) == decode
    assert set(pending[len(decode):len(decode) + len(hot)]) == hot
    assert svc.stats()["prefetches"] >= len(hot)

    svc.drain(max_jobs=len(decode) + len(hot))
    remaining = set(svc.pending_jobs())
    assert hot.isdisjoint(remaining)       # hottest shapes tuned first...
    assert cold <= remaining               # ...cold ones still waiting
    assert svc.stats()["upgrades"] >= 1    # and upgrades actually landed
    fleet.close()


def test_fleet_serves_a_trace_end_to_end(small_lm, tmp_path):
    """Every submitted request is either completed or shed; queue bounds
    hold; the summary carries the acceptance metrics."""
    cfg, model, params = small_lm
    registry = ScheduleRegistry(str(tmp_path / "reg"))
    fleet = ServingFleet(cfg, model, params, replicas=2, slots=2, max_len=32,
                         registry=registry, policy="least_loaded",
                         queue_cap=4)
    gen = TrafficGenerator(seed=3, vocab_size=cfg.vocab_size,
                           arrival_rate=1.5, tick_s=fleet.tick_s,
                           short_lens=(3, 6), long_lens=(8, 12),
                           new_tokens=(2, 4), prompt_cap=12)
    summary = fleet.serve(gen.trace(10))
    assert summary["completed"] + summary["shed"] == 10
    assert summary["completed"] > 0
    assert summary["tokens"] > 0 and summary["throughput_tok_per_s"] > 0
    assert summary["queue_depth_max"] <= 4
    assert summary["latency_s"]["p50"] <= summary["latency_s"]["p95"] \
           <= summary["latency_s"]["p99"]
    assert summary["schedule_mismatches"] == 0
    for r in summary["replicas"]:
        assert r["requests"] >= 0 and "plan_tiers" in r
    fleet.close()


def test_paged_fleet_serves_a_trace_end_to_end(small_lm, tmp_path):
    """engine="paged" swaps the replica engine under the same serve loop:
    every request completes or sheds, plans propagate without divergence,
    and the paged gauges hold (zero padding, live page utilization)."""
    cfg, model, params = small_lm
    registry = ScheduleRegistry(str(tmp_path / "reg"))
    fleet = ServingFleet(cfg, model, params, replicas=2, slots=2, max_len=32,
                         engine="paged", decode_batch=4, page_size=4,
                         pool_pages=2 * 32 // 4 + 1, chunk=8,
                         registry=registry, policy="plan_aware", queue_cap=8)
    gen = TrafficGenerator(seed=3, vocab_size=cfg.vocab_size,
                           arrival_rate=1.5, tick_s=fleet.tick_s,
                           short_lens=(3, 6), long_lens=(8, 12),
                           new_tokens=(2, 4), prompt_cap=12)
    summary = fleet.serve(gen.trace(12))
    assert summary["engine"] == "paged"
    assert summary["completed"] + summary["shed"] == 12
    assert summary["completed"] > 0
    assert summary["schedule_mismatches"] == 0
    assert summary["padding_waste_frac"] == 0.0
    assert 0.0 < summary["kv_utilization_mean"] <= 1.0
    for r in summary["replicas"]:
        assert r["engine"] == "paged"
        assert r["preemptions"] >= 0
    fleet.close()


def test_fleet_rejects_unknown_engine(small_lm, tmp_path):
    cfg, model, params = small_lm
    with pytest.raises(ValueError, match="engine"):
        ServingFleet(cfg, model, params, replicas=1, slots=2, max_len=32,
                     engine="warp",
                     registry=ScheduleRegistry(str(tmp_path / "reg")))
