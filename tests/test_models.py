"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train step on CPU, asserting output shapes + no NaNs — plus
prefill/decode equivalence for every family (the serving contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch, reduced
from repro.launch import steps as steps_mod
from repro.models import build_model
from repro.models.common import count_params
from repro.optim.adamw import AdamWConfig


def _batch(cfg, rng, b=2, s=12, extra_tok=0):
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (b, s + extra_tok)), jnp.int32)
    batch = {"tokens": toks}
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(rng.normal(size=(b, cfg.encoder_seq, cfg.d_model)),
                                      jnp.float32)
    if cfg.vision_tokens:
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.vision_tokens, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch, rng):
    cfg = reduced(get_arch(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    assert count_params(params) > 0
    b, s = 2, 12
    batch = _batch(cfg, rng, b, s)

    logits, aux = model.forward(params, batch)
    seq = s + (cfg.vision_tokens or 0)
    assert logits.shape == (b, seq, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    step = steps_mod.make_train_step(model, AdamWConfig(peak_lr=1e-3, warmup_steps=1,
                                                        total_steps=10))
    opt = steps_mod.init_opt_state(params)
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params must actually change
    moved = jax.tree_util.tree_map(
        lambda a, b_: bool(jnp.any(a != b_)), params, params2)
    assert any(jax.tree_util.tree_leaves(moved)), f"{arch}: no param moved"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch, rng):
    cfg = reduced(get_arch(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    b, s = 2, 10
    full = _batch(cfg, rng, b, s, extra_tok=1)
    batch = dict(full)
    batch["tokens"] = full["tokens"][:, :s]

    logits_full, _ = model.forward(params, full, remat=False)
    lp, cache = model.prefill(params, batch, max_len=s + 4)
    off = cfg.vision_tokens if cfg.family != "audio" else 0
    np.testing.assert_allclose(lp, logits_full[:, off + s - 1, :], rtol=2e-4, atol=2e-4)
    ld, cache = model.decode_step(params, cache, full["tokens"][:, s])
    np.testing.assert_allclose(ld, logits_full[:, off + s, :], rtol=2e-4, atol=2e-4)


def test_grad_accumulation_matches_single_batch(rng):
    """grad_accum=2 over the split batch ≈ one step over the full batch."""
    cfg = reduced(get_arch("minitron-4b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    batch = _batch(cfg, rng, b=4, s=8)
    ocfg = AdamWConfig(peak_lr=1e-3, warmup_steps=1, total_steps=10)
    one = steps_mod.make_train_step(model, ocfg, grad_accum=1)
    acc = steps_mod.make_train_step(model, ocfg, grad_accum=2)
    p1, _, m1 = jax.jit(one)(params, steps_mod.init_opt_state(params), batch)
    p2, _, m2 = jax.jit(acc)(params, steps_mod.init_opt_state(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    l1 = jax.tree_util.tree_leaves(p1)
    l2 = jax.tree_util.tree_leaves(p2)
    for a, b_ in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b_, np.float32),
                                   rtol=5e-3, atol=5e-3)


def test_moe_aux_loss_nonzero(rng):
    cfg = reduced(get_arch("dbrx-132b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    _, metrics = model.loss_fn(params, _batch(cfg, rng))
    assert float(metrics["aux"]) > 0.0


def test_long_context_ring_cache_memory(rng):
    """Local-attention cache is window-sized, not context-sized."""
    cfg = reduced(get_arch("mixtral-8x22b"))  # all-SWA
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(1, 1 << 16))
    k_leaves = [l for p, l in jax.tree_util.tree_flatten_with_path(cache)[0]
                if "'k'" in jax.tree_util.keystr(p)]
    assert k_leaves and all(l.shape[-2] == cfg.window for l in k_leaves)
