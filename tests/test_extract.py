"""Kernel extraction: every arch×shape cell produces a coherent workload set."""
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_arch, get_shape, shape_applicable
from repro.core.cost_model import class_proportions, model_seconds
from repro.core.extract import extract_kernels
from repro.core.workload import KERNEL_CLASSES


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape", sorted(SHAPES))
def test_extraction_every_cell(arch, shape):
    cfg, sh = get_arch(arch), get_shape(shape)
    ok, _ = shape_applicable(cfg, sh)
    if not ok:
        pytest.skip("cell skipped by design")
    uses = extract_kernels(cfg, sh, dp=16, tp=16)
    assert uses, (arch, shape)
    for u in uses:
        assert u.instance.class_id in KERNEL_CLASSES
        assert u.use_count >= 1
        for _, v in u.instance.params:
            assert v >= 1
    assert model_seconds(uses) > 0
    props = class_proportions(uses)
    assert abs(sum(props.values()) - 1.0) < 1e-9


def test_use_counts_scale_with_depth():
    # gemma2: h·hd ≠ d_model, so wq does not dedup with wo (paper Table 1:
    # identical kernels merge into one task with a summed use count).
    cfg = get_arch("gemma2-2b")
    uses = extract_kernels(cfg, get_shape("train_4k"))
    by_tag = {u.tag: u for u in uses}
    assert by_tag["attn.wq"].use_count == cfg.n_layers
    assert by_tag["lm_head"].use_count == 1
    # stablelm: h·hd == d_model -> wq and wo are the same workload (merged)
    cfg2 = get_arch("stablelm-12b")
    uses2 = {u.tag: u for u in extract_kernels(cfg2, get_shape("train_4k"))}
    assert uses2["attn.wq"].use_count == 2 * cfg2.n_layers


def test_decode_shapes_are_single_token():
    cfg = get_arch("gemma2-2b")
    uses = extract_kernels(cfg, get_shape("decode_32k"))
    attn = [u for u in uses if u.instance.family == "attention"]
    assert attn and all(u.instance.extent("Q") == 1 for u in attn)
    assert any(u.instance.extent("KV") == 32768 for u in attn)


def test_tp_shrinks_local_extents():
    cfg = get_arch("stablelm-12b")
    full = {u.tag: u for u in extract_kernels(cfg, get_shape("train_4k"), tp=1)}
    shard = {u.tag: u for u in extract_kernels(cfg, get_shape("train_4k"), tp=16)}
    assert shard["mlp.up"].instance.extent("N") * 16 == full["mlp.up"].instance.extent("N")


def test_attention_free_arch_has_no_attention_kernels():
    uses = extract_kernels(get_arch("rwkv6-1.6b"), get_shape("train_4k"))
    assert all(u.instance.family != "attention" for u in uses)
    assert any(u.instance.class_id == "rwkv6_scan" for u in uses)


def test_class_overlap_across_archs():
    """Transfer-tuning needs shared classes between archs (paper Table 2)."""
    a = {u.instance.class_id for u in extract_kernels(get_arch("gemma2-2b"), get_shape("train_4k"))}
    b = {u.instance.class_id for u in extract_kernels(get_arch("minitron-4b"), get_shape("train_4k"))}
    assert a & b  # e.g. matmul, matmul_lmhead-family


def test_cnn_workloads_match_paper_table1():
    """Paper §4.3 workloads: ResNet18's census matches Table 1 (18 kernels,
    6 classes); the donor heuristic input is well-formed for all 4 CNNs."""
    from repro.core.cnn_workloads import cnn_uses

    r18 = cnn_uses("resnet18")
    assert len(r18) == 18
    classes = {u.instance.class_id for u in r18}
    assert classes == {"conv2d_add", "conv2d_bias_relu", "conv2d_bias_add_relu",
                       "max_pool2d", "global_avg_pool2d", "dense_add"}
    assert sum(u.use_count for u in r18) == 23  # Table 1 Use Count total
    for name in ("resnet50", "alexnet", "vgg16"):
        uses = cnn_uses(name)
        assert uses and all(u.instance.extent("M") > 0 for u in uses)
    # class overlap with resnet50 (what makes the paper's transfer work)
    r50 = {u.instance.class_id for u in cnn_uses("resnet50")}
    assert {"conv2d_bias_relu", "conv2d_add", "conv2d_bias_add_relu"} <= (classes & r50)
