"""Closed-loop observability: SLOs, critical-path profiler, ledger, advisor.

Unit-level pins for the PR-10 loop: declarative :class:`SLO` validation and
per-kind badness, :class:`SLOMonitor` multi-window burn-rate math on a fake
metrics window, :func:`profiler.critical_path` attribution over a hand-built
trace, :class:`SpeedupLedger` aggregation identities, and
:class:`TuningAdvisor` ranking (donor-prior headroom, exhaustion skips,
deterministic order).  The end-to-end closed loop — advisor-fed prefetch
beating demand-order tuning to SLO compliance on a live fleet — is gated by
``benchmarks/bench_slo.py``.
"""
import dataclasses

import pytest

from repro.core.autoscheduler import tune_kernel
from repro.core.database import Record, ScheduleDB
from repro.core.runner import AnalyticalRunner
from repro.core.workload import KernelInstance, KernelUse
from repro.fleet.advisor import TuningAdvisor
from repro.obs import SLO, KINDS, SLOMonitor, SpeedupLedger, Tracer
from repro.obs.export import _records
from repro.obs.ledger import LedgerEntry
from repro.obs.profiler import critical_path, live_workload_seconds, span_cell


# ---------------------------------------------------------------------------
# Fakes shared across the module
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FakeRequest:
    """The outcome fields SLO.is_bad and SLOMonitor._seen consume."""

    arrival_s: float = 0.0
    finished_s: float = None
    latency_s: float = None
    prefill_done_s: float = None
    deadline_s: float = None
    shed: bool = False
    shed_s: float = None


def done(fin, lat, **kw):
    return FakeRequest(arrival_s=fin - lat, finished_s=fin, latency_s=lat,
                       **kw)


class FakeFleetMetrics:
    def __init__(self):
        self.completed = []
        self.shed = []


# ---------------------------------------------------------------------------
# SLO declaration + per-kind badness
# ---------------------------------------------------------------------------


def test_slo_validation():
    with pytest.raises(ValueError, match="unknown SLO kind"):
        SLO("x", "throughput")
    for bad_obj in (0.0, 1.0, -0.5, 1.5):
        with pytest.raises(ValueError, match="objective"):
            SLO("x", "shed", objective=bad_obj)
    for kind in ("latency", "ttft"):
        with pytest.raises(ValueError, match="threshold_s"):
            SLO("x", kind)
    with pytest.raises(ValueError, match="fast_windows"):
        SLO("x", "shed", fast_windows=0)
    with pytest.raises(ValueError, match="fast_windows"):
        SLO("x", "shed", fast_windows=3, slow_windows=2)
    assert SLO("x", "shed", objective=0.98).budget == pytest.approx(0.02)


def test_slo_is_bad_per_kind():
    lat = SLO("l", "latency", threshold_s=5.0)
    assert lat.is_bad(done(10.0, 6.0))
    assert not lat.is_bad(done(10.0, 5.0))          # boundary is good

    ttft = SLO("t", "ttft", threshold_s=2.0)
    assert ttft.is_bad(done(10.0, 4.0, prefill_done_s=9.0))   # arrival 6 -> 3
    assert not ttft.is_bad(done(10.0, 4.0, prefill_done_s=7.5))
    # No prefill mark: first token falls back to the finish instant.
    assert ttft.is_bad(done(10.0, 3.0))
    assert not ttft.is_bad(done(10.0, 1.5))

    shed = SLO("s", "shed", objective=0.98)
    assert not shed.is_bad(done(10.0, 100.0))        # slow completion is good
    ddl = SLO("d", "deadline")
    assert ddl.is_bad(done(10.0, 1.0, deadline_s=9.0))
    assert not ddl.is_bad(done(10.0, 1.0, deadline_s=11.0))
    assert not ddl.is_bad(done(10.0, 1.0))           # no deadline -> good

    dropped = FakeRequest(shed=True, shed_s=3.0)
    for slo in (lat, ttft, shed, ddl):               # shed is bad everywhere
        assert slo.is_bad(dropped)
    assert len(KINDS) == 4


# ---------------------------------------------------------------------------
# SLOMonitor burn-rate math and alert lifecycle
# ---------------------------------------------------------------------------


def _monitor(slos, window_s=10.0, tracer=None):
    fm = FakeFleetMetrics()
    return SLOMonitor(slos, fm, window_s=window_s, tracer=tracer), fm


def test_monitor_rejects_bad_config():
    with pytest.raises(ValueError, match="window_s"):
        SLOMonitor([], FakeFleetMetrics(), window_s=0.0)
    with pytest.raises(ValueError, match="duplicate"):
        SLOMonitor([SLO("a", "shed"), SLO("a", "deadline")],
                   FakeFleetMetrics(), window_s=1.0)


def test_burn_rate_math_and_empty_window():
    slo = SLO("p95", "latency", objective=0.8, threshold_s=5.0)
    mon, fm = _monitor([slo])
    fm.completed += [done(2.0, 3.0), done(4.0, 7.0), done(6.0, 7.0),
                     done(8.0, 7.0)]
    # bad 3 of 4 seen -> bad fraction .75 over budget .2 -> burn 3.75
    assert mon.burn_rate(slo, 0.0, 10.0) == (pytest.approx(3.75), 4)
    # Window binning is [t0, t1): the t=8 finisher is outside [0, 8).
    assert mon.burn_rate(slo, 0.0, 8.0)[1] == 3
    # An empty window burns 0 — a quiet fleet never alerts.
    assert mon.burn_rate(slo, 20.0, 30.0) == (0.0, 0)


def test_sheds_count_against_every_kind():
    slo = SLO("shed", "shed", objective=0.9)
    mon, fm = _monitor([slo])
    fm.completed.append(done(5.0, 1.0))
    fm.shed.append(FakeRequest(shed=True, shed_s=6.0))
    burn, seen = mon.burn_rate(slo, 0.0, 10.0)
    assert seen == 2 and burn == pytest.approx((1 / 2) / 0.1)


def test_alert_needs_both_windows():
    """A fast-window blip that the slow window dilutes must not alert."""
    slo = SLO("p", "latency", objective=0.5, threshold_s=5.0,
              fast_windows=1, slow_windows=2)
    mon, fm = _monitor([slo])
    fm.completed += [done(t, 1.0) for t in (1.0, 3.0, 5.0, 7.0)]  # good burst
    fm.completed += [done(12.0, 9.0), done(14.0, 9.0)]            # bad blip
    (st,) = mon.evaluate(20.0)
    assert st.burn_fast == pytest.approx(2.0)          # [10, 20): all bad
    assert st.burn_slow == pytest.approx((2 / 6) / 0.5)  # [0, 20): diluted
    assert not st.alerting


def test_alert_clear_lifecycle_events_and_summary():
    tr = Tracer(clock=lambda: 0.0)
    slo = SLO("p95", "latency", objective=0.8, threshold_s=5.0,
              fast_windows=1, slow_windows=2)
    mon, fm = _monitor([slo], tracer=tr)
    fm.completed += [done(t, 9.0) for t in (2.0, 4.0, 6.0)]
    (st,) = mon.evaluate(10.0)
    assert st.alerting and st.changed and st.seen_fast == 3
    (st2,) = mon.evaluate(20.0)          # fast [10,20) empty -> burn 0
    assert not st2.alerting and st2.changed
    (st3,) = mon.evaluate(30.0)
    assert not st3.alerting and not st3.changed

    assert mon.metrics.get("slo.alerts").value == 1
    assert mon.metrics.get("slo.clears").value == 1
    assert mon.metrics.get("slo.p95.alerting").samples == [
        (10.0, 1.0), (20.0, 0.0), (30.0, 0.0)]
    names = [e.name for e in tr.events]
    assert names == ["slo_alert", "slo_clear"]
    assert tr.events[0].attrs["slo"] == "p95"

    assert mon.alerting() == []
    assert mon.last_alert_end() == 10.0
    s = mon.summary()["p95"]
    assert s["evaluations"] == 3 and s["alerting_windows"] == 1
    assert s["alert_share"] == pytest.approx(1 / 3)
    assert not s["alerting_now"] and s["last_alert_end_s"] == 10.0


def test_never_alerted_reads_zero():
    mon, _ = _monitor([SLO("s", "shed")])
    mon.evaluate(10.0)
    assert mon.last_alert_end() == 0.0
    assert mon.summary()["s"]["alerting_windows"] == 0


# ---------------------------------------------------------------------------
# Critical-path profiler
# ---------------------------------------------------------------------------


def test_span_cell_mapping():
    def rec(name, **attrs):
        return {"name": name, "cat": None, "attrs": attrs}

    assert span_cell(rec("prefill", bucket=16)) == ("prefill:16", 1.0)
    assert span_cell(rec("chunk", len=8)) == ("prefill:8", 1.0)
    assert span_cell(rec("decode_step")) == ("decode", 1.0)
    assert span_cell(rec("decode")) == ("decode", 1.0)
    assert span_cell(rec("verify")) == ("verify", 1.0)
    assert span_cell(rec("draft_burst", steps=4)) == ("draft_decode", 4.0)
    assert span_cell(rec("draft_sync", len=16)) == ("draft_sync:16", 1.0)
    assert span_cell(rec("step")) is None            # container, not a cell
    assert span_cell({"name": "prefill", "cat": "request",
                      "attrs": {}}) is None          # async phase span


def _profiled_tracer():
    """Two finished requests + cell spans + workload maps on one replica."""
    tr = Tracer(clock=lambda: 0.0)
    for uid, (arr, adm, pd, fin) in {"1": (0.0, 1.0, 2.0, 6.0),
                                     "2": (1.0, 1.5, 3.0, 9.0)}.items():
        tr.add_async_span("request", "replica-0", arr, fin, "request", uid,
                          uid=int(uid))
        tr.add_async_span("queue", "replica-0", arr, adm, "request", uid)
        tr.add_async_span("prefill", "replica-0", adm, pd, "request", uid)
        tr.add_async_span("decode", "replica-0", pd, fin, "request", uid)
    tr.event("cell_workloads", "replica-0", t=0.0, cell="prefill:8",
             workloads=[["wkA", 0.2], ["wkC", 0.3]])
    tr.event("cell_workloads", "replica-0", t=0.0, cell="verify",
             workloads=[["wkA", 0.1]])
    tr.event("cell_workloads", "replica-0", t=0.0, cell="draft_decode",
             workloads=[["wkB", 0.05]])
    # Plan generation flip: verify re-priced before the second execution.
    tr.event("cell_workloads", "replica-0", t=2.2, cell="verify",
             workloads=[["wkA", 0.4]])
    tr.add_span("chunk", "replica-0", 1.0, 2.0, len=8)
    tr.add_span("verify", "replica-0", 2.0, 2.5)
    tr.add_span("draft_burst", "replica-0", 2.5, 3.0, steps=4)
    tr.add_span("verify", "replica-0", 3.0, 3.2)
    return tr


def test_critical_path_attribution():
    cp = critical_path(_records(_profiled_tracer()))
    assert cp["requests"] == 2
    # Latencies [6, 8]: p50 interpolates, p95 via the shared percentile.
    assert cp["latency_s"]["p50"] == pytest.approx(7.0)
    assert cp["segments"]["queue"] == pytest.approx(1.0 + 0.5)
    assert cp["segments"]["prefill"] == pytest.approx(1.0 + 1.5)
    assert cp["segments"]["decode"] == pytest.approx(4.0 + 6.0)

    assert cp["by_cell"]["prefill:8"] == {"seconds": pytest.approx(1.0),
                                          "executions": 1.0}
    assert cp["by_cell"]["verify"]["executions"] == 2.0
    assert cp["by_cell"]["draft_decode"]["executions"] == 4.0

    # First verify execution priced by the t=0 map, second by the t=2.2
    # map (latest emission at or before span start); draft_burst multiplies
    # by its step count.
    assert cp["by_workload"]["wkA"] == pytest.approx(0.2 + 0.1 + 0.4)
    assert cp["by_workload"]["wkB"] == pytest.approx(4 * 0.05)
    assert cp["by_workload"]["wkC"] == pytest.approx(0.3)
    assert cp["attributed_frac"] == 1.0


def test_critical_path_unmapped_cells_lower_attribution():
    tr = Tracer(clock=lambda: 0.0)
    tr.add_span("verify", "replica-0", 0.0, 1.0)     # no cell_workloads map
    cp = critical_path(_records(tr))
    assert cp["attributed_frac"] == 0.0 and cp["by_workload"] == {}
    assert cp["by_cell"]["verify"]["seconds"] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# Fake replica shared by the live profiler / ledger / advisor tests
# ---------------------------------------------------------------------------

INST_A = KernelInstance.make("matmul", M=128, N=128, K=128)
INST_B = KernelInstance.make("matmul", M=160, N=160, K=160)


class FakeResolution:
    def __init__(self, schedule, tier, source_model):
        self.schedule = schedule
        self.tier = tier
        self.source_model = source_model


class FakeReplica:
    """Cell counters + plan-derived costs: the live profiler's whole input."""

    target = "tpu-v5e"
    service = None

    def __init__(self, counts, uses, served_s, untuned_s):
        self.cell_counts = dict(counts)
        self._uses = uses                 # cell -> [KernelUse]
        self._served = served_s           # workload_key -> seconds
        self._untuned = untuned_s

    def cell_uses(self, cell):
        return self._uses.get(cell, [])

    def cell_workload_seconds(self, cell):
        return [(u, u.use_count * self._served[u.instance.workload_key()])
                for u in self.cell_uses(cell)]

    def use_resolution(self, instance):
        return FakeResolution(object(), "transfer", "donor_a")

    def use_seconds(self, instance, schedule):
        key = instance.workload_key()
        return self._untuned[key] if schedule is None else self._served[key]


def _fake_replica():
    return FakeReplica(
        counts={"verify": 3, "draft_decode": 10},
        uses={"verify": [KernelUse(INST_A, use_count=2)],
              "draft_decode": [KernelUse(INST_B, use_count=1)]},
        served_s={INST_A.workload_key(): 1.0, INST_B.workload_key(): 0.25},
        untuned_s={INST_A.workload_key(): 2.0, INST_B.workload_key(): 0.25})


def test_live_workload_seconds():
    live = live_workload_seconds([_fake_replica()])
    a = live[(INST_A.workload_key(), "tpu-v5e")]
    b = live[(INST_B.workload_key(), "tpu-v5e")]
    assert a["seconds"] == pytest.approx(3 * 2 * 1.0)   # execs x use_count x s
    assert b["seconds"] == pytest.approx(10 * 1 * 0.25)
    assert a["instance"] is INST_A


# ---------------------------------------------------------------------------
# Speedup ledger
# ---------------------------------------------------------------------------


def test_ledger_entry_properties():
    e = LedgerEntry(key="k", target="t", class_id="c", tier="transfer",
                    source_model="d", untuned_s=2.0, served_s=1.0,
                    best_s=0.8, weight=4.0)
    assert e.realized_speedup == pytest.approx(2.0)
    assert e.attainable_speedup == pytest.approx(2.5)
    assert e.headroom_s == pytest.approx(0.2)
    e2 = dataclasses.replace(e, best_s=None)
    assert e2.attainable_speedup == pytest.approx(2.0)  # falls back to served
    assert e2.headroom_s == 0.0


def test_ledger_update_from_replicas_and_gauges():
    led = SpeedupLedger()
    agg = led.update([_fake_replica()], now=7.0)
    a = led.entries[(INST_A.workload_key(), "tpu-v5e")]
    assert a.weight == 3 * 2 and a.tier == "transfer"
    assert a.untuned_s == 2.0 and a.served_s == 1.0 and a.best_s is None
    # decode is always included, but with no uses it adds no entry.
    assert agg["workloads"] == 2 and agg["tuned_workloads"] == 0
    un = 6 * 2.0 + 10 * 0.25
    sv = 6 * 1.0 + 10 * 0.25
    assert agg["realized_speedup"] == pytest.approx(un / sv)
    assert agg["realized_fraction"] == 1.0   # best unknown -> served is best
    g = led.metrics.get("ledger.realized_speedup")
    assert g.samples == [(7.0, pytest.approx(un / sv))]


def test_ledger_aggregate_weight_fallback_and_speedup_for():
    led = SpeedupLedger()
    led.entries = {
        ("a", "t"): LedgerEntry("a", "t", "c", "exact", "d", 2.0, 1.0, 1.0),
        ("b", "t"): LedgerEntry("b", "t", "c", "default", "", 1.0, 1.0, 0.5),
    }
    agg = led.aggregates()                 # all weights 0 -> uniform weights
    assert agg["realized_speedup"] == pytest.approx(3.0 / 2.0)
    assert agg["attainable_speedup"] == pytest.approx(3.0 / 1.5)
    assert agg["realized_fraction"] == pytest.approx(1.5 / 2.0)
    assert agg["tiers"] == {"exact": 1, "default": 1}

    uses = [KernelUse(INST_A, use_count=3)]
    led.entries = {(INST_A.workload_key(), "t"):
                   LedgerEntry(INST_A.workload_key(), "t", "c", "transfer",
                               "d", 2.0, 1.0, 0.5)}
    s = led.speedup_for(uses, "t")
    assert s["realized_speedup"] == pytest.approx(2.0)
    assert s["attainable_speedup"] == pytest.approx(4.0)
    assert s["missing"] == []
    s2 = led.speedup_for([KernelUse(INST_B)], "t")
    assert s2["missing"] == [INST_B.workload_key()]
    assert s2["realized_speedup"] == 1.0


def test_ledger_top_headroom_orders_by_weighted_headroom():
    led = SpeedupLedger()
    led.entries = {
        ("small", "t"): LedgerEntry("small", "t", "c", "transfer", "d",
                                    2.0, 1.0, 0.5, weight=1.0),
        ("big", "t"): LedgerEntry("big", "t", "c", "transfer", "d",
                                  2.0, 1.0, 0.9, weight=100.0),
    }
    assert [e.key for e in led.top_headroom(2)] == ["big", "small"]
    top = led.summary()["top_headroom"]
    assert top[0]["key"] == "big"
    assert top[0]["headroom_s"] == pytest.approx(100.0 * 0.1)


# ---------------------------------------------------------------------------
# Tuning advisor
# ---------------------------------------------------------------------------


class FakeSnapshot:
    def __init__(self, db):
        self._db = db

    def db(self, mode=None):
        return self._db


class FakeRegistry:
    def __init__(self, db):
        self._db = db

    def snapshot(self):
        return FakeSnapshot(self._db)


class FakeService:
    target = "tpu-v5e"
    donor_target = "tpu-v5e"

    def __init__(self, db, attempted=()):
        self.registry = FakeRegistry(db)
        self.runner = AnalyticalRunner()
        self._attempted = set(attempted)

    def donor_models(self, db):
        return ["donor_a"]

    def attempted(self, key):
        return key in self._attempted


class FakeFleet:
    def __init__(self, replicas, services):
        self.replicas = replicas
        self.services = services

    def live_replicas(self):
        return self.replicas


@pytest.fixture(scope="module")
def donor_schedule():
    return tune_kernel(INST_A, trials=16, seed=0).best


def _db_with(*records):
    db = ScheduleDB()
    for r in records:
        db.add(r)
    return db


def test_class_headroom_prior_from_donor_pool(donor_schedule):
    runner = AnalyticalRunner()
    donor_inst = KernelInstance.make("matmul", M=192, N=192, K=192)
    untuned = runner.seconds(donor_inst, None)
    db = _db_with(Record(donor_inst, donor_schedule, 0.25 * untuned,
                         "donor_a"))
    svc = FakeService(db)
    adv = TuningAdvisor()
    # Best donor of the class runs at .25x untuned -> 75% headroom prior.
    assert adv.class_headroom(INST_A, svc, db) == pytest.approx(0.75)
    # Cached per (class, target): mutating the db does not change the prior.
    db2 = _db_with()
    assert adv.class_headroom(INST_A, svc, db2) == pytest.approx(0.75)


def test_class_headroom_default_and_clamp(donor_schedule):
    adv = TuningAdvisor(default_headroom=0.4, min_headroom=0.1)
    svc = FakeService(_db_with())
    assert adv.class_headroom(INST_A, svc,
                              svc.registry.snapshot().db()) == 0.4
    # A donor pool with no headroom clamps to the anti-starvation floor.
    runner = AnalyticalRunner()
    donor_inst = KernelInstance.make("matmul", M=192, N=192, K=192)
    untuned = runner.seconds(donor_inst, None)
    db = _db_with(Record(donor_inst, donor_schedule, untuned, "donor_a"))
    adv2 = TuningAdvisor(min_headroom=0.1)
    assert adv2.class_headroom(INST_A, FakeService(db), db) == \
        pytest.approx(0.1)


def test_rank_skips_exhausted_and_sorts_deterministically(donor_schedule):
    rep = _fake_replica()
    inst_c = KernelInstance.make("matmul", M=96, N=96, K=96)
    rep.cell_counts["prefill:8"] = 1
    rep._uses["prefill:8"] = [KernelUse(inst_c, use_count=1)]
    rep._served[inst_c.workload_key()] = 6.0
    rep._untuned[inst_c.workload_key()] = 6.0

    svc = FakeService(_db_with(), attempted=[inst_c.workload_key()])
    adv = TuningAdvisor(default_headroom=0.5)
    fleet = FakeFleet([rep], {"tpu-v5e": svc})
    ranked = adv.rank(fleet)
    # inst_c is attempted -> skipped; A (6s) outranks B (2.5s), same prior.
    assert [r.instance.workload_key() for r in ranked] == \
        [INST_A.workload_key(), INST_B.workload_key()]
    assert ranked[0].priority == pytest.approx(6.0 * 0.5)
    assert ranked[0].critical_s == pytest.approx(6.0)

    # Publishing an exact record for A exhausts it too.
    svc2 = FakeService(_db_with(Record(INST_A, donor_schedule, 0.5,
                                       "target_model")))
    ranked2 = TuningAdvisor().rank(FakeFleet([rep], {"tpu-v5e": svc2}))
    assert INST_A.workload_key() not in \
        [r.instance.workload_key() for r in ranked2]


def test_rank_tie_breaks_by_workload_key(donor_schedule):
    rep = FakeReplica(
        counts={"verify": 1},
        uses={"verify": [KernelUse(INST_A), KernelUse(INST_B)]},
        served_s={INST_A.workload_key(): 1.0, INST_B.workload_key(): 1.0},
        untuned_s={INST_A.workload_key(): 1.0, INST_B.workload_key(): 1.0})
    ranked = TuningAdvisor().rank(
        FakeFleet([rep], {"tpu-v5e": FakeService(_db_with())}))
    keys = [r.instance.workload_key() for r in ranked]
    assert keys == sorted(keys)            # equal priority -> key order
