"""Shared test configuration.

NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device; only
the dry-run entrypoint (and the subprocess distribution tests) force host
platform device counts.
"""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
