"""Cost-model behaviour: determinism, schedule sensitivity, validity."""
import pytest

from repro.core.cost_model import evaluate, kernel_seconds, measure, model_seconds
from repro.core.schedule import Schedule, ScheduleInvalid, concretize, default_schedule
from repro.core.workload import KernelInstance, KernelUse
from repro.hw.specs import TPU_V5E


def g(m=1024, n=1024, k=1024):
    return KernelInstance.make("matmul", M=m, N=n, K=k)


def test_measure_deterministic_given_seed():
    sched = Schedule.make("matmul", {"M": 128, "N": 256, "K": 128})
    a = measure(g(), sched, seed=7)
    b = measure(g(), sched, seed=7)
    assert a.seconds == b.seconds
    c = measure(g(), sched, seed=8)
    assert c.seconds != a.seconds  # noise varies with seed


def test_noise_zero_matches_evaluate():
    sched = Schedule.make("matmul", {"M": 128, "N": 256, "K": 128})
    m = measure(g(), sched, noise_sigma=0.0)
    assert m.seconds == pytest.approx(evaluate(concretize(sched, g())).seconds)


def test_bigger_tiles_reduce_hbm_traffic():
    """Reuse grows with tile size: the memory term must reflect it."""
    small = evaluate(concretize(Schedule.make("matmul", {"M": 8, "N": 128, "K": 128}), g()))
    big = evaluate(concretize(Schedule.make("matmul", {"M": 256, "N": 256, "K": 128}), g()))
    assert big.hbm_bytes < small.hbm_bytes


def test_order_changes_traffic():
    """Reorder (paper primitive) must change the modeled HBM bytes."""
    t = {"M": 64, "N": 128, "K": 128}
    a = evaluate(concretize(Schedule.make("matmul", t, order=("M", "N", "K")), g()))
    b = evaluate(concretize(Schedule.make("matmul", t, order=("M", "K", "N")), g()))
    assert a.hbm_bytes != b.hbm_bytes


def test_vmem_overflow_invalid():
    sched = Schedule.make("matmul", {"M": 4096, "N": 4096, "K": 4096})
    inst = g(4096, 4096, 4096)
    with pytest.raises(ScheduleInvalid):
        evaluate(concretize(sched, inst))
    assert not measure(inst, sched).valid


def test_parallel_reduction_invalid():
    sched = Schedule.make("matmul", {"M": 128, "N": 128, "K": 128},
                          order=("K", "M", "N"), parallel=1)
    with pytest.raises(ScheduleInvalid):
        evaluate(concretize(sched, g()))


def test_alignment_penalty():
    """Misaligned (non-128) N tiles waste MXU lanes -> slower compute term."""
    aligned = evaluate(concretize(Schedule.make("matmul", {"M": 128, "N": 128, "K": 128}), g()))
    odd = KernelInstance.make("matmul", M=1024, N=1000, K=1024)
    mis = evaluate(concretize(Schedule.make("matmul", {"M": 128, "N": 8, "K": 128}),
                              odd, mode="adaptive"))
    assert mis.compute_s > aligned.compute_s


def test_roofline_floor():
    """No schedule may beat the ideal roofline for its kernel."""
    inst = g()
    ideal = max(2 * 1024**3 / TPU_V5E.peak_flops_bf16,
                3 * 1024 * 1024 * 2 / TPU_V5E.hbm_bandwidth)
    for tiles in ({"M": 128, "N": 128, "K": 128}, {"M": 512, "N": 512, "K": 128},
                  {"M": 1024, "N": 256, "K": 512}):
        bd = evaluate(concretize(Schedule.make("matmul", tiles), inst))
        assert bd.seconds >= ideal * 0.99


def test_model_seconds_uses_counts():
    u = [KernelUse(g(), use_count=3)]
    assert model_seconds(u) == pytest.approx(3 * kernel_seconds(g()))


def test_attention_window_cheaper():
    full = KernelInstance.make("flash_attention_causal", Q=4096, KV=4096, H=8, D=128, B=1)
    swa = KernelInstance.make("flash_attention_swa", Q=4096, KV=4096, H=8, D=128, B=1,
                              window=512)
    s_full = kernel_seconds(full)
    s_swa = kernel_seconds(swa)
    assert s_swa < s_full


def test_scan_families():
    rw = KernelInstance.make("rwkv6_scan", T=4096, C=2048, D=64, B=4)
    rg = KernelInstance.make("rglru_scan", T=4096, C=2560, B=4)
    assert kernel_seconds(rw) > 0 and kernel_seconds(rg) > 0
