"""Checkpoint manager: roundtrip, async, atomicity, retention, reshard."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(seed=0):
    r = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(r.normal(size=(4, 8)), jnp.float32),
                   "b": jnp.asarray(r.normal(size=8), jnp.bfloat16)},
        "opt": {"step": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip(tmp_path):
    m = CheckpointManager(str(tmp_path))
    tree = _tree()
    m.save(5, tree)
    step, restored = m.restore(jax.eval_shape(lambda: tree))
    assert step == 5
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_async_save(tmp_path):
    m = CheckpointManager(str(tmp_path))
    m.save(1, _tree(), blocking=False)
    m.wait()
    assert m.latest_step() == 1


def test_retention(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        m.save(s, _tree(s))
    assert m.all_steps() == [3, 4]


def test_no_tmp_dirs_left(tmp_path):
    m = CheckpointManager(str(tmp_path))
    m.save(9, _tree())
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_restore_missing_raises(tmp_path):
    m = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        m.restore({})


def test_shape_mismatch_raises(tmp_path):
    m = CheckpointManager(str(tmp_path))
    m.save(1, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        m.restore({"w": jax.ShapeDtypeStruct((3, 3), jnp.float32)})


def test_restore_with_shardings(tmp_path):
    """Reshard-on-restore: device_put with explicit (single-device) sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((1,), ("data",))
    m = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(8, dtype=jnp.float32)}
    m.save(2, tree)
    sh = {"w": NamedSharding(mesh, P("data"))}
    step, restored = m.restore(jax.eval_shape(lambda: tree), shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(restored["w"], tree["w"])
