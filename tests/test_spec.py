"""Speculative decoding: acceptance math, draft-then-verify exactness, the
verify workload's extraction geometry, and the fleet's acceptance-aware
routing surfaces."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ShapeConfig, get_arch, reduced
from repro.core.extract import extract_kernels
from repro.core.resolution import spec_verify_uses
from repro.fleet import AcceptanceTracker, ServingFleet, TrafficGenerator
from repro.fleet.traffic import load_trace, save_trace
from repro.models import build_model
from repro.serving import (
    PagedServingEngine,
    expected_committed_tokens,
    make_self_draft,
    spec_exact_reason,
    spec_gain,
)


@pytest.fixture(scope="module")
def small_lm():
    cfg = reduced(get_arch("minitron-4b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def drafted(small_lm):
    """(target_model, damped_target_params, draft_model, draft_params) with
    damp=0: the damped target computes exactly the draft's function, so
    greedy proposals always match (acceptance rate 1)."""
    cfg, model, params = small_lm
    dcfg, dparams, tparams = make_self_draft(cfg, params, keep_layers=1,
                                             damp=0.0)
    return model, tparams, build_model(dcfg), dparams


def _prompts(cfg, lens=(3, 11, 6)):
    rng = np.random.default_rng(5)
    return [[int(t) for t in rng.integers(1, cfg.vocab_size, size=n)]
            for n in lens]


def _run(model, params, prompts, *, mnt=8, **kw):
    kw.setdefault("decode_batch", len(prompts))
    kw.setdefault("max_ctx", 32)
    kw.setdefault("page_size", 4)
    kw.setdefault("chunk", 8)
    eng = PagedServingEngine(model, params, **kw)
    reqs = [eng.add_request(p, max_new_tokens=mnt) for p in prompts]
    eng.run_to_completion(max_steps=512)
    assert all(r.done for r in reqs)
    return reqs, eng


# ---------------------------------------------------------------------------
# Acceptance math (pure)
# ---------------------------------------------------------------------------


def test_expected_committed_tokens():
    assert expected_committed_tokens(0, 0.5) == 1.0
    assert expected_committed_tokens(4, 0.0) == 1.0   # all-reject: correction
    assert expected_committed_tokens(4, 1.0) == 5.0   # all-accept: k+1
    # geometric series: 1 + a + a^2 for k=2
    assert expected_committed_tokens(2, 0.5) == pytest.approx(1.75)
    # monotone in both k and alpha
    assert (expected_committed_tokens(4, 0.8)
            > expected_committed_tokens(2, 0.8)
            > expected_committed_tokens(2, 0.4))


def test_spec_gain_break_even():
    kw = dict(draft_cost_s=0.1, verify_cost_s=1.0, decode_cost_s=1.0)
    assert spec_gain(0, 0.9, **kw) == 1.0             # k=0: no speculation
    assert spec_gain(4, 1.0, **kw) == pytest.approx(5.0 / 1.5)
    assert spec_gain(4, 0.0, **kw) == pytest.approx(1.0 / 1.5)  # pure loss
    # free draft, all-reject: burst == one decode == one token -> break even
    assert spec_gain(3, 0.0, draft_cost_s=0.0, verify_cost_s=1.0,
                     decode_cost_s=1.0) == pytest.approx(1.0)


def test_spec_exact_reason_gates_families():
    assert spec_exact_reason(get_arch("minitron-4b")) == ""
    assert "recurrent" in spec_exact_reason(get_arch("recurrentgemma-2b"))
    assert "ring" in spec_exact_reason(get_arch("mixtral-8x22b"))


# ---------------------------------------------------------------------------
# Draft-then-verify on the paged engine: bit-exactness in every regime
# ---------------------------------------------------------------------------


def test_all_accept_commits_k_plus_one_and_matches_plain(small_lm, drafted):
    """damp=0 makes the draft identical to the damped target: every draft
    token is accepted, bursts commit k+1, and the stream is bit-exact vs
    the plain paged engine on the same params."""
    cfg, _, _ = small_lm
    model, tparams, draft, dparams = drafted
    prompts = _prompts(cfg)
    plain, _ = _run(model, tparams, prompts)
    spec, eng = _run(model, tparams, prompts, draft_model=draft,
                     draft_params=dparams, spec_k=3)
    for pr, sr in zip(plain, spec):
        assert pr.generated == sr.generated
    assert eng.spec_bursts > 0
    assert eng.spec_accepted == eng.spec_proposed  # alpha == 1
    # every burst commits its k accepted drafts + the bonus token, except a
    # final burst truncated by max_new_tokens
    events = eng.drain_spec_events()
    assert all(1 <= ev["committed"] <= 4 for ev in events)
    assert sum(ev["committed"] for ev in events) == eng.spec_committed


def test_all_reject_commits_exactly_one_and_matches_plain(small_lm, drafted):
    """Adversarial head: the draft's lm head is the target's with columns
    rolled by one, so its greedy proposal is always (target greedy + 1) mod
    V — never accepted.  Every burst must commit exactly 1 token (the
    correction), and the stream stays bit-exact vs plain decode."""
    cfg, _, _ = small_lm
    model, tparams, draft, dparams = drafted
    bad = dict(dparams)
    bad["lm_head"] = np.roll(np.asarray(dparams["lm_head"]), 1, axis=1)
    prompts = _prompts(cfg)
    plain, _ = _run(model, tparams, prompts)
    spec, eng = _run(model, tparams, prompts, draft_model=draft,
                     draft_params=bad, spec_k=3)
    for pr, sr in zip(plain, spec):
        assert pr.generated == sr.generated
    assert eng.spec_bursts > 0
    assert eng.spec_accepted == 0
    assert eng.spec_committed == eng.spec_bursts  # 1 per burst


def test_partial_acceptance_is_bit_exact(small_lm):
    """damp>0: the draft disagrees with the damped target some of the time;
    greedy verify still reproduces plain decode token-for-token."""
    cfg, model, params = small_lm
    dcfg, dparams, tparams = make_self_draft(cfg, params, keep_layers=1,
                                             damp=0.05)
    draft = build_model(dcfg)
    prompts = _prompts(cfg)
    plain, _ = _run(model, tparams, prompts)
    spec, eng = _run(model, tparams, prompts, draft_model=draft,
                     draft_params=dparams, spec_k=3)
    for pr, sr in zip(plain, spec):
        assert pr.generated == sr.generated
    assert 0 < eng.spec_accepted < eng.spec_proposed  # genuinely partial


def test_spec_k0_degrades_to_plain(small_lm, drafted):
    """spec_k=0 disables speculation entirely: no draft cache, no bursts,
    and the engine is the plain paged engine."""
    cfg, _, _ = small_lm
    model, tparams, draft, dparams = drafted
    prompts = _prompts(cfg)
    plain, _ = _run(model, tparams, prompts)
    spec, eng = _run(model, tparams, prompts, draft_model=draft,
                     draft_params=dparams, spec_k=0)
    assert not eng._spec and eng.spec_bursts == 0
    for pr, sr in zip(plain, spec):
        assert pr.generated == sr.generated


def test_per_request_opt_out(small_lm, drafted):
    """speculative=False on one request keeps it on the plain decode path
    while its neighbors burst; streams stay bit-exact either way."""
    cfg, _, _ = small_lm
    model, tparams, draft, dparams = drafted
    prompts = _prompts(cfg, lens=(4, 9))
    plain, _ = _run(model, tparams, prompts)
    eng = PagedServingEngine(model, tparams, decode_batch=2, max_ctx=32,
                             page_size=4, chunk=8, draft_model=draft,
                             draft_params=dparams, spec_k=3)
    a = eng.add_request(prompts[0], max_new_tokens=8, speculative=False)
    b = eng.add_request(prompts[1], max_new_tokens=8)
    eng.run_to_completion(max_steps=512)
    assert a.generated == plain[0].generated
    assert b.generated == plain[1].generated
    events = eng.drain_spec_events()
    assert events and all(ev["uid"] == b.uid for ev in events)


def test_preemption_rollback_is_bit_exact(small_lm, drafted):
    """An oversubscribed pool preempts speculating lanes mid-stream;
    recompute-on-resume plus verify rollback must reproduce the exact
    token streams of an unconstrained plain run."""
    cfg, _, _ = small_lm
    model, tparams, draft, dparams = drafted
    prompts = [[i + 1] * 5 for i in range(4)]
    plain, _ = _run(model, tparams, prompts, mnt=6, decode_batch=4)
    spec, eng = _run(model, tparams, prompts, mnt=6, decode_batch=4,
                     page_size=2, pool_pages=15, draft_model=draft,
                     draft_params=dparams, spec_k=3)
    assert eng.preemptions > 0
    assert eng.spec_bursts > 0
    for pr, sr in zip(plain, spec):
        assert pr.generated == sr.generated
    assert eng.table.used_pages == 0


# ---------------------------------------------------------------------------
# The verify workload class: extraction geometry + transfer seeding
# ---------------------------------------------------------------------------


def test_verify_cell_geometry(small_lm):
    """Verify attends like chunk_prefill (Q=k+1 over the full cached
    context) but projects *all* positions through the lm head (M = B*(k+1),
    not B) — its logits feed k+1 acceptance decisions per lane."""
    cfg, _, _ = small_lm
    b, k, ctx = 2, 3, 32
    verify = spec_verify_uses(cfg, decode_batch=b, max_ctx=ctx, spec_k=k)
    chunk = extract_kernels(
        cfg, ShapeConfig("c", k + 1, b, "chunk_prefill", ctx_len=ctx),
        dp=1, tp=1)

    def by_class(uses):
        return {u.instance.class_id: dict(u.instance.params) for u in uses}

    v, c = by_class(verify), by_class(chunk)
    attn = v["flash_attention_causal"]
    assert attn["Q"] == k + 1 and attn["KV"] == ctx and attn["B"] == b
    assert attn == c["flash_attention_causal"]  # transfer-seeds exactly
    assert v["matmul_lmhead"]["M"] == b * (k + 1)   # all positions
    assert c["matmul_lmhead"]["M"] == b             # final position only
    # every non-head kernel is workload-identical to the chunk cell
    vk = {u.instance.workload_key() for u in verify
          if u.instance.class_id != "matmul_lmhead"}
    ck = {u.instance.workload_key() for u in chunk
          if u.instance.class_id != "matmul_lmhead"}
    assert vk == ck


def test_engine_plan_covers_spec_cells(small_lm, drafted):
    """A speculating engine's execution plan pre-resolves the verify cell
    and the draft's decode/chunk cells — no default-tier surprises at the
    first burst."""
    from repro.kernels.ops import ScheduleProvider

    cfg, _, _ = small_lm
    model, tparams, draft, dparams = drafted
    eng = PagedServingEngine(model, tparams, decode_batch=2, max_ctx=32,
                             page_size=4, chunk=8, draft_model=draft,
                             draft_params=dparams, spec_k=3,
                             provider=ScheduleProvider())
    assert eng.plan is not None
    for u in spec_verify_uses(cfg, decode_batch=2, max_ctx=32, spec_k=3):
        assert eng.plan.lookup(u.instance) is not None


# ---------------------------------------------------------------------------
# AcceptanceTracker
# ---------------------------------------------------------------------------


def test_acceptance_tracker_prior_and_evidence():
    t = AcceptanceTracker(prior_alpha=0.6, prior_weight=10.0)
    assert t.alpha("chat") == pytest.approx(0.6)  # cold: pure prior
    t.record("chat", proposed=90, accepted=90)
    # 90 accepted of 90 + 6 pseudo-accepted of 10 pseudo-proposed
    assert t.alpha("chat") == pytest.approx(96.0 / 100.0)
    assert t.alpha("bulk") == pytest.approx(0.6)  # classes are independent
    t.record("bulk", proposed=50, accepted=0)
    assert t.alpha("bulk") == pytest.approx(6.0 / 60.0)
    assert t.observed("chat") == pytest.approx(90.0)


def test_acceptance_tracker_decay_tracks_drift():
    t = AcceptanceTracker(half_life_s=10.0, prior_alpha=0.5,
                          prior_weight=0.0)
    t.record("c", 100, 100, t=0.0)
    assert t.alpha("c") == pytest.approx(1.0)
    # one half-life later the old evidence weighs half as much as new
    t.record("c", 100, 0, t=10.0)
    assert t.alpha("c") == pytest.approx(50.0 / 150.0)
    # many half-lives: ancient evidence evaporates entirely
    t.record("c", 10, 0, t=500.0)
    assert t.alpha("c") == pytest.approx(0.0, abs=1e-6)


def test_acceptance_tracker_validation():
    with pytest.raises(ValueError):
        AcceptanceTracker(half_life_s=0.0)
    with pytest.raises(ValueError):
        AcceptanceTracker(prior_alpha=1.5)
    t = AcceptanceTracker()
    with pytest.raises(ValueError):
        t.record("c", proposed=3, accepted=4)


# ---------------------------------------------------------------------------
# Traffic classes + fleet routing surfaces
# ---------------------------------------------------------------------------


def test_traffic_class_mix_is_seeded_and_rng_preserving(tmp_path):
    mix = {"chat": 0.7, "bulk": 0.3}
    a = TrafficGenerator(seed=11, class_mix=mix).trace(20)
    b = TrafficGenerator(seed=11, class_mix=mix).trace(20)
    assert [r.request_class for r in a] == [r.request_class for r in b]
    assert {"chat", "bulk"} == {r.request_class for r in a}
    # class_mix=None must not consume RNG: legacy traces stay byte-identical
    legacy = TrafficGenerator(seed=11).trace(20)
    plain = TrafficGenerator(seed=11, class_mix=None).trace(20)
    assert [(r.arrival_s, r.prompt, r.max_new_tokens) for r in legacy] \
        == [(r.arrival_s, r.prompt, r.max_new_tokens) for r in plain]
    # request_class round-trips through save/load
    path = str(tmp_path / "trace.jsonl")
    save_trace(path, a)
    loaded = load_trace(path)
    assert [r.request_class for r in loaded] == [r.request_class for r in a]


def test_fleet_speculative_serving_and_acceptance_accounting(small_lm,
                                                            drafted):
    """speculative=True fleet: every admit speculates, burst events flow
    into the per-class AcceptanceTracker, and the summary reports them."""
    cfg, _, _ = small_lm
    model, tparams, draft, dparams = drafted
    gen = TrafficGenerator(seed=4, vocab_size=cfg.vocab_size,
                           arrival_rate=1.0, new_tokens=(6, 10),
                           prompt_cap=12,
                           class_mix={"chat": 0.5, "bulk": 0.5})
    fleet = ServingFleet(cfg, model, tparams, replicas=1, engine="paged",
                         decode_batch=2, max_len=32, page_size=4, chunk=8,
                         speculative=True, draft_model=draft,
                         draft_params=dparams, spec_k=3)
    try:
        s = fleet.serve(gen.trace(8))
    finally:
        fleet.close()
    assert s["completed"] == 8
    spec = s["speculative"]
    assert spec["mode"] == "all" and spec["counters"]["admit_spec"] == 8
    assert spec["counters"]["bursts"] > 0
    # damp=0 draft: every proposed token accepted; the blended per-class
    # estimate sits between the prior (0.7) and the measured rate (1.0)
    assert spec["counters"]["accepted"] == spec["counters"]["proposed"] > 0
    for cls in spec["acceptance"]["classes"].values():
        assert 0.7 < cls["alpha"] <= 1.0
    rep = fleet.replicas[0]
    assert rep.spec_capable
    # gain is monotone in alpha and the per-token estimate never exceeds
    # plain decode (auto admission would refuse a losing trade)
    assert rep.spec_gain(1.0) >= rep.spec_gain(0.5) >= rep.spec_gain(0.0)
    assert rep.expected_token_s("chat") <= rep.decode_cost() + 1e-12


def test_fleet_speculative_validation(small_lm):
    cfg, model, params = small_lm
    with pytest.raises(ValueError, match="paged"):
        ServingFleet(cfg, model, params, replicas=1, engine="slot",
                     speculative=True, draft_model=object(), draft_params={})
    with pytest.raises(ValueError, match="draft_model"):
        ServingFleet(cfg, model, params, replicas=1, engine="paged",
                     speculative="auto")
