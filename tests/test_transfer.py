"""Transfer-tuning engine: the paper's core claims at unit scale."""
import pytest

from repro.core.autoscheduler import tune_kernel, tune_model
from repro.core.cost_model import kernel_seconds, measure
from repro.core.database import Record, ScheduleDB
from repro.core.heuristic import donor_scores, select_donor
from repro.core.schedule import default_schedule
from repro.core.transfer import transfer_matrix, transfer_tune
from repro.core.workload import KernelInstance, KernelUse


def g(m, n, k):
    return KernelInstance.make("matmul", M=m, N=n, K=k)


@pytest.fixture(scope="module")
def gemm_db():
    """Donor DB: tuned 512^3 and 1024^3 GEMMs (paper §4.1 setting)."""
    db = ScheduleDB()
    for size, model in ((512, "gemm512"), (1024, "gemm1024")):
        res = tune_kernel(g(size, size, size), trials=128, seed=0)
        db.add(Record(g(size, size, size), res.best, res.best_seconds, model))
    return db


def test_gemm_cross_transfer_within_margin(gemm_db):
    """Paper §4.1: a transferred GEMM schedule is valid, captures most of the
    tuned speedup, and is within a small factor of native (paper saw ~5% for
    its pair; our margin absorbs search stochasticity — the benchmark
    reports the actual ratio)."""
    rec512 = gemm_db.by_class("matmul", ["gemm512"])[0]
    rec1024 = gemm_db.by_class("matmul", ["gemm1024"])[0]
    m = measure(g(1024, 1024, 1024), rec512.schedule, noise_sigma=0.0)
    assert m.valid
    assert m.seconds <= rec1024.seconds * 2.5
    untuned = kernel_seconds(g(1024, 1024, 1024), default_schedule(g(1024, 1024, 1024)))
    assert m.seconds < untuned  # strictly better than the generic default


def test_transfer_much_cheaper_than_tuning(gemm_db):
    target = [KernelUse(g(2048, 2048, 2048))]
    tt = transfer_tune(target, gemm_db, model_id="target")
    full = tune_model(target, "target", total_trials=256, seed=0)
    assert tt.search_time_s < full.search_time_s / 10
    assert tt.speedup > 1.5  # still a large fraction of the benefit


def test_exact_workload_hit_is_free(gemm_db):
    """Ansor workload-ID reuse: zero measurements for exact shape matches."""
    tt = transfer_tune([KernelUse(g(512, 512, 512))], gemm_db)
    k = tt.kernels[0]
    assert k.exact_hit and k.candidates == 0
    assert tt.search_time_s == 0.0


def test_invalid_transfers_detected(gemm_db):
    """Fig. 4's -1 bars: some donor schedules are invalid on new shapes."""
    tiny = [KernelUse(g(96, 96, 96))]  # many 2^k tiles won't divide/fit 96
    tt = transfer_tune(tiny, gemm_db, mode="strict")
    mat = transfer_matrix(tiny, gemm_db)
    row = list(mat.values())[0]
    assert len(row) == 2
    assert tt.kernels[0].invalid + (1 if tt.kernels[0].chosen is not None else 0) >= 1


def test_adaptive_mode_recovers_invalids(gemm_db):
    tiny = [KernelUse(g(96, 96, 96))]
    strict = transfer_tune(tiny, gemm_db, mode="strict")
    adaptive = transfer_tune(tiny, gemm_db, mode="adaptive")
    assert adaptive.tuned_seconds <= strict.tuned_seconds + 1e-12


def test_fallback_to_default_when_no_donor():
    db = ScheduleDB()
    uses = [KernelUse(g(512, 512, 512))]
    tt = transfer_tune(uses, db)
    assert tt.kernels[0].chosen is None
    assert tt.speedup == pytest.approx(1.0)
    assert tt.coverage() == 0.0


def test_mixed_pool_never_worse_standalone(gemm_db):
    """With *standalone* kernel costs, a larger pool can only help per-kernel
    (the paper's §5.5 regression arises from in-context effects)."""
    target = [KernelUse(g(2048, 2048, 2048))]
    one = transfer_tune(target, gemm_db, donors=["gemm512"])
    mixed = transfer_tune(target, gemm_db, donors=None)
    assert mixed.tuned_seconds <= one.tuned_seconds + 1e-12
    assert mixed.search_time_s >= one.search_time_s


# ---------------------------------------------------------------------------
# Heuristic (Eq. 1)
# ---------------------------------------------------------------------------


def _fake_db_with_classes(model_classes: dict[str, dict[str, int]]) -> ScheduleDB:
    db = ScheduleDB()
    for model, classes in model_classes.items():
        for class_id, n in classes.items():
            for i in range(n):
                size = 128 * (i + 1)
                inst = KernelInstance.make(class_id, M=size, N=size, K=size)
                db.add(Record(inst, default_schedule(inst),
                              kernel_seconds(inst), model))
    return db


def test_heuristic_prefers_matching_expensive_class():
    """BERT↔MobileBERT analogue: donors sharing the dominant class win."""
    db = _fake_db_with_classes({
        "donor_lmheads": {"matmul_lmhead": 4},
        "donor_misc": {"matmul_bias": 12},
    })
    uses = [
        KernelUse(KernelInstance.make("matmul_lmhead", M=8192, N=4096, K=512), 1),
        KernelUse(KernelInstance.make("matmul_bias", M=64, N=64, K=64), 1),
    ]
    assert select_donor(uses, db) == "donor_lmheads"


def test_heuristic_sqrt_damping():
    """Many schedules of a cheap class must not dominate (the sqrt/square)."""
    db = _fake_db_with_classes({
        "few_relevant": {"matmul_lmhead": 1},
        "many_irrelevant": {"matmul_bias": 100},
    })
    uses = [
        KernelUse(KernelInstance.make("matmul_lmhead", M=8192, N=8192, K=1024), 1),
        KernelUse(KernelInstance.make("matmul_bias", M=32, N=32, K=32), 1),
    ]
    scores = {s.model_id: s.score for s in donor_scores(uses, db)}
    assert scores["few_relevant"] > scores["many_irrelevant"]


def test_heuristic_excludes_self():
    db = _fake_db_with_classes({"self": {"matmul": 3}, "other": {"matmul": 2}})
    uses = [KernelUse(g(512, 512, 512))]
    assert select_donor(uses, db, exclude=("self",)) == "other"


def test_heuristic_v2_prefers_compatible_donor():
    """Beyond-paper: equal Eq.1 scores but one donor's tiles cannot bind to
    the target's reduction extents — v2 must prefer the compatible donor."""
    from repro.core.heuristic import select_donor_v2
    from repro.core.schedule import Schedule

    db = ScheduleDB()
    good = Schedule.make("matmul", {"M": 128, "N": 128, "K": 96})   # 96 | 480
    bad = Schedule.make("matmul", {"M": 128, "N": 128, "K": 1024})  # 1024 > 480
    db.add(Record(g(960, 960, 960), good, 1e-5, "compatible"))
    db.add(Record(g(2048, 2048, 2048), bad, 1e-5, "incompatible"))
    target = [KernelUse(g(480, 480, 480))]
    assert select_donor_v2(target, db) == "compatible"
    # Eq.1 alone cannot distinguish them (same class, one schedule each)
    s = {d.model_id: d.score for d in donor_scores(target, db)}
    assert abs(s["compatible"] - s["incompatible"]) < 1e-12
