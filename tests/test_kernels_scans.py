"""Recurrent-scan kernels (rwkv6 wkv, RG-LRU) vs lax.scan oracles."""
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core.schedule import Schedule, concretize
from repro.core.workload import KernelInstance
from repro.kernels import ref
from repro.kernels import rglru_scan as rg
from repro.kernels import rwkv6_scan as rw


def _sigmoid(x):
    return 1.0 / (1.0 + np.exp(-x))


@given(t=st.sampled_from([4, 8, 16]), ct=st.sampled_from([2, 4, 8]),
       h=st.sampled_from([1, 3]), d=st.sampled_from([4, 8]))
@settings(max_examples=16, deadline=None)
def test_rwkv6_kernel_matches_oracle(t, ct, h, d):
    b = 2
    r_ = np.random.default_rng(t * 37 + ct)
    mk = lambda: jnp.asarray(r_.normal(size=(b, h, t, d)), jnp.float32)
    r, k, v = mk(), mk(), mk()
    w = jnp.asarray(_sigmoid(r_.normal(size=(b, h, t, d))) * 0.9 + 0.05, jnp.float32)
    u = jnp.asarray(r_.normal(size=(h, d)), jnp.float32)
    s0 = jnp.asarray(r_.normal(size=(b, h, d, d)), jnp.float32)
    inst = KernelInstance.make("rwkv6_scan", T=t, C=h * d, D=d, B=b, dtype="float32")
    cs = concretize(Schedule.make("rwkv6_scan", {"T": ct, "C": h * d}, order=("C", "T")),
                    inst, mode="adaptive")
    y, sT = rw.rwkv6_scan(r, k, v, w, u, s0, cs)
    yr, sTr = ref.rwkv6_scan(r, k, v, w, u, s0)
    np.testing.assert_allclose(y, yr, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(sT, sTr, rtol=1e-5, atol=1e-5)


@given(t=st.sampled_from([4, 8, 16]), ct=st.sampled_from([2, 4, 8]),
       c=st.sampled_from([8, 12]), bc=st.sampled_from([4, 8]))
@settings(max_examples=16, deadline=None)
def test_rglru_kernel_matches_oracle(t, ct, c, bc):
    b = 2
    r_ = np.random.default_rng(t * 11 + c)
    x = jnp.asarray(r_.normal(size=(b, t, c)), jnp.float32)
    a = jnp.asarray(_sigmoid(r_.normal(size=(b, t, c))), jnp.float32)
    h0 = jnp.asarray(r_.normal(size=(b, c)), jnp.float32)
    inst = KernelInstance.make("rglru_scan", T=t, C=c, B=b, dtype="float32")
    cs = concretize(Schedule.make("rglru_scan", {"T": ct, "C": bc}, order=("C", "T")),
                    inst, mode="adaptive")
    y, hT = rg.rglru_scan(x, a, h0, cs)
    yr, hTr = ref.rglru_scan(x, a, h0)
    np.testing.assert_allclose(y, yr, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(hT, hTr, rtol=1e-5, atol=1e-5)


def test_chunking_invariance():
    """Different T tiles must give bit-identical recurrences (state carry)."""
    b, h, t, d = 1, 2, 16, 4
    r_ = np.random.default_rng(0)
    mk = lambda: jnp.asarray(r_.normal(size=(b, h, t, d)), jnp.float32)
    r, k, v = mk(), mk(), mk()
    w = jnp.asarray(_sigmoid(r_.normal(size=(b, h, t, d))), jnp.float32)
    u = jnp.asarray(r_.normal(size=(h, d)), jnp.float32)
    s0 = jnp.zeros((b, h, d, d), jnp.float32)
    outs = []
    for ct in (2, 4, 16):
        inst = KernelInstance.make("rwkv6_scan", T=t, C=h * d, D=d, B=b, dtype="float32")
        cs = concretize(Schedule.make("rwkv6_scan", {"T": ct, "C": h * d},
                                      order=("C", "T")), inst)
        y, _ = rw.rwkv6_scan(r, k, v, w, u, s0, cs)
        outs.append(np.asarray(y))
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


def test_state_continuation():
    """Scanning [0:t1] then [t1:t] must equal one scan (serving contract)."""
    b, t, c = 2, 12, 8
    r_ = np.random.default_rng(1)
    x = jnp.asarray(r_.normal(size=(b, t, c)), jnp.float32)
    a = jnp.asarray(_sigmoid(r_.normal(size=(b, t, c))), jnp.float32)
    h0 = jnp.zeros((b, c), jnp.float32)
    y_full, h_full = ref.rglru_scan(x, a, h0)
    y1, h1 = ref.rglru_scan(x[:, :5], a[:, :5], h0)
    y2, h2 = ref.rglru_scan(x[:, 5:], a[:, 5:], h1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full, rtol=1e-6)
    np.testing.assert_allclose(h2, h_full, rtol=1e-6)
