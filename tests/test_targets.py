"""Multi-target subsystem: registry, namespacing, cross-target transfer."""
import dataclasses

import pytest

from repro.core.autoscheduler import tune_kernel
from repro.core.database import Record, ScheduleDB
from repro.core.runner import AnalyticalRunner, CachedRunner, default_runner, resolve_runner
from repro.core.schedule import Schedule, default_schedule
from repro.core.transfer import cross_target_transfer, transfer_tune
from repro.core.workload import KernelInstance, KernelUse
from repro.hw.specs import TPU_V5E, TPU_V5E_LITE, TPU_V5P, dim_efficiency
from repro.service import ScheduleRegistry, TuningService
from repro.targets import (
    DEFAULT_TARGET,
    Target,
    get_target,
    list_targets,
    register_target,
    resolve_target,
    target_name,
)

SERVER, EDGE = "tpu-v5e", "tpu-v5e-lite"


def g(m, n=None, k=None):
    return KernelInstance.make("matmul", M=m, N=n or m, K=k or m)


def sched(bm, bn, bk):
    return Schedule.make("matmul", tiles={"M": bm, "N": bn, "K": bk},
                         order=("M", "N", "K"))


#: Valid on v5e (≈18 MiB VMEM), overflows the lite chip's 8 MiB budget.
BIG = sched(1024, 2048, 512)
#: Fits every registered target.
SMALL = sched(128, 256, 256)


# ---------------------------------------------------------------------------
# Target registry
# ---------------------------------------------------------------------------


def test_registered_targets_and_specs():
    assert {"tpu-v5e", "tpu-v5e-lite", "tpu-v5p"} <= set(list_targets())
    assert get_target("tpu-v5e").spec == TPU_V5E
    assert get_target("tpu-v5e").tier == "server"
    assert get_target(EDGE).tier == "edge"
    lite, v5p = get_target(EDGE).spec, get_target("tpu-v5p").spec
    assert lite.vmem_capacity < TPU_V5E.vmem_capacity < v5p.vmem_capacity
    assert lite.peak_flops_bf16 < TPU_V5E.peak_flops_bf16 < v5p.peak_flops_bf16
    assert lite.hbm_bandwidth < TPU_V5E.hbm_bandwidth < v5p.hbm_bandwidth


def test_resolve_target_forms():
    assert resolve_target(None).name == DEFAULT_TARGET
    assert resolve_target(EDGE).spec == TPU_V5E_LITE
    t = get_target("tpu-v5p")
    assert resolve_target(t) is t
    assert resolve_target(TPU_V5P) is t            # registered spec round-trips
    custom = dataclasses.replace(TPU_V5E, name="my-chip", vmem_capacity=1 << 20)
    anon = resolve_target(custom)
    assert anon.name == "my-chip" and anon.spec is custom
    # A different chip wearing a registered name would alias two namespaces.
    imposter = dataclasses.replace(TPU_V5E, vmem_capacity=1 << 20)
    with pytest.raises(ValueError, match="distinct name"):
        resolve_target(imposter)
    with pytest.raises(KeyError, match="tpu-v5e"):  # lists available targets
        get_target("nonexistent-chip")


def test_register_target_guard():
    with pytest.raises(ValueError, match="already registered"):
        register_target(Target("tpu-v5e", TPU_V5E))
    with pytest.raises(ValueError, match="tier"):
        Target("x", TPU_V5E, tier="mainframe")


def test_target_name_passthrough():
    assert target_name(None) == DEFAULT_TARGET
    assert target_name("anything-goes") == "anything-goes"
    assert target_name(get_target(EDGE)) == EDGE
    assert target_name(TPU_V5P) == "tpu-v5p"


# ---------------------------------------------------------------------------
# dim_efficiency edge cases (hw/specs.py)
# ---------------------------------------------------------------------------


def test_dim_efficiency_edge_cases():
    assert dim_efficiency(0, 128) == 0.0
    assert dim_efficiency(-8, 128) == 0.0
    assert dim_efficiency(128, 128) == 1.0
    assert dim_efficiency(256, 128) == 1.0          # exact multiple: no waste
    assert dim_efficiency(96, 128) == pytest.approx(96 / 128)
    # block > native pays only for its remainder tile: 192 pads to 256
    assert dim_efficiency(192, 128) == pytest.approx(192 / 256)
    assert dim_efficiency(1, 8) == pytest.approx(1 / 8)


# ---------------------------------------------------------------------------
# Runner target identity
# ---------------------------------------------------------------------------


def test_runner_targets_and_cache_isolation():
    assert AnalyticalRunner().target == DEFAULT_TARGET
    assert CachedRunner(AnalyticalRunner(EDGE)).target == EDGE
    assert default_runner("tpu-v5p").target == "tpu-v5p"
    # The same (instance, schedule) question must measure differently per chip.
    inst = g(512)
    s_server = default_runner(SERVER).measure(inst, SMALL, noise_sigma=0.0).seconds
    s_edge = default_runner(EDGE).measure(inst, SMALL, noise_sigma=0.0).seconds
    assert s_edge > s_server


def test_resolve_runner_mismatch_raises():
    r = default_runner(SERVER)
    assert resolve_runner(r, SERVER) == (r, SERVER)
    assert resolve_runner(r, None) == (r, SERVER)
    with pytest.raises(ValueError, match="measures target"):
        resolve_runner(r, EDGE)


def test_vmem_valid_on_server_invalid_on_edge():
    inst = g(2048)
    assert default_runner(SERVER).measure(inst, BIG).valid
    assert not default_runner(EDGE).measure(inst, BIG).valid
    assert default_runner(EDGE).measure(inst, SMALL).valid


# ---------------------------------------------------------------------------
# ScheduleDB namespacing + persistence
# ---------------------------------------------------------------------------


def test_db_namespaces_never_leak():
    inst = g(512)
    db = ScheduleDB()
    db.add(Record(inst, SMALL, 1.0, "m", target=SERVER))
    db.add(Record(inst, SMALL, 0.1, "m", target=EDGE))  # faster, other chip
    assert db.targets() == sorted((SERVER, EDGE))
    assert db.exact(inst, target=SERVER).target == SERVER
    assert db.exact(inst, target=SERVER).seconds == 1.0  # not the faster edge one
    assert db.exact(inst, target=EDGE).seconds == 0.1
    assert db.exact(inst) == db.exact(inst, target=DEFAULT_TARGET)
    assert db.exact(inst, target="tpu-v5p") is None
    assert [r.target for r in db.by_class("matmul", target=EDGE)] == [EDGE]
    assert db.models(target=EDGE) == ["m"]
    assert db.models(target="tpu-v5p") == []
    assert db.class_counts("m", target=EDGE) == {"matmul": 1}


def test_db_save_load_preserves_target(tmp_path):
    db = ScheduleDB([Record(g(256), SMALL, 1.0, "m", target=EDGE)])
    path = str(tmp_path / "db.json")
    db.save(path)
    back = ScheduleDB.load(path)
    assert back.records()[0].target == EDGE
    assert back.exact(g(256), target=EDGE) is not None


def test_legacy_record_without_target_reads_as_default():
    d = Record(g(256), SMALL, 1.0, "m").to_json()
    del d["target"]  # pre-subsystem stores never wrote the field
    assert Record.from_json(d).target == DEFAULT_TARGET


# ---------------------------------------------------------------------------
# Cross-target transfer
# ---------------------------------------------------------------------------


@pytest.fixture
def server_db():
    """Donor pool tuned on the server chip: one edge-infeasible, one portable."""
    inst = g(2048)
    runner = default_runner(SERVER)
    return ScheduleDB([
        Record(inst, BIG, runner.measure(inst, BIG, noise_sigma=0.0).seconds,
               "donor", target=SERVER),
        Record(inst, SMALL, runner.measure(inst, SMALL, noise_sigma=0.0).seconds,
               "donor", target=SERVER),
    ])


def test_cross_target_rejects_edge_infeasible_donors(server_db):
    uses = [KernelUse(g(1024, 2048, 2048))]
    res = cross_target_transfer(uses, server_db, source_target=SERVER,
                                target=EDGE, donors=["donor"])
    assert res.target == EDGE and res.donor_target == SERVER
    assert res.invalid_transfers >= 1          # BIG overflows the edge VMEM
    k = res.kernels[0]
    assert k.chosen != BIG                     # the infeasible donor never wins
    assert res.tuned_seconds <= res.untuned_seconds


def test_cross_target_same_chip_rejected(server_db):
    with pytest.raises(ValueError, match="both"):
        cross_target_transfer([KernelUse(g(512))], server_db,
                              source_target=SERVER, target=SERVER)


def test_same_shape_foreign_record_is_not_an_exact_hit(server_db):
    # The donor tuned the *identical* workload on the server chip; on the
    # edge chip that record must be re-measured as a candidate, never reused
    # as a zero-cost exact hit.
    inst = g(2048)
    res = transfer_tune([KernelUse(inst)], server_db, donors=["donor"],
                        target=EDGE, donor_target=SERVER)
    assert not res.kernels[0].exact_hit
    assert res.kernels[0].candidates == 2
    same = transfer_tune([KernelUse(inst)], server_db, donors=["donor"],
                         target=SERVER)
    assert same.kernels[0].exact_hit


def test_tune_kernel_tags_target():
    res = tune_kernel(g(256), trials=24, seed=0, target=EDGE)
    assert res.target == EDGE
    # every surviving schedule fits the edge VMEM by construction
    m = default_runner(EDGE).measure(g(256), res.best)
    assert m.valid


# ---------------------------------------------------------------------------
# Registry / service namespacing
# ---------------------------------------------------------------------------


def test_service_lookup_never_serves_foreign_target(tmp_path):
    inst = g(512)
    reg = ScheduleRegistry(str(tmp_path / "reg"))
    reg.publish([Record(inst, SMALL, 1e-9, "donor", target=SERVER)])

    edge_svc = TuningService(reg, runner=default_runner(EDGE), target=EDGE,
                             max_workers=0, probe_candidates=0)
    res = edge_svc.lookup(inst)
    assert res.tier != "exact"                  # the v5e record is invisible
    assert res.schedule is None
    assert edge_svc.stats()["target"] == EDGE

    server_svc = TuningService(reg, runner=default_runner(SERVER),
                               max_workers=0, probe_candidates=0)
    assert server_svc.lookup(inst).tier == "exact"


def test_edge_service_cross_target_donors(tmp_path):
    """Explicit cross-target serving: edge service, server-tuned donor pool."""
    donor_inst, target_inst = g(2048), g(1024, 2048, 2048)
    reg = ScheduleRegistry(str(tmp_path / "reg"))
    runner = default_runner(SERVER)
    reg.publish([
        Record(donor_inst, s, runner.measure(donor_inst, s, noise_sigma=0.0).seconds,
               "donor", target=SERVER)
        for s in (BIG, SMALL)
    ])
    svc = TuningService(reg, runner=default_runner(EDGE), target=EDGE,
                        donor_target=SERVER, max_workers=0, seed=0)
    first = svc.lookup(target_inst)
    assert first.tier in ("transfer", "default")
    svc.drain()
    upgraded = svc.lookup(target_inst)
    rec = reg.snapshot().db(None).exact(target_inst, target=EDGE)
    if rec is not None:                         # job published into EDGE only
        assert upgraded.tier == "exact"
        assert rec.target == EDGE
    assert reg.snapshot().db(None).exact(target_inst, target=SERVER) is None


def test_registry_auto_compact(tmp_path):
    reg = ScheduleRegistry(str(tmp_path / "reg"), auto_compact_segments=3)
    for i in range(5):
        reg.publish([Record(g(512), SMALL, float(5 - i), f"m{i}")])
    stats = reg.stats()
    # Folds the moment a publish pushes the count past the threshold, so the
    # store never exceeds it (5 unbounded publishes would leave 5 segments).
    assert stats["segments"] <= 3
    assert stats["compactions"] >= 1
    assert reg.snapshot().db(None).exact(g(512)).seconds == 1.0  # best kept

    # Reopen: the compacted store is the durable state.
    reopened = ScheduleRegistry(str(tmp_path / "reg"))
    assert reopened.stats()["segments"] <= 3
    assert reopened.snapshot().db(None).exact(g(512)).seconds == 1.0

    with pytest.raises(ValueError, match="auto_compact_segments"):
        ScheduleRegistry(str(tmp_path / "reg2"), auto_compact_segments=0)


def test_compaction_keeps_best_per_target(tmp_path):
    inst = g(512)
    reg = ScheduleRegistry(str(tmp_path / "reg"))
    reg.publish([Record(inst, SMALL, 1.0, "m", target=SERVER)])
    reg.publish([Record(inst, SMALL, 2.0, "m", target=EDGE)])
    reg.publish([Record(inst, SMALL, 0.5, "m", target=SERVER)])
    reg.compact()
    db = reg.snapshot().db(None)
    assert len(reg.snapshot()) == 2             # one per (workload, target)
    assert db.exact(inst, target=SERVER).seconds == 0.5
    assert db.exact(inst, target=EDGE).seconds == 2.0
