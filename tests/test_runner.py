"""MeasureRunner subsystem: caching transparency, pruning safety, telemetry."""
import random

import pytest

from repro.core.autoscheduler import random_schedule, tune_kernel
from repro.core.cost_model import kernel_seconds, measure
from repro.core.database import Record, ScheduleDB
from repro.core.runner import (
    AnalyticalRunner,
    CachedRunner,
    PruningRunner,
    default_runner,
    telemetry_delta,
)
from repro.core.schedule import Schedule, default_schedule
from repro.core.transfer import transfer_matrix, transfer_tune
from repro.core.workload import KernelInstance, KernelUse


def g(m=512, n=512, k=512):
    return KernelInstance.make("matmul", M=m, N=n, K=k)


def _schedules(inst, n=12, seed=0):
    rng = random.Random(seed)
    return [default_schedule(inst)] + [random_schedule(inst, rng) for _ in range(n - 1)]


# ---------------------------------------------------------------------------
# (a) CachedRunner is bit-transparent over AnalyticalRunner
# ---------------------------------------------------------------------------


def test_cached_runner_bit_identical_to_analytical():
    inst = g()
    bare, cached = AnalyticalRunner(), CachedRunner(AnalyticalRunner())
    for sched in _schedules(inst):
        a = bare.measure(inst, sched, seed=3)
        b = cached.measure(inst, sched, seed=3)
        assert a.seconds == b.seconds
        assert a.measure_cost_s == b.measure_cost_s
        assert a.breakdown == b.breakdown
        assert a.valid == b.valid and a.adapted == b.adapted


def test_cached_runner_matches_direct_measure():
    inst = g(768, 768, 768)
    r = default_runner()
    for sched in _schedules(inst, seed=1):
        m = r.measure(inst, sched, mode="strict", seed=0, noise_sigma=0.05)
        direct = measure(inst, sched, mode="strict", seed=0, noise_sigma=0.05)
        assert m.seconds == direct.seconds


# ---------------------------------------------------------------------------
# (b) cache hits charge measure_cost_s exactly once per unique key
# ---------------------------------------------------------------------------


def test_cache_hit_charges_cost_once_per_unique_key():
    inst = g()
    sched = default_schedule(inst)
    r = CachedRunner(AnalyticalRunner())
    first = r.measure(inst, sched, seed=0)
    assert first.measure_cost_s > 0 and not first.cached
    for _ in range(3):
        hit = r.measure(inst, sched, seed=0)
        assert hit.measure_cost_s == 0.0 and hit.cached
        assert hit.seconds == first.seconds
    assert r.stats.cache_misses == 1 and r.stats.cache_hits == 3
    # the inner runner evaluated the cost model exactly once
    assert r.inner.stats.measurements == 1
    assert r.inner.stats.measure_cost_s == first.measure_cost_s


def test_cache_key_includes_mode_seed_and_sigma():
    inst = g()
    sched = default_schedule(inst)
    r = CachedRunner(AnalyticalRunner())
    r.measure(inst, sched, seed=0, noise_sigma=0.05)
    r.measure(inst, sched, seed=1, noise_sigma=0.05)     # new seed -> miss
    r.measure(inst, sched, seed=0, noise_sigma=0.0)      # new sigma -> miss
    r.measure(inst, sched, mode="adaptive", seed=0, noise_sigma=0.05)
    assert r.stats.cache_hits == 0 and r.stats.cache_misses == 4


def test_cached_seconds_query_is_memoized():
    inst = g()
    r = CachedRunner(AnalyticalRunner())
    a = r.seconds(inst, None)
    b = r.seconds(inst, None)
    assert a == b == kernel_seconds(inst, None)


# ---------------------------------------------------------------------------
# (c) PruningRunner: winner-preserving when verify_top_k covers the batch
# ---------------------------------------------------------------------------


def _winner(measured, schedules):
    best = None
    for s, m in zip(schedules, measured):
        if m.valid and (best is None or m.seconds < best[1]):
            best = (s, m.seconds)
    return best


def test_pruning_runner_full_verify_preserves_winner():
    inst = g(1024, 1024, 1024)
    schedules = _schedules(inst, n=10, seed=2)
    bare = AnalyticalRunner()
    reference = _winner(bare.measure_many(inst, schedules, seed=0), schedules)
    pr = PruningRunner(CachedRunner(AnalyticalRunner()),
                       verify_top_k=len(schedules))
    pruned = _winner(pr.measure_many(inst, schedules, seed=0), schedules)
    assert pruned == reference
    assert pr.stats.pruned == 0


def test_pruning_runner_charges_only_verified():
    inst = g(1024, 1024, 1024)
    schedules = _schedules(inst, n=12, seed=4)
    pr = PruningRunner(AnalyticalRunner(), verify_top_k=3)
    ms = pr.measure_many(inst, schedules, seed=0)
    verified = [m for m in ms if m.valid]
    dropped = [m for m in ms if m.pruned]
    assert len(verified) <= 3
    assert all(m.measure_cost_s == 0.0 for m in dropped)
    assert pr.inner.stats.measurements <= 3
    assert pr.stats.drafts == len(schedules)


def test_pruning_runner_draft_catches_invalid_statically():
    inst = g(96, 96, 96)
    bad = Schedule.make("matmul", {"M": 128, "N": 128, "K": 1024})  # K > 96
    pr = PruningRunner(AnalyticalRunner(), verify_top_k=4)
    ms = pr.measure_many(inst, [bad, default_schedule(inst)], seed=0)
    assert ms[0].seconds is None and not ms[0].pruned
    assert ms[1].valid
    assert pr.inner.stats.measurements == 1  # the invalid one never built


# ---------------------------------------------------------------------------
# Integration: transfer stack over the runner seam
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_db():
    db = ScheduleDB()
    for size, model in ((512, "d512"), (1024, "d1024"), (1536, "d1536")):
        res = tune_kernel(g(size, size, size), trials=64, seed=0)
        db.add(Record(g(size, size, size), res.best, res.best_seconds, model))
    return db


def test_transfer_tune_default_runner_identical_to_bare(small_db):
    target = [KernelUse(g(2048, 2048, 2048)), KernelUse(g(256, 256, 256))]
    default = transfer_tune(target, small_db)
    bare = transfer_tune(target, small_db, runner=AnalyticalRunner())
    assert default.tuned_seconds == bare.tuned_seconds
    assert default.search_time_s == bare.search_time_s
    assert [k.chosen for k in default.kernels] == [k.chosen for k in bare.kernels]
    assert default.measurements == default.cache_misses > 0


def test_shared_runner_makes_matrix_then_tune_free(small_db):
    target = [KernelUse(g(2048, 2048, 2048))]
    runner = default_runner()
    before = runner.telemetry()
    transfer_matrix(target, small_db, runner=runner)
    mid = runner.telemetry()
    tt = transfer_tune(target, small_db, runner=runner)
    after = runner.telemetry()
    assert telemetry_delta(mid, before)["measurements"] > 0
    # every tune-pass cell was already measured by the matrix pass
    assert telemetry_delta(after, mid)["measurements"] == 0
    assert tt.cache_hits == tt.kernels[0].candidates
    assert tt.search_time_s == 0.0


def test_pruning_runner_transfer_winner_safe(small_db):
    target = [KernelUse(g(2048, 2048, 2048))]
    full = transfer_tune(target, small_db)
    pruned = transfer_tune(
        target, small_db,
        runner=PruningRunner(CachedRunner(), verify_top_k=len(small_db.records())))
    assert pruned.kernels[0].chosen == full.kernels[0].chosen
    assert pruned.tuned_seconds == full.tuned_seconds


def test_transfer_matrix_omits_pruned_cells(small_db):
    """Pruned cells must not masquerade as invalid (-1) transfers."""
    target = [KernelUse(g(2048, 2048, 2048))]
    full = transfer_matrix(target, small_db)
    pruned = transfer_matrix(
        target, small_db, runner=PruningRunner(CachedRunner(), verify_top_k=1))
    full_row = next(iter(full.values()))
    pruned_row = next(iter(pruned.values()))
    assert len(pruned_row) < len(full_row)
    assert all(v is not None or full_row[k] is None for k, v in pruned_row.items())


def test_max_candidates_keeps_strongest_donors(small_db):
    """Truncation must keep the strongest donors (best speedup on their own
    workload — raw seconds would bias toward small shapes), not insertion
    order."""
    recs = sorted(small_db.by_class("matmul"),
                  key=lambda r: r.seconds / kernel_seconds(r.instance, None))
    target = [KernelUse(g(2048, 2048, 2048))]
    limited = transfer_tune(target, small_db, max_candidates_per_kernel=1)
    unlimited = transfer_tune(target, small_db)
    assert limited.kernels[0].candidates == 1
    # the single surviving candidate is the strongest-at-home record
    if limited.kernels[0].chosen is not None:
        assert limited.kernels[0].chosen == recs[0].schedule
    # never worse than what the weakest single donor would give
    assert limited.tuned_seconds >= unlimited.tuned_seconds - 1e-12


def test_exact_hit_counts_zero_measurements(small_db):
    """Ansor workload-ID reuse must not appear in measurement telemetry."""
    tt = transfer_tune([KernelUse(g(512, 512, 512))], small_db)
    assert tt.kernels[0].exact_hit
    assert tt.measurements == 0 and tt.search_time_s == 0.0
    assert tt.runner_telemetry["measure_cost_s"] == 0.0
