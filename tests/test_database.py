"""Schedule database semantics + persistence."""
import json
import os

import pytest

from repro.core.database import Record, ScheduleDB, UnknownSchemaVersion
from repro.core.schedule import Schedule, default_schedule
from repro.core.workload import KernelInstance


def g(m, n, k):
    return KernelInstance.make("matmul", M=m, N=n, K=k)


def rec(inst, secs, model="m"):
    return Record(inst, default_schedule(inst), secs, model)


def test_keeps_best_per_workload_and_model():
    db = ScheduleDB()
    db.add(rec(g(512, 512, 512), 2.0))
    db.add(rec(g(512, 512, 512), 1.0))
    db.add(rec(g(512, 512, 512), 3.0))
    assert len(db) == 1
    assert db.exact(g(512, 512, 512)).seconds == 1.0


def test_exact_across_models_returns_best():
    db = ScheduleDB()
    db.add(rec(g(512, 512, 512), 2.0, "a"))
    db.add(rec(g(512, 512, 512), 1.5, "b"))
    assert db.exact(g(512, 512, 512)).model_id == "b"


def test_by_class_filters_models():
    db = ScheduleDB()
    db.add(rec(g(512, 512, 512), 1.0, "a"))
    db.add(rec(g(256, 256, 256), 1.0, "b"))
    assert len(db.by_class("matmul")) == 2
    assert [r.model_id for r in db.by_class("matmul", ["a"])] == ["a"]
    assert db.class_counts("a") == {"matmul": 1}


def test_persistence_roundtrip(tmp_path):
    db = ScheduleDB()
    s = Schedule.make("matmul", {"M": 64, "N": 128, "K": 128}, order=("N", "M", "K"))
    db.add(Record(g(512, 512, 512), s, 1.25, "donor", trials=42))
    path = os.path.join(tmp_path, "db.json")
    db.save(path)
    db2 = ScheduleDB.load(path)
    assert len(db2) == 1
    r = db2.records()[0]
    assert r.schedule == s and r.seconds == 1.25 and r.trials == 42
    assert db2.exact(g(512, 512, 512)) is not None


def test_load_or_empty(tmp_path):
    assert len(ScheduleDB.load_or_empty(os.path.join(tmp_path, "nope.json"))) == 0


def test_load_rejects_unknown_version(tmp_path):
    path = os.path.join(tmp_path, "db.json")
    with open(path, "w") as f:
        json.dump({"version": 99, "records": []}, f)
    with pytest.raises(UnknownSchemaVersion, match="version 99"):
        ScheduleDB.load(path)


def test_load_rejects_missing_version(tmp_path):
    path = os.path.join(tmp_path, "db.json")
    with open(path, "w") as f:
        json.dump({"records": []}, f)
    with pytest.raises(UnknownSchemaVersion):
        ScheduleDB.load(path)
