"""Elastic fleet: autoscaler hysteresis, demand decay, windowed metrics,
bursty/replay traffic, and the warm-join / drain-retire lifecycle edges."""
import jax
import pytest

from repro.configs import get_arch, reduced
from repro.fleet import (
    Autoscaler,
    BurstyTraffic,
    DemandTracker,
    DiurnalTraffic,
    FleetMetrics,
    FleetRequest,
    ServingFleet,
    TrafficGenerator,
    load_trace,
    save_trace,
)
from repro.models import build_model
from repro.service import ScheduleRegistry


def _req(uid, plen=3, arrival=0.0, mnt=2):
    return FleetRequest(uid=uid, prompt=[1] * plen, max_new_tokens=mnt,
                        arrival_s=arrival)


# ---------------------------------------------------------------------------
# Autoscaler (pure controller: synthetic windows)
# ---------------------------------------------------------------------------


def _win(**kw):
    w = {"t0": 0.0, "t1": 10.0, "completed": 5, "shed": 0, "shed_rate": 0.0,
         "tokens": 20, "latency_s": {"p50": 1.0, "p95": 2.0, "p99": 2.0},
         "queue_depth_mean": 0.0, "queue_depth_max": 0,
         "utilization_mean": 0.5}
    w.update(kw)
    return w


def _scaler(**kw):
    kw.setdefault("min_replicas", 1)
    kw.setdefault("max_replicas", 4)
    kw.setdefault("window_s", 10.0)
    kw.setdefault("cooldown_s", 30.0)
    return Autoscaler(**kw)


def test_up_requires_consecutive_hot_windows():
    a = _scaler(up_windows=2, queue_high=2.0, cooldown_s=0.0)
    hot = _win(queue_depth_mean=5.0)
    assert a.observe(hot, now=10.0, replicas=1).action == "hold"
    # a quiet-but-not-idle window resets the streak
    assert a.observe(_win(), now=20.0, replicas=1).action == "hold"
    assert a.observe(hot, now=30.0, replicas=1).action == "hold"
    d = a.observe(hot, now=40.0, replicas=1)
    assert d.action == "up" and "queue_depth_mean" in d.reason


def test_cooldown_suppresses_flapping():
    """Oscillating load inside the cooldown never scales — every decision in
    the refractory window is a hold with reason 'cooldown'."""
    a = _scaler(up_windows=1, down_windows=1, cooldown_s=30.0,
                queue_high=2.0, util_low=0.4, queue_low=0.5)
    hot = _win(queue_depth_mean=5.0)
    quiet = _win(utilization_mean=0.1)
    assert a.observe(hot, now=10.0, replicas=2).action == "up"
    for now, w in ((20.0, quiet), (30.0, hot), (39.0, quiet)):
        d = a.observe(w, now=now, replicas=3)
        assert d.action == "hold" and d.reason == "cooldown"
    # cooldown over: pressure present in this window acts immediately
    assert a.observe(hot, now=50.0, replicas=3).action == "up"


def test_bounds_clamp_and_down_needs_quiet_streak():
    a = _scaler(up_windows=1, down_windows=2, cooldown_s=0.0,
                min_replicas=1, max_replicas=2)
    hot = _win(shed=3, shed_rate=0.4)
    d = a.observe(hot, now=10.0, replicas=2)
    assert d.action == "hold" and "at max_replicas" in d.reason
    quiet = _win(utilization_mean=0.1, queue_depth_mean=0.0)
    assert a.observe(quiet, now=20.0, replicas=2).action == "hold"
    assert a.observe(quiet, now=30.0, replicas=2).action == "down"
    assert a.observe(quiet, now=40.0, replicas=1).action == "hold"  # streak reset
    d = a.observe(quiet, now=50.0, replicas=1)
    assert d.action == "hold" and "at min_replicas" in d.reason
    s = a.stats()
    assert s["evaluations"] == 5 and s["scale_downs"] == 1


def test_p95_trend_is_an_up_signal():
    a = _scaler(up_windows=1, cooldown_s=0.0, p95_rise=0.5)
    a.observe(_win(latency_s={"p50": 1.0, "p95": 2.0, "p99": 2.0}),
              now=10.0, replicas=1)
    d = a.observe(_win(latency_s={"p50": 1.5, "p95": 4.0, "p99": 5.0}),
                  now=20.0, replicas=1)
    assert d.action == "up" and "p95 rose" in d.reason


# ---------------------------------------------------------------------------
# Demand decay (satellite: cold bucket overtakes)
# ---------------------------------------------------------------------------


def test_demand_decay_cold_bucket_overtakes():
    """A bucket hot long ago decays below the bucket hot now; without decay
    the stale bucket keeps the top rank forever."""
    decayed = DemandTracker(half_life_s=10.0)
    frozen = DemandTracker()
    for d in (decayed, frozen):
        for i in range(8):
            d.record(_req(i, plen=3, arrival=0.0))
        for i in range(2):
            d.record(_req(100 + i, plen=9, arrival=100.0))
    # 10 half-lives later: 8 arrivals have decayed to ~0.008 weight
    assert decayed.hottest()[0][0] == 9
    assert decayed.total < 3.0
    assert frozen.hottest()[0][0] == 3          # no decay: stale bucket wins
    assert frozen.total == 10                    # ints stay exact
    assert decayed.stats()["half_life_s"] == 10.0


def test_demand_decay_prunes_dead_buckets():
    d = DemandTracker(half_life_s=1.0)
    d.record(_req(1, plen=3, arrival=0.0))
    d.record(_req(2, plen=9, arrival=200.0))  # 200 half-lives: 3 evaporates
    assert [b for b, _ in d.hottest()] == [9]


# ---------------------------------------------------------------------------
# Windowed metrics (satellite: one code path for signal and bench)
# ---------------------------------------------------------------------------


def test_metrics_windows_bin_by_time():
    m = FleetMetrics()
    for uid, (arr, fin) in enumerate(((0.0, 1.0), (0.5, 1.5), (2.2, 3.0))):
        r = _req(uid, arrival=arr)
        r.tokens = 2
        m.record_completion(r, fin)
    shed = _req(9, arrival=1.4)
    shed.shed = "queue_full"
    m.record_shed(shed, 1.4)
    m.sample_queue(4, 0.5)
    m.sample_queue(2, 1.5)
    m.sample_queue(0, 2.5)
    m.sample_utilization(1.0, 0.5)
    m.sample_utilization(0.0, 2.5)

    w0, w1 = m.window(0.0, 2.0), m.window(2.0, 4.0)
    assert w0["completed"] == 2 and w0["shed"] == 1
    assert w0["shed_rate"] == pytest.approx(1 / 3)
    assert w0["queue_depth_mean"] == pytest.approx(3.0)
    assert w0["queue_depth_max"] == 4
    assert w0["utilization_mean"] == pytest.approx(1.0)
    assert w0["latency_s"]["p50"] == pytest.approx(1.0)
    assert w1["completed"] == 1 and w1["shed"] == 0
    assert w1["latency_s"]["p95"] == pytest.approx(0.8)

    ws = m.window_summaries(2.0)
    assert [w["t0"] for w in ws] == [0.0, 2.0]
    assert [w["completed"] for w in ws] == [2, 1]
    # whole-run summary still agrees with the union of windows
    assert m.summary()["completed"] == 3


# ---------------------------------------------------------------------------
# Bursty / diurnal / replay traffic
# ---------------------------------------------------------------------------


def test_bursty_traffic_concentrates_arrivals_in_bursts():
    gen = BurstyTraffic(seed=1, vocab_size=64, arrival_rate=0.2,
                        burst_rate=2.0, burst_every_ticks=50.0,
                        burst_len_ticks=10.0, tick_s=1.0)
    trace = gen.trace(400)
    t_end = trace[-1].arrival_s
    n_burst = sum(1 for r in trace if gen.phase_at(r.arrival_s) == "burst")
    burst_time = 0.2 * t_end     # bursts cover 10/50 of the timeline
    base_time = 0.8 * t_end
    rate_burst = n_burst / burst_time
    rate_base = (len(trace) - n_burst) / base_time
    assert rate_burst > 4 * rate_base       # true ratio is 10x
    # deterministic under the seed, different under another
    again = BurstyTraffic(seed=1, vocab_size=64, arrival_rate=0.2,
                          burst_rate=2.0, burst_every_ticks=50.0,
                          burst_len_ticks=10.0, tick_s=1.0).trace(400)
    assert [r.arrival_s for r in again] == [r.arrival_s for r in trace]
    with pytest.raises(ValueError, match="burst_rate"):
        BurstyTraffic(arrival_rate=1.0, burst_rate=0.5,
                      burst_every_ticks=10.0, burst_len_ticks=2.0)


def test_diurnal_traffic_rate_curve():
    gen = DiurnalTraffic(seed=0, arrival_rate=1.0, amplitude=0.8,
                         period_ticks=100.0, tick_s=1.0)
    assert gen.rate_at(25.0) == pytest.approx(1.8)   # peak at quarter period
    assert gen.rate_at(75.0) == pytest.approx(0.2)   # trough
    assert gen.peak_rate() == pytest.approx(1.8)
    trace = gen.trace(50)
    assert [r.arrival_s for r in trace] == sorted(r.arrival_s for r in trace)
    with pytest.raises(ValueError, match="amplitude"):
        DiurnalTraffic(arrival_rate=1.0, amplitude=1.5, period_ticks=10.0)


def test_trace_save_load_roundtrip(tmp_path):
    gen = TrafficGenerator(seed=5, vocab_size=64, deadline_ticks=8.0)
    trace = gen.trace(12)
    path = str(tmp_path / "trace.jsonl")
    save_trace(path, trace)
    back = load_trace(path)
    assert [(r.uid, r.arrival_s, r.prompt, r.max_new_tokens, r.deadline_s)
            for r in back] == \
           [(r.uid, r.arrival_s, r.prompt, r.max_new_tokens, r.deadline_s)
            for r in trace]
    # outcome fields are not recorded: a replayed trace starts clean
    assert all(r.shed == "" and r.finished_s is None for r in back)


# ---------------------------------------------------------------------------
# Fleet lifecycle (real engines)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_lm():
    cfg = reduced(get_arch("minitron-4b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_retire_refused_at_min_replicas(small_lm, tmp_path):
    cfg, model, params = small_lm
    fleet = ServingFleet(cfg, model, params, replicas=2, slots=2, max_len=32,
                         registry=ScheduleRegistry(str(tmp_path / "reg")))
    fleet.retire_replica(1)
    assert fleet.replicas[1].state == "retired"    # idle: finalizes at once
    with pytest.raises(ValueError, match="min_replicas"):
        fleet.retire_replica(0)
    with pytest.raises(ValueError, match="not active"):
        fleet.retire_replica(1)
    assert [e["action"] for e in fleet.scale_events] == ["retire"]
    fleet.close()


def test_warm_join_inherits_published_exact_tier(small_lm, tmp_path):
    """A replica joining after upgrades were published boots with them
    exact-tier — the warm-join contract the bench's share criterion rests
    on — and the recorded event carries join >= pre-join share."""
    import dataclasses as dc

    from repro.core.database import Record
    from repro.core.schedule import default_schedule
    from repro.targets import DEFAULT_TARGET

    cfg, model, params = small_lm
    registry = ScheduleRegistry(str(tmp_path / "reg"))
    fleet = ServingFleet(cfg, model, params, replicas=1, slots=2, max_len=32,
                         registry=registry)
    svc = fleet.services[DEFAULT_TARGET]
    for _ in range(4):
        fleet.demand.record(_req(0, plen=3))
    inst = next(u.instance for u in fleet.replicas[0].engine.plan.uses
                if u.instance.class_id == "matmul")
    upgraded = dc.replace(default_schedule(inst), unroll=4,
                          source="background")
    registry.publish([Record(instance=inst, schedule=upgraded,
                             seconds=svc.runner.seconds(inst, upgraded),
                             model_id="background", target=DEFAULT_TARGET)])

    joined = fleet.add_replica(now=5.0)
    assert joined.idx == 1 and joined.joined_s == 5.0
    assert joined.engine.plan.lookup(inst).tier == "exact"   # born warm
    ev = fleet.scale_events[-1]
    assert ev["action"] == "join"
    assert ev["join_exact_share"] >= ev["pre_join_exact_share"]
    assert fleet.schedule_mismatches() == 0
    assert len(fleet.router.replicas) == 2
    fleet.close()


def test_warm_join_empty_registry_degrades_to_default(small_lm, tmp_path):
    cfg, model, params = small_lm
    fleet = ServingFleet(cfg, model, params, replicas=1, slots=2, max_len=32,
                         registry=ScheduleRegistry(str(tmp_path / "e")))
    fleet.demand.record(_req(0, plen=3))
    joined = fleet.add_replica()
    plan = joined.engine.plan
    assert plan is not None and plan.tier_counts().get("exact", 0) == 0
    ev = fleet.scale_events[-1]
    assert ev["join_exact_share"] == 0.0 == ev["pre_join_exact_share"]
    # and it actually serves: route one request through the joined replica
    req = _req(1, plen=3)
    fleet.demand.record(req)
    assert fleet._admit(req, joined.idx) is True
    fleet.close()


def test_retire_requeues_engine_waiting_work(small_lm, tmp_path):
    """Drain-retire with queued-but-unstarted work: the paged engine's
    waiting requests are withdrawn, requeued at the router front, and
    complete on the surviving replica — nothing is dropped."""
    cfg, model, params = small_lm
    fleet = ServingFleet(cfg, model, params, replicas=2, slots=2, max_len=32,
                         engine="paged", decode_batch=2, page_size=4,
                         chunk=8, registry=ScheduleRegistry(str(tmp_path / "r")))
    reqs = [_req(i, plen=3) for i in range(3)]
    for r in reqs:
        fleet.demand.record(r)
        assert fleet._admit(r, 0) is True     # all parked in replica 0
    assert fleet.replicas[0].engine.in_flight == 3

    fleet.retire_replica(0)
    ev = fleet.scale_events[-1]
    assert ev["requeued"] == 3 and ev["in_flight"] == 0
    assert fleet.replicas[0].state == "retired"   # emptied by the withdraw
    assert fleet.router.depth == 3
    assert all(r.replica is None for r in reqs)

    summary = fleet.serve([])                     # drain the requeue
    assert summary["completed"] == 3 and summary["shed"] == 0
    assert all(r.replica == 1 for r in reqs)
    assert summary["router"]["requeued"] == 3
    fleet.close()


def test_elastic_fleet_scales_through_a_burst(small_lm, tmp_path):
    """End-to-end: an autoscaled fleet riding a bursty trace joins and
    retires replicas mid-stream with zero drops and zero divergence."""
    cfg, model, params = small_lm
    fleet = ServingFleet(cfg, model, params, replicas=1, slots=2, max_len=32,
                         registry=ScheduleRegistry(str(tmp_path / "reg")),
                         policy="least_loaded", queue_cap=8)
    scaler = Autoscaler(min_replicas=1, max_replicas=2,
                        window_s=8.0 * fleet.tick_s,
                        cooldown_s=8.0 * fleet.tick_s,
                        up_windows=1, down_windows=2,
                        queue_high=1.0, util_low=0.6, queue_low=0.75)
    fleet.attach_autoscaler(scaler)
    gen = BurstyTraffic(seed=2, vocab_size=cfg.vocab_size, arrival_rate=0.3,
                        burst_rate=3.0, burst_every_ticks=40.0,
                        burst_len_ticks=10.0, offset_ticks=4.0,
                        tick_s=fleet.tick_s, short_lens=(3, 6),
                        long_lens=(8, 12), new_tokens=(2, 4), prompt_cap=12)
    n = 30
    summary = fleet.serve(gen.trace(n))
    assert summary["completed"] + summary["shed"] == n   # zero drops
    assert summary["completed"] > 0
    assert summary["schedule_mismatches"] == 0
    ups = [e for e in summary["scale_events"] if e["action"] == "join"]
    assert len(ups) >= 1                                  # the burst scaled us
    assert summary["autoscaler"]["evaluations"] > 0
    assert summary["replica_seconds"] > 0
    # every decision during a cooldown held (no flapping)
    last = None
    for d in scaler.decisions:
        if last is not None and d.t - last < scaler.cooldown_s:
            assert d.action == "hold"
        if d.action != "hold":
            last = d.t
    fleet.close()
