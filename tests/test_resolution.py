"""Resolution pipeline: stage order, per-tier accounting, generation-keyed
memoization (+ migration), and execution plans."""
import dataclasses
import json
import threading

import pytest

from repro.core.database import Record
from repro.core.resolution import (
    DefaultStage,
    ResolutionPipeline,
    ServiceStage,
    StaticMapStage,
    plan_model,
    plan_uses,
)
from repro.core.runner import AnalyticalRunner, CachedRunner
from repro.core.schedule import Schedule, default_schedule
from repro.core.workload import KernelInstance, KernelUse
from repro.kernels.ops import ScheduleProvider
from repro.service import ScheduleRegistry, TuningService


def make_instance(m=64, n=64, k=64, dtype="float32"):
    return KernelInstance.make("matmul", M=m, N=n, K=k, dtype=dtype)


def make_schedule(tm=32, tn=32, tk=32, **kw):
    return Schedule.make("matmul", tiles={"M": tm, "N": tn, "K": tk}, **kw)


def make_service(tmp_path, name="svc", **kw):
    registry = ScheduleRegistry(str(tmp_path / name))
    kw.setdefault("runner", CachedRunner(AnalyticalRunner()))
    kw.setdefault("max_workers", 0)
    kw.setdefault("probe_candidates", 0)
    return registry, TuningService(registry, model_id="serving", **kw)


def publish(registry, inst, sched, seconds=1e-6, model_id="donor",
            target="tpu-v5e", mode="strict"):
    registry.publish([Record(instance=inst, schedule=sched, seconds=seconds,
                             model_id=model_id, target=target)], mode=mode)


# ---------------------------------------------------------------------------
# Stage order + per-tier accounting
# ---------------------------------------------------------------------------


def test_stage_order_service_beats_static_beats_default(tmp_path):
    inst = make_instance()
    svc_sched = make_schedule(32, 32, 32)
    static_sched = make_schedule(16, 16, 16)
    registry, service = make_service(tmp_path)
    publish(registry, inst, svc_sched)

    pipe = ResolutionPipeline.build(
        schedule_map={inst.workload_key(): static_sched}, service=service)
    res = pipe.resolve(inst)
    assert res.tier == "exact" and res.schedule == svc_sched

    pipe_static = ResolutionPipeline.build(
        schedule_map={inst.workload_key(): static_sched})
    res = pipe_static.resolve(inst)
    assert res.tier == "static" and res.schedule == static_sched

    pipe_empty = ResolutionPipeline.build()
    res = pipe_empty.resolve(inst)
    assert res.tier == "default"
    assert res.schedule == default_schedule(inst)


def test_default_tier_service_answer_is_not_a_hit(tmp_path):
    """A service lookup answering the untuned-default tier falls through and
    is counted as a default resolution, never exact/transfer (the old
    provider's hit/miss pair conflated this)."""
    inst = make_instance()
    _, service = make_service(tmp_path)  # empty registry: every lookup misses
    provider = ScheduleProvider(service=service)
    provider.get(inst)
    stats = provider.stats()
    assert stats["served_exact"] == 0
    assert stats["served_transfer"] == 0
    assert stats["served_default"] == 1
    assert provider.hits == 0 and provider.misses == 1


def test_per_tier_counts_reported(tmp_path):
    inst_hit, inst_miss = make_instance(64), make_instance(128)
    registry, service = make_service(tmp_path)
    publish(registry, inst_hit, make_schedule())
    pipe = ResolutionPipeline.build(service=service)
    pipe.resolve(inst_hit)
    pipe.resolve(inst_miss)
    stats = pipe.stats()
    assert stats["served_exact"] == 1
    assert stats["served_default"] == 1
    assert stats["resolves"] == 2


# ---------------------------------------------------------------------------
# Memo cache: steady state, invalidation, migration
# ---------------------------------------------------------------------------


def test_steady_state_is_one_dict_hit(tmp_path):
    inst = make_instance()
    registry, service = make_service(tmp_path)
    publish(registry, inst, make_schedule())
    pipe = ResolutionPipeline.build(service=service)
    first = pipe.resolve(inst)
    for _ in range(5):
        assert pipe.resolve(inst) is first
    stats = pipe.stats()
    assert stats["cache_misses"] == 1 and stats["cache_hits"] == 5
    # the service was consulted exactly once — repeats never touch its lock
    assert service.stats()["lookups"] == 1


def test_generation_bump_invalidates_and_upgrades(tmp_path):
    inst = make_instance()
    registry, service = make_service(tmp_path)
    pipe = ResolutionPipeline.build(service=service)
    assert pipe.resolve(inst).tier == "default"

    better = make_schedule()
    publish(registry, inst, better)  # external writer: generation bump
    res = pipe.resolve(inst)
    assert res.tier == "exact" and res.schedule == better
    assert res.generation == pipe.generation()


def test_changed_since_migrates_unchanged_entries(tmp_path):
    inst_a, inst_b = make_instance(64), make_instance(128)
    registry, service = make_service(tmp_path)
    pipe = ResolutionPipeline.build(service=service)
    pipe.resolve(inst_a)
    pipe.resolve(inst_b)

    # Publish through the service: the pipeline can attribute the bump.
    sched = make_schedule(64, 64, 64)
    service._publish(inst_a, sched,
                     service.runner.seconds(inst_a, sched), "donor")
    assert pipe.resolve(inst_a).tier == "exact"
    stats = pipe.stats()
    assert stats["migrated"] >= 1          # inst_b carried across generations
    assert stats["invalidations"] == 0     # no full clear
    # migrated entry still serves without re-walking stages
    lookups_before = service.stats()["lookups"]
    assert pipe.resolve(inst_b).tier == "default"
    assert service.stats()["lookups"] == lookups_before


def test_two_generation_bearing_stages_attribute_independently(tmp_path):
    """Each stage's changed_since is asked against its OWN last generation:
    with two service stages, a publish through either invalidates exactly
    that workload (summed generations would misattribute the bump)."""
    inst = make_instance()
    _, svc_a = make_service(tmp_path, "a")
    _, svc_b = make_service(tmp_path, "b")
    pipe = ResolutionPipeline([ServiceStage(svc_a), ServiceStage(svc_b),
                               DefaultStage()])
    assert pipe.resolve(inst).tier == "default"

    sched = make_schedule()
    svc_b._publish(inst, sched, svc_b.runner.seconds(inst, sched), "donor")
    res = pipe.resolve(inst)
    assert res.tier == "exact" and res.schedule == sched
    assert pipe.stats()["invalidations"] == 0  # attributed, not cleared


def test_external_publish_clears_cache_conservatively(tmp_path):
    inst_a, inst_b = make_instance(64), make_instance(128)
    registry, service = make_service(tmp_path)
    pipe = ResolutionPipeline.build(service=service)
    pipe.resolve(inst_a)
    pipe.resolve(inst_b)
    publish(registry, inst_a, make_schedule())  # bypasses the service
    pipe.resolve(inst_b)
    stats = pipe.stats()
    assert stats["invalidations"] == 1 and stats["migrated"] == 0


# ---------------------------------------------------------------------------
# Cache-key dimensions: mode / target / generation
# ---------------------------------------------------------------------------


def test_cache_key_mode_dimension():
    inst = make_instance(64, 64, 64)
    # 48 does not divide 64 on the reduction axis: strict-invalid, adaptive ok
    sched = make_schedule(32, 32, 48)
    pipe = ResolutionPipeline.build(
        schedule_map={inst.workload_key(): sched})
    assert pipe.resolve(inst, mode="strict").tier == "default"
    assert pipe.resolve(inst, mode="adaptive").tier == "static"
    keys = set(pipe._cache)
    assert (inst.workload_key(), "strict", pipe.target, 0) in keys
    assert (inst.workload_key(), "adaptive", pipe.target, 0) in keys


def test_cache_key_target_dimension(tmp_path):
    inst = make_instance()
    registry = ScheduleRegistry(str(tmp_path / "reg"))
    publish(registry, inst, make_schedule(), target="tpu-v5e")
    runner_kw = dict(max_workers=0, probe_candidates=0)
    svc_server = TuningService(registry, target="tpu-v5e", **runner_kw)
    svc_edge = TuningService(registry, target="tpu-v5e-lite", **runner_kw)
    pipe_server = ResolutionPipeline.build(service=svc_server)
    pipe_edge = ResolutionPipeline.build(service=svc_edge)
    assert pipe_server.target == "tpu-v5e"
    assert pipe_edge.target == "tpu-v5e-lite"
    # a record tuned for the server chip never serves the edge namespace
    assert pipe_server.resolve(inst).tier == "exact"
    assert pipe_edge.resolve(inst).tier == "default"
    assert next(iter(pipe_edge._cache))[2] == "tpu-v5e-lite"


def test_cache_key_generation_dimension(tmp_path):
    inst = make_instance()
    registry, service = make_service(tmp_path)
    pipe = ResolutionPipeline.build(service=service)
    pipe.resolve(inst)
    g0 = pipe.generation()
    publish(registry, inst, make_schedule())
    pipe.resolve(inst)
    g1 = pipe.generation()
    assert g1 > g0
    assert all(key[3] == g1 for key in pipe._cache)  # stale keys pruned


# ---------------------------------------------------------------------------
# Thread safety
# ---------------------------------------------------------------------------


def test_concurrent_resolution_accounting(tmp_path):
    instances = [make_instance(64 * (i + 1)) for i in range(4)]
    registry, service = make_service(tmp_path)
    publish(registry, instances[0], make_schedule())
    pipe = ResolutionPipeline.build(service=service)
    errors = []

    def worker():
        try:
            for _ in range(50):
                for inst in instances:
                    pipe.resolve(inst)
        except Exception as e:  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    stats = pipe.stats()
    assert stats["resolves"] == 8 * 50 * len(instances)
    assert sum(stats[f"served_{t}"] for t in
               ("exact", "transfer", "static", "default")) == stats["resolves"]


# ---------------------------------------------------------------------------
# Execution plans
# ---------------------------------------------------------------------------


def test_plan_model_covers_and_matches_pipeline(tmp_path):
    registry, service = make_service(tmp_path)
    pipe = ResolutionPipeline.build(service=service)
    plan = plan_model("minitron-4b", pipe, "train_4k", dp=16, tp=16)
    assert len(plan) == len(plan.uses) > 0
    assert sum(plan.tier_counts().values()) == len(plan)
    for u, res in plan.items():
        direct = pipe.resolve(u.instance)
        assert (json.dumps(res.schedule.to_json(), sort_keys=True)
                == json.dumps(direct.schedule.to_json(), sort_keys=True))
    assert plan.generation == pipe.generation()


def test_plan_refresh_picks_up_upgrade_and_keeps_old_plan_frozen(tmp_path):
    registry, service = make_service(tmp_path)
    pipe = ResolutionPipeline.build(service=service)
    uses = [KernelUse(make_instance())]
    plan = plan_uses(uses, pipe)
    inst = uses[0].instance
    assert plan.lookup(inst).tier == "default"

    better = make_schedule()
    publish(registry, inst, better)
    plan2 = plan.refresh(pipe)
    assert plan.lookup(inst).tier == "default"      # old plan untouched
    assert plan2.lookup(inst).tier == "exact"
    assert plan2.lookup(inst).schedule == better
    assert plan2.generation > plan.generation


def test_provider_consults_plan_before_pipeline(tmp_path):
    registry, service = make_service(tmp_path)
    pipe = ResolutionPipeline.build(service=service)
    inst = make_instance()
    plan = plan_uses([KernelUse(inst)], pipe)
    provider = ScheduleProvider(pipeline=pipe, plan=plan)
    lookups = service.stats()["lookups"]
    cs = provider.get(inst)
    assert provider.plan_hits == 1
    assert service.stats()["lookups"] == lookups    # plan hit: no service call
    assert cs.schedule == plan.lookup(inst).schedule
    # a default-tier plan answer is an untuned kernel, not a hit (misses
    # count the planning-time pipeline resolve plus the plan-served call)
    assert provider.hits == 0 and provider.misses == 2
    # unplanned instance falls back to the pipeline (and the gap is counted)
    other = make_instance(256)
    provider.get(other)
    assert provider.plan_hits == 1
    assert provider.stats()["plan_misses"] == 1
    assert provider.stats()["served_default"] >= 1

    # after an upgrade, an exact-tier plan answer does count as a hit
    publish(registry, inst, make_schedule())
    provider.plan = plan.refresh(pipe)
    provider.get(inst)
    assert provider.stats()["plan_served"]["exact"] == 1
    assert provider.hits == 2  # the re-planning resolve + the plan-served call


# ---------------------------------------------------------------------------
# Service generation / changed-workload notification
# ---------------------------------------------------------------------------


def test_service_generation_and_changed_since(tmp_path):
    inst = make_instance()
    registry, service = make_service(tmp_path)
    g0 = service.generation()
    assert service.changed_since(g0) == set()

    sched = make_schedule()
    service._publish(inst, sched, service.runner.seconds(inst, sched), "donor")
    g1 = service.generation()
    assert g1 > g0
    assert service.changed_since(g0) == {inst.workload_key()}
    assert service.changed_since(g1) == set()

    publish(registry, make_instance(128), make_schedule())  # external writer
    assert service.changed_since(g0) is None
    assert service.changed_since(g1) is None
