"""Observability layer: tracer invariants, metrics registry, exporters,
trace reports, and the no-perturbation guarantee for instrumented serving."""
import json

import jax
import pytest

from repro.configs import get_arch, reduced
from repro.fleet import Autoscaler, BurstyTraffic, ServingFleet, \
    TrafficGenerator
from repro.fleet.metrics import FleetMetrics
from repro.models import build_model
from repro.obs import (
    NULL_TRACER,
    CounterGroup,
    MetricsRegistry,
    Tracer,
    percentile,
)
from repro.obs import report as obs_report
from repro.obs.export import (
    chrome_trace,
    load_records,
    write_chrome_trace,
    write_jsonl,
)
from repro.service import ScheduleRegistry


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------


def test_registry_counter_gauge_histogram():
    m = MetricsRegistry()
    c = m.counter("fleet.requests")
    c.inc()
    c.inc(3)
    assert c.value == 4
    g = m.gauge("fleet.queue_depth")
    g.sample(2, 0.5)
    g.sample(5, 1.5)
    assert g.value == 5
    assert g.values(0.0, 1.0) == [2.0]       # [t0, t1) windowing
    h = m.histogram("fleet.latency_s")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    assert h.count == 4 and h.mean == 2.5
    assert h.percentile(50) == percentile([1.0, 2.0, 3.0, 4.0], 50)
    out = m.to_json()
    assert out["fleet.requests"]["kind"] == "counter"
    assert out["fleet.latency_s"]["value"]["count"] == 4
    # one name, one kind — a re-get with another kind is a bug, not a merge
    with pytest.raises(TypeError):
        m.gauge("fleet.requests")


def test_gauge_sample_requires_timestamp():
    """Unstamped gauge samples cannot be windowed — they are rejected."""
    m = MetricsRegistry()
    with pytest.raises(TypeError):
        m.gauge("g").sample(1.0, None)
    fm = FleetMetrics()
    with pytest.raises(TypeError):
        fm.sample_queue(3)          # the old now=0.0 default is gone
    fm.sample_queue(3, 1.25)
    assert fm.queue_samples == [(1.25, 3.0)]


def test_counter_group_is_dict_compatible():
    """CounterGroup is the migration path for the legacy stats dicts."""
    m = MetricsRegistry()
    g = CounterGroup(m, "tuning.tpu", ["lookups", "exact_hits"])
    g["lookups"] += 2
    g.inc("exact_hits")
    assert g["lookups"] == 2 and "exact_hits" in g
    assert dict(g) == {"lookups": 2, "exact_hits": 1}
    # the registry holds the same numbers under the namespaced names
    assert m.counter("tuning.tpu.lookups").value == 2


def test_percentile_is_shared_single_implementation():
    import benchmarks.common as bc
    import repro.fleet.metrics as fm
    assert fm.percentile is percentile
    assert bc.percentile is percentile
    assert percentile([], 95) == 0.0


def test_percentile_edge_cases_pinned():
    """Empty and single-sample series are pinned (SLO burn math and ledger
    ratios divide by these): empty -> 0.0 for every q, one sample -> that
    sample bit-exactly, bypassing interpolation arithmetic."""
    for q in (0, 50, 95, 99, 100):
        assert percentile([], q) == 0.0
    v = 0.1 + 0.2                      # not representable as exactly 0.3
    for q in (0, 37.5, 50, 95, 100):
        assert percentile([v], q) == v
    h = MetricsRegistry().histogram("h")
    assert h.percentile(95) == 0.0
    h.observe(v)
    assert h.percentile(5) == v and h.percentile(95) == v


def test_gauge_values_window_boundaries():
    """``values(t0, t1)`` is half-open [t0, t1): a sample landing exactly on
    a window boundary belongs to the later window, never to both — the
    invariant that makes adjacent autoscaler windows partition a run."""
    g = MetricsRegistry().gauge("g")
    for t in (0.0, 1.0, 2.0):
        g.sample(t * 10, t)
    assert g.values(0.0, 1.0) == [0.0]
    assert g.values(1.0, 2.0) == [10.0]
    assert g.values(0.0, 2.0) + g.values(2.0, 4.0) == g.values()
    assert g.values(2.0, 2.0) == []
    assert g.values(t1=1.0) == [0.0]   # open start defaults to -inf


# ---------------------------------------------------------------------------
# Tracer invariants
# ---------------------------------------------------------------------------


def test_span_nesting_and_timestamp_invariants():
    t = {"v": 0.0}
    tr = Tracer(clock=lambda: t["v"])
    with tr.span("outer", "eng", uid=1) as outer:
        t["v"] = 1.0
        with tr.span("inner", "eng") as inner:
            t["v"] = 2.0
        t["v"] = 3.0
    o, i = tr.spans[outer.index], tr.spans[inner.index]
    assert o.parent is None and i.parent == outer.index
    assert (o.t0, o.t1) == (0.0, 3.0)
    assert (i.t0, i.t1) == (1.0, 2.0)
    assert o.t0 <= i.t0 and i.t1 <= o.t1      # children nest
    assert o.attrs == {"uid": 1}
    with pytest.raises(ValueError):
        tr.add_span("bad", "eng", 2.0, 1.0)   # time cannot run backwards


def test_tracks_keep_registration_order():
    tr = Tracer(clock=lambda: 0.0)
    for name in ("replica-0", "router", "replica-0", "autoscaler"):
        tr.track(name)
    assert tr.tracks() == ["replica-0", "router", "autoscaler"]


def test_null_tracer_is_inert():
    assert NULL_TRACER.enabled is False
    assert NULL_TRACER.add_span("x", "t", 0.0, 1.0) == -1
    NULL_TRACER.event("x", "t")
    with NULL_TRACER.span("x", "t"):
        pass
    assert NULL_TRACER.spans == [] and NULL_TRACER.events == []


# ---------------------------------------------------------------------------
# Exporters
# ---------------------------------------------------------------------------


def _sample_tracer() -> Tracer:
    tr = Tracer(clock=lambda: 0.0)
    p = tr.add_span("step", "replica-0", 0.0, 3.0, n=2)
    tr.add_span("chunk", "replica-0", 0.0, 2.0, parent=p, len=8)
    tr.add_async_span("request", "replica-0", 0.5, 2.5, "request", "7",
                      uid=7, latency_s=2.0)
    tr.event("shed", "router", t=1.0, uid=9, reason="queue_full")
    return tr


def test_chrome_trace_shape_and_roundtrip(tmp_path):
    tr = _sample_tracer()
    doc = chrome_trace(tr)
    ev = doc["traceEvents"]
    names = {r["args"]["name"] for r in ev
             if r["ph"] == "M" and r["name"] == "thread_name"}
    assert {"replica-0", "router"} <= names
    ts = [r["ts"] for r in ev if "ts" in r]
    assert ts == sorted(ts)                   # monotone export order
    xs = [r for r in ev if r["ph"] == "X"]
    assert {r["name"] for r in xs} == {"step", "chunk"}
    assert any(r["ph"] == "b" for r in ev) and any(r["ph"] == "e" for r in ev)

    path = str(tmp_path / "trace.json")
    write_chrome_trace(path, tr)
    recs = load_records(path)
    spans = [r for r in recs if r["kind"] == "span"]
    req = next(r for r in spans if r.get("cat") == "request")
    assert req["t0"] == pytest.approx(0.5) and req["t1"] == pytest.approx(2.5)
    assert req["attrs"]["uid"] == 7
    evs = [r for r in recs if r["kind"] == "event"]
    assert evs[0]["name"] == "shed" and evs[0]["attrs"]["reason"] == "queue_full"


def test_jsonl_roundtrip_matches_chrome(tmp_path):
    tr = _sample_tracer()
    jl = str(tmp_path / "trace.jsonl")
    ch = str(tmp_path / "trace.json")
    write_jsonl(jl, tr)
    write_chrome_trace(ch, tr)
    a = sorted(load_records(jl), key=lambda r: json.dumps(r, sort_keys=True))
    b = sorted(load_records(ch), key=lambda r: json.dumps(r, sort_keys=True))
    assert len(a) == len(b)
    for ra, rb in zip(a, b):
        assert ra["kind"] == rb["kind"] and ra["name"] == rb["name"]
        assert ra["attrs"] == rb["attrs"]


def test_load_records_bitexact_across_formats(tmp_path):
    """The two export formats fold back to *identical* records — floats
    included.  The Chrome file's exact-seconds sidecar keys (``ts_s`` /
    ``t1_s``) make the microsecond ``ts`` rounding irrelevant, which is what
    lets the critical-path profiler reproduce FleetMetrics' percentiles from
    either file."""
    tr = Tracer(clock=lambda: 0.0)
    t0, t1 = 1.0 / 3.0, 0.1 + 0.2          # awkward after a x1e6 round-trip
    p = tr.add_span("step", "replica-0", t0, 7 * t1, n=2)
    tr.add_span("verify", "replica-0", 2 * t0, 5 * t1, parent=p)
    tr.add_async_span("request", "replica-0", t0, 6 * t1, "request", "1",
                      uid=1, latency_s=6 * t1 - t0)
    tr.event("cell_workloads", "replica-0", t=t0, cell="verify",
             workloads=[["wk", 0.1]])
    ch, jl = str(tmp_path / "t.json"), str(tmp_path / "t.jsonl")
    write_chrome_trace(ch, tr)
    write_jsonl(jl, tr)

    def key(r):
        return json.dumps(r, sort_keys=True)

    a = sorted(load_records(ch), key=key)
    b = sorted(load_records(jl), key=key)
    assert a == b                           # full records, bit-exact
    v = next(r for r in a if r["kind"] == "span" and r["name"] == "verify")
    assert v["t0"] == 2 * t0 and v["t1"] == 5 * t1
    req = next(r for r in a if r.get("cat") == "request")
    assert req["attrs"]["latency_s"] == 6 * t1 - t0


# ---------------------------------------------------------------------------
# Trace report on a golden fixture
# ---------------------------------------------------------------------------


def _golden_tracer() -> Tracer:
    """Two served requests + one shed, two tuning jobs, a scale decision."""
    tr = Tracer(clock=lambda: 0.0)
    for uid, (arr, adm, pd, fin) in {
            "1": (0.0, 1.0, 2.0, 6.0),
            "2": (1.0, 1.5, 3.0, 9.0)}.items():
        tr.add_async_span("request", "replica-0", arr, fin, "request", uid,
                          uid=int(uid))
        tr.add_async_span("queue", "replica-0", arr, adm, "request", uid)
        tr.add_async_span("prefill", "replica-0", adm, pd, "request", uid)
        tr.add_async_span("decode", "replica-0", pd, fin, "request", uid)
    tr.event("shed", "router", t=2.0, uid=3, reason="queue_full")
    for t, tier in ((0.5, "default"), (4.0, "default"), (8.0, "exact")):
        tr.event("lookup", "tuning/tpu-v5e", t=t, key="k", tier=tier,
                 generation=0)
    tr.add_async_span("tune", "tuning/tpu-v5e", 2.0, 5.0, "tune", "k",
                      key="k", search_s=3.0)
    tr.event("scale_decision", "autoscaler", t=4.0, action="up",
             reason="queue", replicas=1)
    tr.event("join", "autoscaler", t=4.0, replica=1, target="tpu-v5e")
    return tr


def test_trace_report_golden_numbers(tmp_path):
    path = str(tmp_path / "golden.jsonl")
    write_jsonl(path, _golden_tracer())
    s = obs_report.summarize(load_records(path), windows=2)

    lat = s["latency"]
    assert lat["requests"] == 2 and lat["shed"] == 1
    # request 1: latency 6, queue 1, prefill 1, decode 4
    # request 2: latency 8, queue 0.5, prefill 1.5, decode 6
    assert lat["latency_s"]["mean"] == pytest.approx(7.0)
    assert lat["queue_s"]["mean"] == pytest.approx(0.75)
    assert lat["ttft_s"]["mean"] == pytest.approx((2.0 + 2.0) / 2)
    assert lat["decode_s"]["mean"] == pytest.approx(5.0)
    assert lat["latency_s"]["p95"] == percentile([6.0, 8.0], 95)

    shares = s["tier_shares"]
    assert len(shares) == 2
    assert shares[0]["shares"] == {"default": 1.0}      # t in [0.5, 4.25)
    assert shares[1]["shares"] == {"exact": 1.0}        # the late lookup

    jobs = s["tuning_jobs"]
    assert len(jobs) == 1
    assert jobs[0]["key"] == "k" and jobs[0]["duration_s"] == pytest.approx(3.0)

    names = [e["name"] for e in s["scale_timeline"]]
    assert names == ["scale_decision", "join"]


def test_trace_report_cli_formats(tmp_path, capsys):
    from repro.launch import trace_report

    path = str(tmp_path / "golden.jsonl")
    write_jsonl(path, _golden_tracer())
    out = trace_report.main([path])
    text = capsys.readouterr().out
    assert "latency breakdown" in text and "scale timeline" in text
    assert out["latency"]["requests"] == 2
    out2 = trace_report.main([path, "--json"])
    assert json.loads(capsys.readouterr().out) is not None
    assert out2["latency"] == out["latency"]


# ---------------------------------------------------------------------------
# Instrumented serving (real fleet)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_lm():
    cfg = reduced(get_arch("minitron-4b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _serve(cfg, model, params, tracer, registry=None, **kw):
    fleet = ServingFleet(cfg, model, params, replicas=2, slots=2, max_len=32,
                         registry=registry, policy="least_loaded",
                         queue_cap=8, tracer=tracer, **kw)
    gen = TrafficGenerator(seed=3, vocab_size=cfg.vocab_size,
                           arrival_rate=1.2, tick_s=fleet.tick_s,
                           short_lens=(3, 6), long_lens=(8, 12),
                           new_tokens=(2, 4), prompt_cap=12)
    summary = fleet.serve(gen.trace(12))
    fleet.close()
    return fleet, summary


def test_disabled_tracer_serving_output_is_byte_identical(small_lm):
    """The no-op default must not perturb serving at all: the summary JSON
    of an untraced run and a traced run are byte-identical."""
    cfg, model, params = small_lm
    _, off = _serve(cfg, model, params, None)
    _, on = _serve(cfg, model, params, Tracer())
    assert json.dumps(off, sort_keys=True) == json.dumps(on, sort_keys=True)


def test_fleet_trace_spans_nest_and_stay_monotone(small_lm, tmp_path):
    cfg, model, params = small_lm
    tracer = Tracer()
    fleet, summary = _serve(cfg, model, params, tracer,
                            registry=ScheduleRegistry(str(tmp_path / "reg")))
    eps = 1e-9
    by_track: dict = {}
    for s in tracer.spans:
        assert s.t1 >= s.t0 - eps
        if s.cat is None and s.parent is None:
            by_track.setdefault(s.track, []).append(s)
        if s.parent is not None:                # children nest in the parent
            p = tracer.spans[s.parent]
            assert p.t0 - eps <= s.t0 and s.t1 <= p.t1 + eps
    for track, spans in by_track.items():       # replicas are serial
        spans.sort(key=lambda s: s.t0)
        for a, b in zip(spans, spans[1:]):
            assert b.t0 >= a.t1 - eps, f"overlap on {track}"

    # the trace reproduces the fleet's percentiles exactly
    path = str(tmp_path / "trace.json")
    write_chrome_trace(path, tracer)
    rep = obs_report.summarize(load_records(path))
    assert rep["latency"]["requests"] == summary["completed"]
    for q in ("p50", "p95", "p99"):
        assert rep["latency"]["latency_s"][q] == \
            pytest.approx(summary["latency_s"][q], rel=1e-9)


def test_autoscaled_run_traces_scale_decisions(small_lm, tmp_path):
    """Acceptance path: an autoscaled bursty run leaves the scale-up
    decision and the warm-join visible in the trace."""
    cfg, model, params = small_lm
    tracer = Tracer()
    fleet = ServingFleet(cfg, model, params, replicas=1, slots=2, max_len=32,
                         registry=ScheduleRegistry(str(tmp_path / "reg")),
                         policy="least_loaded", queue_cap=8, tracer=tracer)
    fleet.attach_autoscaler(Autoscaler(
        min_replicas=1, max_replicas=2, window_s=8.0 * fleet.tick_s,
        cooldown_s=8.0 * fleet.tick_s, up_windows=1, down_windows=2,
        queue_high=1.0, util_low=0.6, queue_low=0.75))
    gen = BurstyTraffic(seed=2, vocab_size=cfg.vocab_size, arrival_rate=0.3,
                        burst_rate=3.0, burst_every_ticks=40.0,
                        burst_len_ticks=10.0, offset_ticks=4.0,
                        tick_s=fleet.tick_s, short_lens=(3, 6),
                        long_lens=(8, 12), new_tokens=(2, 4), prompt_cap=12)
    summary = fleet.serve(gen.trace(30))
    fleet.close()
    assert any(e["action"] == "join" for e in summary["scale_events"])

    path = str(tmp_path / "trace.json")
    write_chrome_trace(path, tracer)
    timeline = obs_report.scale_timeline(load_records(path))
    names = [e["name"] for e in timeline]
    assert "scale_decision" in names and "join" in names
    ups = [e for e in timeline
           if e["name"] == "scale_decision" and e["action"] == "up"]
    assert len(ups) >= 1
    # autoscaler counters live in the fleet-wide registry after bind_obs
    assert fleet.obs.counter("autoscaler.scale_ups").value >= 1
    # decisions and joins appear in virtual-time order
    ts = [e["t"] for e in timeline]
    assert ts == sorted(ts)
