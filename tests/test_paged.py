"""Paged KV cache: page-table bookkeeping, continuous-batching semantics,
and numerical equivalence with the contiguous (slot) engine."""
import jax
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import build_model
from repro.serving import (
    PagedServingEngine,
    PagesExhausted,
    PageTable,
    ServingEngine,
    SlotsFull,
)


@pytest.fixture(scope="module")
def small_lm():
    cfg = reduced(get_arch("minitron-4b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


# ---------------------------------------------------------------------------
# PageTable (pure bookkeeping, no jax)
# ---------------------------------------------------------------------------


def test_pagetable_alloc_accounting_and_determinism():
    t = PageTable(9, 4)  # 8 usable pages of 4 slots
    assert t.usable_pages == 8 and t.capacity_tokens == 32
    assert t.pages_for(0) == 0 and t.pages_for(1) == 1
    assert t.pages_for(4) == 1 and t.pages_for(5) == 2
    assert t.ensure(1, 6) == [1, 2]    # lowest-numbered free pages first
    assert t.ensure(1, 6) == []        # already covered: no-op
    assert t.ensure(1, 9) == [3]       # grows by exactly the shortfall
    assert t.ensure(2, 3) == [4]
    assert t.used_pages == 4 and t.free_pages == 4
    assert t.holders() == [1, 2]
    assert t.held_tokens(1) == 12


def test_pagetable_exhaustion_is_atomic():
    t = PageTable(4, 2)  # 3 usable pages
    t.ensure(1, 4)       # takes 2
    with pytest.raises(PagesExhausted):
        t.ensure(2, 6)   # needs 3, only 1 free
    assert t.free_pages == 1        # nothing was allocated
    assert 2 not in t.holders()     # the failed uid holds nothing
    t.ensure(2, 2)                  # the remaining page still works
    with pytest.raises(PagesExhausted):
        t.ensure(1, 6)              # growth failure keeps existing pages
    assert t.pages(1) == [1, 2]


def test_pagetable_release_reuses_lowest_first():
    t = PageTable(5, 2)
    t.ensure(1, 2)
    t.ensure(2, 2)
    t.ensure(3, 2)
    assert t.release(2) == 1
    assert t.release(2) == 0        # double release is a no-op
    assert t.ensure(4, 2) == [2]    # freed page is the lowest available
    assert t.releases == 1 and t.allocs == 4


def test_flat_rows_maps_overflow_to_trash_page():
    t = PageTable(6, 4)
    t.ensure(7, 6)  # pages [1, 2]
    rows = t.flat_rows(7, 16)
    assert list(rows[:4]) == [4, 5, 6, 7]       # page 1
    assert list(rows[4:8]) == [8, 9, 10, 11]    # page 2
    assert list(rows[8:]) == [0] * 8            # beyond allocation: trash
    assert list(t.flat_rows(99, 4)) == [0] * 4  # unknown uid: all trash


def test_fragmentation_gauge_and_defrag():
    t = PageTable(9, 2)
    for uid in range(1, 5):
        t.ensure(uid, 4)  # pages 1..8 across 4 uids
    for uid in (1, 3):
        t.release(uid)    # free list {1,2,5,6}: two runs of two
    assert t.fragmentation() == pytest.approx(0.5)
    before = {uid: t.flat_rows(uid, 4).copy() for uid in (2, 4)}
    moves = t.defrag()
    assert moves and all(src > dst for src, dst in moves)
    assert t.fragmentation() == 0.0  # free space is one contiguous block
    assert sorted(p for uid in t.holders() for p in t.pages(uid)) == [1, 2, 3, 4]
    for uid in (2, 4):  # per-request page ORDER preserved: rows stay aligned
        assert len(t.flat_rows(uid, 4)) == len(before[uid])


# ---------------------------------------------------------------------------
# Engine: admission, exact token counts, pool pressure
# ---------------------------------------------------------------------------


def _engine(model, params, **kw):
    kw.setdefault("decode_batch", 2)
    kw.setdefault("max_ctx", 32)
    kw.setdefault("page_size", 4)
    kw.setdefault("chunk", 8)
    return PagedServingEngine(model, params, **kw)


def test_rejects_oversize_and_admission_cap(small_lm):
    cfg, model, params = small_lm
    eng = _engine(model, params, admit_cap=2)
    with pytest.raises(ValueError, match="max_ctx"):
        eng.add_request(list(range(1, 30)), max_new_tokens=8)
    eng.add_request([1, 2, 3], max_new_tokens=2)
    eng.add_request([4, 5], max_new_tokens=2)
    with pytest.raises(SlotsFull):
        eng.add_request([6], max_new_tokens=1)
    assert not eng.free_slots
    eng.run_to_completion()
    assert not eng.active and eng.table.used_pages == 0


def test_request_larger_than_pool_rejected(small_lm):
    cfg, model, params = small_lm
    eng = _engine(model, params, pool_pages=3)  # 2 usable pages = 8 tokens
    with pytest.raises(ValueError, match="pages"):
        eng.add_request(list(range(1, 10)), max_new_tokens=4)
    assert not eng.active


def test_max_new_tokens_exact_and_chunked_prefill_progress(small_lm):
    """mnt=N yields exactly N tokens; a prompt longer than ``chunk``
    prefills across several steps without blocking the other lane."""
    cfg, model, params = small_lm
    eng = _engine(model, params, chunk=4)
    long = eng.add_request(list(range(1, 14)), max_new_tokens=3)   # 4 chunks
    short = eng.add_request([7, 8], max_new_tokens=3)
    eng.step()
    assert eng._off[long.uid] == 4          # one chunk of progress
    assert short.generated                  # short prompt already emitted
    eng.run_to_completion()
    assert long.done and len(long.generated) == 3
    assert short.done and len(short.generated) == 3
    assert eng.prefill_true_tokens == eng.prefill_padded_tokens  # no padding


def test_oversubscribed_pool_preempts_and_still_completes(small_lm):
    """More concurrent footprint than the pool holds: the engine evicts
    youngest decoders (recompute-on-resume) and every request still
    finishes with its exact token count."""
    cfg, model, params = small_lm
    eng = _engine(model, params, decode_batch=4, page_size=2,
                  pool_pages=13, chunk=8)  # 24 usable tokens for 4 lanes
    reqs = [eng.add_request([i + 1] * 5, max_new_tokens=6) for i in range(4)]
    eng.run_to_completion(max_steps=256)
    assert all(r.done and len(r.generated) == 6 for r in reqs)
    assert eng.preemptions > 0
    assert eng.table.used_pages == 0


def test_admission_gate_holds_fifo_until_pages_free(small_lm):
    """The watermark gate: a request whose prompt cannot fit on top of
    worst-case decode growth stays queued — and later arrivals never jump
    it (FIFO)."""
    cfg, model, params = small_lm
    eng = _engine(model, params, decode_batch=3, page_size=2,
                  pool_pages=8, chunk=16)  # 14 usable tokens
    a = eng.add_request([1] * 10, max_new_tokens=2)
    b = eng.add_request([2] * 10, max_new_tokens=2)   # cannot fit beside a
    c = eng.add_request([3, 4], max_new_tokens=2)     # could fit, but FIFO
    plan = eng.planned_work()
    assert plan["admits"] == 1
    eng.step()
    laned = [r.uid for r in eng.lanes if r is not None]
    assert laned == [a.uid]
    assert [r.uid for r in eng.waiting] == [b.uid, c.uid]
    eng.run_to_completion(max_steps=256)
    assert a.done and b.done and c.done


def test_partial_chunk_advances_under_page_pressure(small_lm):
    """When free pages cannot hold a whole chunk the schedule shrinks the
    chunk instead of stalling the prefill queue behind it."""
    cfg, model, params = small_lm
    eng = _engine(model, params, decode_batch=2, page_size=2,
                  pool_pages=10, chunk=8)  # 18 usable tokens
    a = eng.add_request([1] * 16, max_new_tokens=2)
    eng.step()                  # full first chunk: 8 tokens = 4 pages
    assert eng._off[a.uid] == 8
    eng.table.ensure(777, 8)    # external pressure: grab 4 of 5 free pages
    plan = eng.planned_work()
    assert plan["chunk_lens"] == [2]  # (4 held + 1 free) * 2 - 8 = 2 tokens
    eng.step()
    assert eng._off[a.uid] == 10      # partial progress, no stall
    eng.table.release(777)
    eng.run_to_completion(max_steps=256)
    assert a.done and len(a.generated) == 2


# ---------------------------------------------------------------------------
# Numerical equivalence with the contiguous cache
# ---------------------------------------------------------------------------


def _prompts(cfg, lens=(3, 11, 18, 6)):
    rng = np.random.default_rng(5)
    return [[int(t) for t in rng.integers(1, cfg.vocab_size, size=n)]
            for n in lens]


def _run_paged(model, params, prompts, *, fragment=False, mnt=5, **kw):
    kw.setdefault("decode_batch", len(prompts))
    kw.setdefault("max_ctx", 32)
    kw.setdefault("page_size", 4)
    kw.setdefault("chunk", 8)
    eng = PagedServingEngine(model, params, record_logits=True, **kw)
    if fragment:  # shred the free list before any real allocation
        for i in range(12):
            eng.table.ensure(900 + i, kw["page_size"])
        for i in range(0, 12, 2):
            eng.table.release(900 + i)
        assert eng.table.fragmentation() > 0.0
    reqs = [eng.add_request(p, max_new_tokens=mnt) for p in prompts]
    eng.run_to_completion(max_steps=512)
    assert all(r.done for r in reqs)
    return reqs, eng


def test_paged_matches_slot_bit_exact_global_attention(small_lm):
    """G-only arch: pages + gather/scatter + chunked prefill change nothing
    — token streams match the slot engine's exact (unbucketed) prefill."""
    cfg, model, params = small_lm
    prompts = _prompts(cfg)
    paged_reqs, _ = _run_paged(model, params, prompts)

    slot = ServingEngine(model, params, slots=len(prompts), max_len=32,
                         prefill_buckets=False)
    slot_reqs = [slot.add_request(p, max_new_tokens=5) for p in prompts]
    while slot.active:
        slot.step()
    for pr, sr in zip(paged_reqs, slot_reqs):
        assert pr.generated == sr.generated


def test_fragmented_pool_is_bit_exact_vs_contiguous(small_lm):
    """Scattered pages vs a fresh pool: identical tokens AND identical
    final-chunk logits, bitwise — the dense gather makes layout invisible."""
    cfg, model, params = small_lm
    prompts = _prompts(cfg)
    contig_reqs, contig = _run_paged(model, params, prompts)
    frag_reqs, frag = _run_paged(model, params, prompts, fragment=True)
    for cr, fr in zip(contig_reqs, frag_reqs):
        assert cr.generated == fr.generated
        assert np.array_equal(contig.chunk_logits[cr.uid],
                              frag.chunk_logits[fr.uid])


@pytest.mark.parametrize("arch", ["mixtral-8x22b", "recurrentgemma-2b"])
def test_paged_matches_slot_windowed_and_recurrent(arch):
    """Ring caches and recurrent state stay dense lane strips in the paged
    engine; chunked prefill is exact at every split, so generations match
    the slot engine (logit-level fp reordering tolerated via one decode
    step's allclose, tokens compared exactly)."""
    cfg = reduced(get_arch(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    prompts = _prompts(cfg, lens=(4, 13, 9))  # crosses the reduced window
    paged_reqs, _ = _run_paged(model, params, prompts, page_size=4, chunk=6)

    slot = ServingEngine(model, params, slots=len(prompts), max_len=32,
                         prefill_buckets=False)
    slot_reqs = [slot.add_request(p, max_new_tokens=5) for p in prompts]
    while slot.active:
        slot.step()
    for pr, sr in zip(paged_reqs, slot_reqs):
        assert pr.generated == sr.generated


def test_live_defrag_is_bit_exact(small_lm):
    """A defrag forced mid-generation moves live KV pages and changes
    nothing observable: tokens and final-chunk logits match a run that
    never defragmented, and the engine counts the compaction."""
    cfg, model, params = small_lm
    prompts = _prompts(cfg)
    base_reqs, base = _run_paged(model, params, prompts)
    assert base.defrags == 0                      # no threshold: never fires

    eng = PagedServingEngine(model, params, decode_batch=len(prompts),
                             max_ctx=32, page_size=4, chunk=8,
                             defrag_threshold=0.05, record_logits=True)
    # dummies shred the free list so real allocations land scattered
    for i in range(12):
        eng.table.ensure(900 + i, 4)
    for i in range(0, 12, 2):
        eng.table.release(900 + i)
    reqs = [eng.add_request(p, max_new_tokens=5) for p in prompts]
    for _ in range(3):
        eng.step()                                # real KV rows now exist
    # releasing the interleaved dummies mid-run re-shreds the free list:
    # the next step boundary must defrag and relocate LIVE pages
    for i in range(1, 12, 2):
        eng.table.release(900 + i)
    assert eng.table.fragmentation() > 0.05
    eng.run_to_completion(max_steps=512)
    assert eng.defrags >= 1
    assert all(r.done for r in reqs)
    for br, r in zip(base_reqs, reqs):
        assert br.generated == r.generated
        assert np.array_equal(base.chunk_logits[br.uid],
                              eng.chunk_logits[r.uid])
