"""Optimizer + gradient compression."""
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.optim import adamw, compression


def test_adamw_converges_on_quadratic():
    cfg = adamw.AdamWConfig(peak_lr=0.1, warmup_steps=5, total_steps=200,
                            weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    state = adamw.init_state(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw.apply_updates(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_lr_schedule_shape():
    cfg = adamw.AdamWConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
    lrs = [float(adamw.lr_at(cfg, jnp.asarray(s))) for s in range(101)]
    assert lrs[0] == 0.0
    assert lrs[10] == max(lrs)
    assert abs(lrs[100] - 0.1) < 1e-6
    assert all(a >= b - 1e-9 for a, b in zip(lrs[10:], lrs[11:]))  # monotone decay


def test_grad_clipping():
    cfg = adamw.AdamWConfig(clip_norm=1.0, weight_decay=0.0, peak_lr=1.0,
                            warmup_steps=0, total_steps=10)
    params = {"w": jnp.zeros(4)}
    state = adamw.init_state(params)
    huge = {"w": jnp.full(4, 1e6)}
    _, _, metrics = adamw.apply_updates(params, huge, state, cfg)
    assert float(metrics["grad_norm"]) > 1e6  # reported raw
    # after clipping the effective update magnitude is bounded by lr
    p2, _, _ = adamw.apply_updates(params, huge, state, cfg)
    assert float(jnp.abs(p2["w"]).max()) <= 10.0


def test_master_weights_f32():
    params = {"w": jnp.zeros(4, jnp.bfloat16)}
    state = adamw.init_state(params)
    assert state["master"]["w"].dtype == jnp.float32
    assert state["m"]["w"].dtype == jnp.float32


@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=4, max_size=16))
@settings(max_examples=40, deadline=None)
def test_quantize_roundtrip_bounded(vals):
    x = jnp.asarray(vals, jnp.float32)
    q, scale = compression.quantize(x)
    err = jnp.abs(compression.dequantize(q, scale) - x)
    assert float(err.max()) <= float(scale) * 0.5 + 1e-6


def test_error_feedback_preserves_sum():
    """Σ_t deq(g_t + r_t) ≈ Σ_t g_t — quantization error does not accumulate."""
    rng = np.random.default_rng(0)
    grads = [jnp.asarray(rng.normal(size=32), jnp.float32) for _ in range(50)]
    residual = jnp.zeros(32, jnp.float32)
    applied = jnp.zeros(32, jnp.float32)
    for gdrop in grads:
        q, scale, residual = compression.compress_with_feedback(gdrop, residual)
        applied = applied + compression.dequantize(q, scale)
    true_sum = sum(grads)
    # residual bounds the total deviation (one quantization step, not 50)
    np.testing.assert_allclose(applied + residual, true_sum, rtol=1e-5, atol=1e-5)
    assert float(jnp.abs(applied - true_sum).max()) < 0.2


def test_compressed_gradients_tree():
    grads = {"a": jnp.ones((3, 3)), "b": jnp.full(5, -2.0)}
    residuals = compression.init_residuals(grads)
    deq, new_r = compression.compressed_gradients(grads, residuals)
    assert jax.tree_util.tree_structure(deq) == jax.tree_util.tree_structure(grads)
    np.testing.assert_allclose(deq["a"], grads["a"], atol=0.02)
