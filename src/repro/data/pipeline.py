"""Deterministic, shard-aware token data pipeline.

Two sources:
* ``SyntheticSource`` — stateless hash-based token generation: batch at
  (step, shard) is a pure function of (seed, step, shard), so restarts and
  elastic re-sharding reproduce the exact global stream with no data state
  in checkpoints (the step number *is* the data cursor).
* ``MemmapSource``  — windows from a binary token corpus (np.memmap), with
  deterministic shuffled window order per epoch.

``Pipeline`` adds host-side background prefetch (double-buffered thread) and
splits the global batch across data shards: shard i of N reads rows
[i·B/N, (i+1)·B/N) of the global batch — on a multi-host deployment each
host feeds its addressable shard; in this single-process container the
launcher assembles all shards (same code path, N=1..n).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    source: str = "synthetic"       # "synthetic" | "memmap"
    corpus_path: str = ""
    num_shards: int = 1
    shard_index: int = 0


class SyntheticSource:
    """Pure-function token batches: counter-based PRNG (Philox) keyed by
    (seed, step, shard) — deterministic, seekable, restart-safe."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rows = cfg.global_batch // cfg.num_shards
        rng = np.random.Generator(np.random.Philox(key=cfg.seed, counter=[0, 0, step, cfg.shard_index]))
        # skewed zipf-ish distribution so models can actually learn structure
        z = rng.zipf(1.3, size=(rows, cfg.seq_len + 1)).astype(np.int64)
        tokens = (z % (cfg.vocab_size - 1)) + 1
        return {
            "tokens": tokens[:, : cfg.seq_len].astype(np.int32),
            "mask": np.ones((rows, cfg.seq_len), np.int32),
        }


class MemmapSource:
    """Windows from a flat binary int32 token file."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.tokens = np.memmap(cfg.corpus_path, dtype=np.int32, mode="r")
        self.n_windows = max(1, (len(self.tokens) - 1) // cfg.seq_len)

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rows = cfg.global_batch // cfg.num_shards
        rng = np.random.Generator(np.random.Philox(key=cfg.seed, counter=[0, 1, step, cfg.shard_index]))
        idx = rng.integers(0, self.n_windows, size=rows)
        out = np.stack([self.tokens[i * cfg.seq_len: i * cfg.seq_len + cfg.seq_len] for i in idx])
        return {"tokens": out.astype(np.int32), "mask": np.ones_like(out, np.int32)}


def make_source(cfg: DataConfig):
    if cfg.source == "memmap":
        return MemmapSource(cfg)
    return SyntheticSource(cfg)


class Pipeline:
    """Background-prefetched iterator over batches starting at `start_step`."""

    def __init__(self, cfg: DataConfig, start_step: int = 0, prefetch: int = 2):
        if cfg.global_batch % cfg.num_shards:
            raise ValueError("global_batch must divide evenly across data shards")
        self.cfg = cfg
        self.source = make_source(cfg)
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        return self

    def __next__(self) -> tuple[int, dict]:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
