from repro.data.pipeline import DataConfig, MemmapSource, Pipeline, SyntheticSource, make_source

__all__ = ["DataConfig", "MemmapSource", "Pipeline", "SyntheticSource", "make_source"]
