"""Pallas TPU kernels (pl.pallas_call + BlockSpec) for the compute hot-spots,
with ``ops.py`` schedule-aware wrappers and ``ref.py`` pure-jnp oracles."""
