"""Schedule-driven Pallas flash-attention kernel.

Online-softmax attention with BlockSpec tiling over the query (``Q`` tile)
and key/value (``KV`` tile) axes — the two loop axes the auto-scheduler
tunes for the ``flash_attention_*`` kernel classes.  Supports:

* causal and bidirectional masking,
* sliding/local windows (mixtral SWA, gemma2 local, griffin local),
* attention logit softcapping (gemma2),
* GQA: the kv-head index map divides the query-head program id,
* decode (Sq=1 with a long KV context) — same kernel, bq clamps to Sq.

Grid: (batch·q_heads, Q/bq, KV/bkv) with KV innermost so the f32 softmax
state (m, l, acc scratch) persists across the KV trip.  The ``order`` field
of attention schedules chooses whether Q or KV is the *outer* streaming
axis in the cost model; the builder canonicalizes execution to KV-inner
(see DESIGN.md — on TPU the accumulator state must live in VMEM across the
reduction, so KV-outer realizations are strictly dominated and the cost
model penalizes them with spill traffic).

Validated against ref.attention / ref.chunked_attention in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.schedule import ConcreteSchedule

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            kv_trips: int, bq: int, bkv: int, sq: int, skv: int,
            causal: bool, window: int, softcap: float, scale: float,
            q_offset: int, out_dtype):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = q_offset + qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    kv_pos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    ok = kv_pos < skv  # padding guard
    if causal:
        ok &= kv_pos <= q_pos
    if window > 0:
        ok &= kv_pos > q_pos - window

    # Skip fully-masked tiles (beyond the causal frontier / outside window).
    def tile_live() -> jax.Array:
        live = jnp.array(True)
        if causal:
            live &= (ki * bkv) <= (q_offset + qi * bq + bq - 1)
        if window > 0:
            live &= (ki * bkv + bkv) > (q_offset + qi * bq - window)
        return live

    @pl.when(tile_live())
    def _():
        q = q_ref[0].astype(jnp.float32) * scale
        k = k_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(ok, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        m_ref[...] = m_new
        v = v_ref[0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(p, v, preferred_element_type=jnp.float32)

    @pl.when(ki == kv_trips - 1)
    def _():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(out_dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    cs: ConcreteSchedule, *, causal: bool = True,
                    window: int = 0, softcap: float = 0.0, q_offset: int = 0,
                    scale: float | None = None, interpret: bool = True) -> jax.Array:
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D). Returns (B, Hq, Sq, D)."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    bq = min(cs.t["Q"], sq)
    bkv = min(cs.t["KV"], skv)

    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hkv, skv, d)
    vf = v.reshape(b * hkv, skv, d)

    grid = (b * hq, pl.cdiv(sq, bq), pl.cdiv(skv, bkv))

    def kv_head(bh):
        # program id over b*hq -> row index into (b*hkv) k/v arrays
        return (bh // hq) * hkv + (bh % hq) // group

    in_specs = [
        pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        pl.BlockSpec((1, bkv, d), lambda bh, qi, ki: (kv_head(bh), ki, 0)),
        pl.BlockSpec((1, bkv, d), lambda bh, qi, ki: (kv_head(bh), ki, 0)),
    ]
    out_specs = pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0))

    kernel = functools.partial(
        _kernel,
        kv_trips=grid[2], bq=bq, bkv=bkv, sq=sq, skv=skv,
        causal=causal, window=window, softcap=softcap, scale=scale,
        q_offset=q_offset, out_dtype=q.dtype,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, sq, d)
