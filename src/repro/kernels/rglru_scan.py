"""Schedule-driven Pallas RG-LRU kernel (Griffin / RecurrentGemma).

Diagonal linear recurrence  h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ x_t —
memory-bound and embarrassingly parallel over channels, sequential over
time.  Schedule axes: ``T`` time-chunk and ``C`` channel block: the channel
grid axis is parallel; the f32 state scratch (one row per channel block)
persists across the sequential T trip.

Grid: (B, C/bc, T/ct) — T innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.schedule import ConcreteSchedule


def _kernel(x_ref, a_ref, h0_ref, y_ref, hT_ref, h_ref, *, t_trips: int, out_dtype):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _():
        h_ref[...] = h0_ref[...].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)  # (ct, bc)
    a = a_ref[0].astype(jnp.float32)

    def step(h, xs):
        xt, at = xs
        h_new = at * h + jnp.sqrt(jnp.maximum(1.0 - at * at, 0.0)) * xt
        return h_new, h_new

    h_final, ys = jax.lax.scan(step, h_ref[0], (x, a))
    h_ref[...] = h_final[None]
    y_ref[0] = ys.astype(out_dtype)

    @pl.when(ti == t_trips - 1)
    def _():
        hT_ref[0] = h_final


def rglru_scan(x: jax.Array, a: jax.Array, state: jax.Array,
               cs: ConcreteSchedule, *, interpret: bool = True
               ) -> tuple[jax.Array, jax.Array]:
    """x, a: (B, T, C); state: (B, C) f32. Returns (y, state_out)."""
    b, t, c = x.shape
    ct = min(cs.t["T"], t)
    bc = min(cs.t["C"], c)
    grid = (b, pl.cdiv(c, bc), pl.cdiv(t, ct))

    in_specs = [
        pl.BlockSpec((1, ct, bc), lambda bi, ci, ti: (bi, ti, ci)),
        pl.BlockSpec((1, ct, bc), lambda bi, ci, ti: (bi, ti, ci)),
        pl.BlockSpec((1, bc), lambda bi, ci, ti: (bi, ci)),
    ]
    out_specs = [
        pl.BlockSpec((1, ct, bc), lambda bi, ci, ti: (bi, ti, ci)),
        pl.BlockSpec((1, bc), lambda bi, ci, ti: (bi, ci)),
    ]
    y, h_out = pl.pallas_call(
        functools.partial(_kernel, t_trips=grid[2], out_dtype=x.dtype),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=[
            jax.ShapeDtypeStruct((b, t, c), x.dtype),
            jax.ShapeDtypeStruct((b, c), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, bc), jnp.float32)],
        interpret=interpret,
    )(x, a, state)
    return y, h_out
