"""Schedule-driven Pallas matmul kernel (fused epilogues).

The kernel realizes a :class:`repro.core.schedule.ConcreteSchedule` on TPU:

* ``tiles``      → BlockSpec block shapes (bm, bn, bk);
* ``order``      → grid axis order (Pallas iterates the last grid dim
                    fastest, i.e. ``order[-1]`` is the innermost loop);
* ``cache_write``→ f32 VMEM scratch accumulator (requires the reduction axis
                    K innermost so the scratch survives the whole K trip);
                    otherwise partial sums are accumulated into the output
                    block (read-modify-write on revisits — the spill traffic
                    the cost model charges for non-K-inner orders);
* ``parallel``   → ``dimension_semantics`` prefix (TPU compiler hint);
* epilogues (bias/gelu/glu/residual/softcap) are applied on the final
  reduction step, inside the kernel.

GLU epilogues use *interleaved* packing — columns alternate (gate, up) — so
one N-block holds complete pairs and can emit its (bm, bn/2) output block
independently.  Shape-changing epilogues therefore require the scratch-
accumulator path (enforced in :func:`build_call`).

Validated against :mod:`repro.kernels.ref` in interpret mode (tests sweep
shapes × dtypes × schedules).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.schedule import ConcreteSchedule

SHAPE_CHANGING = ("matmul_silu_glu", "matmul_gelu_glu", "moe_gemm_silu_glu")


def _epilogue_fn(class_id: str, softcap: float) -> Callable[..., jax.Array]:
    def f(acc, bias=None, residual=None):
        y = acc
        if bias is not None:
            y = y + bias
        if class_id == "matmul_bias_gelu":
            y = jax.nn.gelu(y)
        elif class_id in ("matmul_silu_glu", "moe_gemm_silu_glu"):
            y = jax.nn.silu(y[:, 0::2]) * y[:, 1::2]
        elif class_id == "matmul_gelu_glu":
            y = jax.nn.gelu(y[:, 0::2]) * y[:, 1::2]
        elif class_id == "matmul_residual":
            y = y + residual
        elif class_id == "matmul_lmhead_softcap":
            y = jnp.tanh(y / softcap) * softcap
        return y

    return f


def _kernel(x_ref, w_ref, *rest, class_id: str, softcap: float, k_pos: int,
            k_trips: int, use_scratch: bool, has_bias: bool, has_residual: bool,
            out_dtype):
    """Kernel body shared by all matmul classes.

    rest = (*optional bias_ref, *optional residual_ref, o_ref, *optional acc_ref)
    """
    i = 0
    bias_ref = rest[i] if has_bias else None
    i += int(has_bias)
    residual_ref = rest[i] if has_residual else None
    i += int(has_residual)
    o_ref = rest[i]
    acc_ref = rest[i + 1] if use_scratch else None

    k_idx = pl.program_id(k_pos)
    epilogue = _epilogue_fn(class_id, softcap)
    partial = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    def emit(acc):
        bias = bias_ref[...].astype(jnp.float32) if bias_ref is not None else None
        res = residual_ref[...].astype(jnp.float32) if residual_ref is not None else None
        o_ref[...] = epilogue(acc, bias, res).astype(out_dtype)

    if use_scratch:
        @pl.when(k_idx == 0)
        def _():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += partial

        @pl.when(k_idx == k_trips - 1)
        def _():
            emit(acc_ref[...])
    else:
        if k_trips == 1:
            emit(partial)
        else:
            # read-modify-write accumulation in the output block (out dtype)
            @pl.when(k_idx == 0)
            def _():
                o_ref[...] = partial.astype(out_dtype)

            @pl.when((k_idx > 0) & (k_idx < k_trips - 1))
            def _():
                o_ref[...] = (o_ref[...].astype(jnp.float32) + partial).astype(out_dtype)

            @pl.when(k_idx == k_trips - 1)
            def _():
                emit(o_ref[...].astype(jnp.float32) + partial)


def build_call(
    m: int,
    n: int,
    k: int,
    cs: ConcreteSchedule,
    *,
    class_id: str = "matmul",
    softcap: float = 0.0,
    has_bias: bool = False,
    has_residual: bool = False,
    groups: int = 0,
    out_dtype=jnp.float32,
    interpret: bool = True,
):
    """Build a pallas_call for x:(M,K) @ w:(K,N) (+epilogue inputs) -> out.

    ``groups`` > 0 builds the grouped (MoE) variant: x:(E,M,K), w:(E,K,N).
    Shape-changing (GLU) epilogues emit N//2 columns.
    """
    bm, bn, bk = cs.t["M"], cs.t["N"], cs.t["K"]
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    order = [a for a in cs.order if a in ("M", "N", "K")]
    trips = {"M": pl.cdiv(m, bm), "N": pl.cdiv(n, bn), "K": pl.cdiv(k, bk)}
    shape_changing = class_id in SHAPE_CHANGING
    use_scratch = cs.schedule.cache_write and order[-1] == "K"
    if shape_changing and not use_scratch:
        # GLU epilogue cannot RMW through a differently-shaped output block.
        if order[-1] != "K":
            order = [a for a in order if a != "K"] + ["K"]
            trips = {"M": pl.cdiv(m, bm), "N": pl.cdiv(n, bn), "K": pl.cdiv(k, bk)}
        use_scratch = True
    if shape_changing and bn % 2:
        raise ValueError(f"GLU epilogue needs even N tile, got {bn}")

    pos = {a: i for i, a in enumerate(order)}
    g = int(groups > 0)  # leading expert grid dim for grouped matmul
    grid = ((groups,) if g else ()) + tuple(trips[a] for a in order)

    def idx(*axes):
        def f(*pids):
            base = {a: pids[g + pos[a]] for a in ("M", "N", "K")}
            lead = (pids[0],) if g else ()
            return lead + tuple(base[a] for a in axes)

        return f

    lead_blk = (1,) if g else ()
    in_specs = [
        pl.BlockSpec(lead_blk + (bm, bk), idx("M", "K")),
        pl.BlockSpec(lead_blk + (bk, bn), idx("K", "N")),
    ]
    n_out = n // 2 if shape_changing else n
    bn_out = bn // 2 if shape_changing else bn
    if has_bias:
        in_specs.append(pl.BlockSpec((1, bn), lambda *p: (0, p[g + pos["N"]])))
    if has_residual:
        in_specs.append(pl.BlockSpec(lead_blk + (bm, bn_out), idx("M", "N")))

    out_specs = pl.BlockSpec(lead_blk + (bm, bn_out), idx("M", "N"))

    kernel = functools.partial(
        _kernel,
        class_id=class_id,
        softcap=softcap,
        k_pos=g + pos["K"],
        k_trips=trips["K"],
        use_scratch=use_scratch,
        has_bias=has_bias,
        has_residual=has_residual,
        out_dtype=out_dtype,
    )

    def _squeeze_lead(body):
        # grouped blocks carry a leading length-1 expert dim; strip it inside
        if not g:
            return body

        def wrapped(x_ref, w_ref, *rest):
            refs = [x_ref.at[0], w_ref.at[0]]
            i = 0
            if has_bias:
                refs.append(rest[i])
                i += 1
            if has_residual:
                refs.append(rest[i].at[0])
                i += 1
            refs.append(rest[i].at[0])  # o_ref
            refs.extend(rest[i + 1:])   # scratch
            return body(*refs)

        return wrapped

    out_shape = jax.ShapeDtypeStruct(((groups,) if g else ()) + (m, n_out), out_dtype)
    return pl.pallas_call(
        _squeeze_lead(kernel),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)] if use_scratch else [],
        interpret=interpret,
    )


def matmul(x: jax.Array, w: jax.Array, cs: ConcreteSchedule, *,
           class_id: str = "matmul", bias: jax.Array | None = None,
           residual: jax.Array | None = None, softcap: float = 0.0,
           interpret: bool = True) -> jax.Array:
    """Run the kernel: x (M,K) @ w (K,N) with fused epilogue."""
    m, k = x.shape
    n = w.shape[1]
    call = build_call(
        m, n, k, cs, class_id=class_id, softcap=softcap,
        has_bias=bias is not None, has_residual=residual is not None,
        out_dtype=x.dtype, interpret=interpret,
    )
    args = [x, w]
    if bias is not None:
        args.append(bias.reshape(1, -1))
    if residual is not None:
        args.append(residual)
    return call(*args)


def grouped_matmul(x: jax.Array, w: jax.Array, cs: ConcreteSchedule, *,
                   class_id: str = "moe_gemm", interpret: bool = True) -> jax.Array:
    """Grouped (MoE expert) matmul: x (E,M,K) @ w (E,K,N) -> (E,M,out)."""
    e, m, k = x.shape
    n = w.shape[2]
    call = build_call(
        m, n, k, cs, class_id=class_id, groups=e, out_dtype=x.dtype,
        interpret=interpret,
    )
    return call(x, w)
