"""Schedule-driven Pallas RWKV6 (Finch) wkv time-mix kernel.

The wkv recurrence is the sequential hot-spot of RWKV6: per (batch, head)
a (D×D) state is decayed per-channel (data-dependent ``w``) and updated
with rank-1 outer products.  TPU adaptation: the state lives in an f32 VMEM
scratch that persists across the sequential time-chunk grid axis; tokens
inside a chunk run in a ``lax.scan`` over VMEM-resident slices.

Schedule axes: ``T`` (time-chunk length, tiles the sequential axis — larger
chunks amortize DMA, cost VMEM) and ``C`` (channel/head blocking — here the
grid over batch·heads; the C tile gates how many heads share one program).

Grid: (B·H, T/ct) — T innermost so the state scratch survives the trip.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.schedule import ConcreteSchedule


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref, s_ref, *,
            t_trips: int, out_dtype):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _():
        s_ref[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)  # (ct, D)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)  # (D,) bonus, broadcast over k-dim

    def step(s, xs):
        rt, kt, vt, wt = xs  # (D,) each
        kv = kt[:, None] * vt[None, :]                      # (D, D)
        y = rt @ (s + u[:, None] * kv)                      # (D,)
        s_new = wt[:, None] * s + kv
        return s_new, y

    s_final, ys = jax.lax.scan(step, s_ref[...], (r, k, v, w))
    s_ref[...] = s_final
    y_ref[0] = ys.astype(out_dtype)

    @pl.when(ti == t_trips - 1)
    def _():
        sT_ref[0] = s_final


def rwkv6_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
               u: jax.Array, state: jax.Array, cs: ConcreteSchedule, *,
               interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """r/k/v/w: (B, H, T, D); u: (H, D); state: (B, H, D, D) f32.

    Returns (y: (B, H, T, D), state_out: (B, H, D, D) f32).
    """
    b, h, t, d = r.shape
    ct = min(cs.t["T"], t)
    grid = (b * h, pl.cdiv(t, ct))

    def flat(x):
        return x.reshape(b * h, t, d)

    rf, kf, vf, wf = flat(r), flat(k), flat(v), flat(w)
    sf = state.reshape(b * h, d, d)

    in_specs = [
        pl.BlockSpec((1, ct, d), lambda bh, ti: (bh, ti, 0)),
        pl.BlockSpec((1, ct, d), lambda bh, ti: (bh, ti, 0)),
        pl.BlockSpec((1, ct, d), lambda bh, ti: (bh, ti, 0)),
        pl.BlockSpec((1, ct, d), lambda bh, ti: (bh, ti, 0)),
        pl.BlockSpec((1, d), lambda bh, ti: (bh % h, 0)),       # u per head
        pl.BlockSpec((1, d, d), lambda bh, ti: (bh, 0, 0)),     # initial state
    ]
    out_specs = [
        pl.BlockSpec((1, ct, d), lambda bh, ti: (bh, ti, 0)),
        pl.BlockSpec((1, d, d), lambda bh, ti: (bh, 0, 0)),
    ]
    y, s_out = pl.pallas_call(
        functools.partial(_kernel, t_trips=grid[1], out_dtype=r.dtype),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=[
            jax.ShapeDtypeStruct((b * h, t, d), r.dtype),
            jax.ShapeDtypeStruct((b * h, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, wf, u, sf)
    return y.reshape(b, h, t, d), s_out.reshape(b, h, d, d)
