"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` of the contract).

These are the ground truth the kernels are validated against in interpret
mode, and the XLA execution path on non-TPU backends (this container).  The
attention oracle also has a *chunked* online-softmax variant used by the
models so the dry-run memory profile matches the flash kernel's (no S×S
materialization at 32k+).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Matmul + fused epilogues
# ---------------------------------------------------------------------------


def _glu(y: jax.Array, act: Callable[[jax.Array], jax.Array]) -> jax.Array:
    """Interleaved GLU: columns are packed (gate, up, gate, up, ...).

    The Pallas kernel applies the epilogue per N-block, which requires the
    gate/up pair to live in the same block — hence interleaved packing (the
    framework owns the weight layout; see models/common.py pack_glu).
    """
    g = y[..., 0::2]
    u = y[..., 1::2]
    return act(g) * u


def apply_epilogue(y: jax.Array, class_id: str, *, bias: jax.Array | None = None,
                   residual: jax.Array | None = None, softcap: float = 0.0) -> jax.Array:
    if bias is not None:
        y = y + bias
    if class_id in ("matmul", "matmul_bias", "matmul_lmhead", "moe_router", "moe_gemm"):
        pass
    elif class_id == "matmul_bias_gelu":
        y = jax.nn.gelu(y)
    elif class_id in ("matmul_silu_glu", "moe_gemm_silu_glu"):
        y = _glu(y, jax.nn.silu)
    elif class_id == "matmul_gelu_glu":
        y = _glu(y, jax.nn.gelu)
    elif class_id == "matmul_residual":
        assert residual is not None
        y = y + residual
    elif class_id == "matmul_lmhead_softcap":
        assert softcap > 0.0
        y = jnp.tanh(y / softcap) * softcap
    else:
        raise ValueError(f"unknown matmul epilogue class {class_id!r}")
    return y


def matmul(x: jax.Array, w: jax.Array, class_id: str = "matmul", *,
           bias: jax.Array | None = None, residual: jax.Array | None = None,
           softcap: float = 0.0) -> jax.Array:
    """Oracle for the matmul kernel family. x: (..., K), w: (K, N)."""
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    y = apply_epilogue(y, class_id, bias=bias, residual=residual, softcap=softcap)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def _mask_bias(sq: int, skv: int, q_offset: int, causal: bool, window: int,
               dtype=jnp.float32) -> jax.Array:
    """Additive mask bias (0 / -inf) for a (sq, skv) score tile.

    ``q_offset`` is the absolute position of query row 0 (kv rows are
    absolute 0..skv). Supports causal and sliding-window (local) masks.
    """
    q_pos = q_offset + jnp.arange(sq)[:, None]
    kv_pos = jnp.arange(skv)[None, :]
    ok = jnp.ones((sq, skv), dtype=bool)
    if causal:
        ok &= kv_pos <= q_pos
    if window > 0:
        ok &= kv_pos > q_pos - window
    return jnp.where(ok, 0.0, -jnp.inf).astype(dtype)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
              window: int = 0, softcap: float = 0.0, q_offset: int = 0,
              scale: float | None = None) -> jax.Array:
    """Naive full-materialization oracle.

    q: (B, Hq, Sq, D), k/v: (B, Hkv, Skv, D) with Hq % Hkv == 0 (GQA).
    """
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, hkv, group, sq, d)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    s = s + _mask_bias(sq, k.shape[2], q_offset, causal, window)[None, None, None]
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, sq, d).astype(q.dtype)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: int = 0, softcap: float = 0.0,
                      q_offset: int = 0, chunk: int = 1024,
                      scale: float | None = None) -> jax.Array:
    """Online-softmax attention chunked over KV: O(Sq·chunk) live memory.

    Numerically equivalent to :func:`attention` (validated by tests); the
    execution-path analogue of the flash kernel for XLA backends, used by
    the models so 32k+ dry-runs don't materialize S×S scores.
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    chunk = min(chunk, skv)
    if skv % chunk:
        pad = chunk - skv % chunk
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        skv_p = skv + pad
    else:
        skv_p = skv
    n_chunks = skv_p // chunk
    qg = (q.reshape(b, hkv, group, sq, d) * scale).astype(jnp.float32)
    kc = k.reshape(b, hkv, n_chunks, chunk, d).astype(jnp.float32)
    vc = v.reshape(b, hkv, n_chunks, chunk, d).astype(jnp.float32)

    q_pos = q_offset + jnp.arange(sq)

    def step(carry, xs):
        m, l, acc = carry
        kb, vb, idx = xs
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kb)
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        kv_pos = idx * chunk + jnp.arange(chunk)
        ok = kv_pos[None, :] < skv
        if causal:
            ok = ok & (kv_pos[None, :] <= q_pos[:, None])
        if window > 0:
            ok = ok & (kv_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(ok[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # guard fully-masked rows (m_new == -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(ok[None, None, None], p, 0.0)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bhgqk,bhkd->bhgqd", p, vb)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, group, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hkv, group, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, group, sq, d), jnp.float32)
    kc_t = jnp.moveaxis(kc, 2, 0)
    vc_t = jnp.moveaxis(vc, 2, 0)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc_t, vc_t, jnp.arange(n_chunks)))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return o.reshape(b, hq, sq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# RWKV6 time-mix scan (Finch wkv: data-dependent per-channel decay + bonus)
# ---------------------------------------------------------------------------


def rwkv6_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
               u: jax.Array, state: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Oracle wkv6 recurrence.

    r/k/v/w: (B, H, T, D); u: (H, D); state: (B, H, D, D) mapping k-dim->v-dim.
      y_t   = (S_t + (u ⊙ k_t) v_tᵀ)ᵀ r_t
      S_t+1 = diag(w_t) S_t + k_t v_tᵀ          (w_t = exp(-exp(ŵ_t)) ∈ (0,1))
    """
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(s, xs):
        rt, kt, vt, wt = xs  # (B,H,D) each
        kv = kt[..., :, None] * vt[..., None, :]           # (B,H,D,D)
        y = jnp.einsum("bhk,bhkv->bhv", rt, s + uf[None, :, :, None] * kv)
        s_new = wt[..., :, None] * s + kv
        return s_new, y

    xs = tuple(jnp.moveaxis(x, 2, 0) for x in (rf, kf, vf, wf))
    s_final, ys = jax.lax.scan(step, state.astype(jnp.float32), xs)
    y = jnp.moveaxis(ys, 0, 2)  # (B,H,T,D)
    return y.astype(r.dtype), s_final


# ---------------------------------------------------------------------------
# RG-LRU scan (Griffin / RecurrentGemma)
# ---------------------------------------------------------------------------


def rglru_scan(x: jax.Array, a: jax.Array, state: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Oracle RG-LRU recurrence.

    x, a: (B, T, C) — pre-gated input and per-step decay a_t ∈ (0,1);
    state: (B, C).   h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ x_t
    """
    xf, af = x.astype(jnp.float32), a.astype(jnp.float32)

    def step(h, xs):
        xt, at = xs
        h_new = at * h + jnp.sqrt(jnp.maximum(1.0 - at * at, 0.0)) * xt
        return h_new, h_new

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(af, 1, 0))
    h_final, hs = jax.lax.scan(step, state.astype(jnp.float32), xs)
    return jnp.moveaxis(hs, 0, 1).astype(x.dtype), h_final
