"""Public kernel ops: schedule-aware, backend-dispatching wrappers.

Models call these instead of raw jnp so tuned schedules (native or
transfer-tuned) plumb into execution as a first-class feature:

* ``backend="ref"``    — pure-jnp oracle path (XLA).  Default on CPU/this
  container; also the dry-run path, so `.lower()` sees the same sub-
  quadratic structure the Pallas kernels have (chunked attention).
* ``backend="pallas"`` — the Pallas kernels, realizing the resolved
  :class:`ConcreteSchedule` as BlockSpecs.  On CPU this runs in interpret
  mode (functionally exact, used by the tests); on TPU it compiles.

Schedule resolution is the :class:`~repro.core.resolution.ResolutionPipeline`
(service → static map → default) behind a :class:`ScheduleProvider` facade.
When an :class:`~repro.core.resolution.ExecutionPlan` is active (serving),
the pre-resolved plan is consulted first — a lock-free dict hit — and only
unplanned instances walk the pipeline (whose memo cache makes the steady
state a dict hit as well).

The per-op hot path is kept cheap: the interpret-mode backend probe runs
once per process, and kernel instances are interned so repeated calls with
the same shapes reuse one validated :class:`KernelInstance` (and its cached
workload key) instead of rebuilding it.
"""
from __future__ import annotations

import contextlib
import functools
import threading
from typing import Mapping

import jax
import jax.numpy as jnp

from repro.core import resolution
from repro.core.resolution import ExecutionPlan, ResolutionPipeline
from repro.core.schedule import ConcreteSchedule, Schedule
from repro.core.workload import KernelInstance
from repro.kernels import flash_attention as _fa
from repro.kernels import matmul as _mm
from repro.kernels import ref
from repro.kernels import rglru_scan as _rg
from repro.kernels import rwkv6_scan as _rw

_state = threading.local()


def _default_backend() -> str:
    return getattr(_state, "backend", "ref")


def set_backend(backend: str) -> None:
    assert backend in ("ref", "pallas")
    _state.backend = backend


@contextlib.contextmanager
def use_backend(backend: str):
    prev = _default_backend()
    set_backend(backend)
    try:
        yield
    finally:
        set_backend(prev)


class ScheduleProvider:
    """Resolves the schedule for each kernel instance the model emits.

    A thin facade over a :class:`ResolutionPipeline` plus an optional active
    :class:`ExecutionPlan`:

    * ``plan`` (when set) answers first — pre-resolved dict hit;
    * the pipeline walks service → static map → default on plan misses and
      memoizes per ``(workload, mode, target, generation)``.

    Construct either from the legacy pieces (``schedule_map`` and/or
    ``service``) or from an explicit ``pipeline``.  Invalid entries (e.g. a
    transferred schedule that does not concretize strictly) fall through to
    the next stage — execution never fails on a bad DB.

    Per-tier lookup counts (``exact``/``transfer``/``static``/``default``)
    live in the pipeline and are thread-safe; a service answer of the
    untuned-default tier is *not* a hit.  ``hits``/``misses`` remain as
    derived compatibility properties.
    """

    def __init__(self, schedule_map: Mapping[str, Schedule] | None = None,
                 mode: str = "strict", service=None, *,
                 pipeline: ResolutionPipeline | None = None,
                 plan: ExecutionPlan | None = None, target=None):
        if pipeline is None:
            pipeline = ResolutionPipeline.build(
                schedule_map=schedule_map, service=service, mode=mode,
                target=target)
        self.pipeline = pipeline
        self.plan = plan
        self._lock = threading.Lock()
        # Plan answers bucketed by tier (a default-tier plan entry is still
        # an untuned kernel — it must not masquerade as a hit), plus misses
        # (instances the plan does not cover, served by the pipeline) so
        # coverage gaps are observable.
        self._plan_served = {t: 0 for t in resolution.TIERS}
        self._plan_misses = 0

    @property
    def mode(self) -> str:
        return self.pipeline.mode

    @property
    def service(self):
        return self.pipeline.service

    @property
    def schedule_map(self) -> dict[str, Schedule]:
        return self.pipeline.schedule_map

    def get(self, instance: KernelInstance) -> ConcreteSchedule:
        plan = self.plan
        if plan is not None:
            r = plan.lookup(instance)
            if r is not None:
                with self._lock:
                    self._plan_served[r.tier] += 1
                return r.concrete
            with self._lock:
                self._plan_misses += 1
        return self.pipeline.resolve(instance).concrete

    # -- telemetry ------------------------------------------------------------
    @property
    def plan_hits(self) -> int:
        """Total resolutions the active plan answered (any tier)."""
        with self._lock:
            return sum(self._plan_served.values())

    def stats(self) -> dict:
        out = self.pipeline.stats()
        with self._lock:
            out["plan_served"] = dict(self._plan_served)
            out["plan_misses"] = self._plan_misses
        out["plan_hits"] = sum(out["plan_served"].values())
        out["plan_entries"] = len(self.plan) if self.plan is not None else 0
        out["plan_generation"] = (self.plan.generation
                                  if self.plan is not None else None)
        return out

    # Legacy counters: tuned-tier resolutions count as hits, untuned as
    # misses (regardless of whether the plan or the pipeline served them).
    @property
    def hits(self) -> int:
        s = self.stats()
        return sum(s["plan_served"][t] + s[f"served_{t}"]
                   for t in ("exact", "transfer", "static"))

    @property
    def misses(self) -> int:
        s = self.stats()
        return s["plan_served"]["default"] + s["served_default"]


_DEFAULT_PROVIDER = ScheduleProvider()


def set_default_provider(provider: ScheduleProvider | None) -> ScheduleProvider:
    """Install the provider kernels use when no explicit one is passed.

    Returns the previous default so callers can restore it.  ``None``
    reinstalls an empty (all-defaults) provider."""
    global _DEFAULT_PROVIDER
    prev = _DEFAULT_PROVIDER
    _DEFAULT_PROVIDER = provider if provider is not None else ScheduleProvider()
    return prev


def _resolve(provider: ScheduleProvider | None) -> ScheduleProvider:
    return provider if provider is not None else _DEFAULT_PROVIDER


# ---------------------------------------------------------------------------
# Per-op hot-path helpers: interned instances, hoisted backend probe
# ---------------------------------------------------------------------------

_DTYPE_STR: dict = {}


def _dtype_str(dt) -> str:
    s = _DTYPE_STR.get(dt)
    if s is None:
        s = _DTYPE_STR[dt] = str(dt)
    return s


@functools.lru_cache(maxsize=8192)
def _interned(class_id: str, dtype: str,
              params: tuple[tuple[str, int], ...]) -> KernelInstance:
    return KernelInstance(class_id=class_id, params=params, dtype=dtype)


def _instance(class_id: str, dtype, **params: int) -> KernelInstance:
    """Interned KernelInstance.make: validation + workload key amortized."""
    return _interned(class_id, _dtype_str(dtype),
                     tuple(sorted((k, int(v)) for k, v in params.items())))


_INTERPRET: bool | None = None


def _interpret() -> bool:
    """Pallas interpret mode: on unless a real TPU backend is present.

    The backend probe is process-wide and stable, so it runs once instead of
    on every op call."""
    global _INTERPRET
    if _INTERPRET is None:
        _INTERPRET = jax.default_backend() != "tpu"
    return _INTERPRET


# ---------------------------------------------------------------------------
# matmul family
# ---------------------------------------------------------------------------


def matmul(x: jax.Array, w: jax.Array, *, class_id: str = "matmul",
           bias: jax.Array | None = None, residual: jax.Array | None = None,
           softcap: float = 0.0, provider: ScheduleProvider | None = None,
           backend: str | None = None) -> jax.Array:
    """x: (..., K) @ w: (K, N) with fused epilogue. GLU classes emit N//2."""
    backend = backend or _default_backend()
    *lead, k = x.shape
    n = w.shape[1]
    if backend == "ref":
        return ref.matmul(x, w, class_id, bias=bias, residual=residual, softcap=softcap)
    m = 1
    for s in lead:
        m *= s
    x2 = x.reshape(m, k)
    res2 = residual.reshape(m, -1) if residual is not None else None
    inst = _instance(class_id, x.dtype, M=m, N=n, K=k)
    cs = _resolve(provider).get(inst)
    y = _mm.matmul(x2, w, cs, class_id=class_id, bias=bias, residual=res2,
                   softcap=softcap, interpret=_interpret())
    return y.reshape(*lead, y.shape[-1])


def moe_gemm(x: jax.Array, w: jax.Array, *, class_id: str = "moe_gemm",
             provider: ScheduleProvider | None = None,
             backend: str | None = None) -> jax.Array:
    """Grouped expert GEMM: x (E, M, K) @ w (E, K, N)."""
    backend = backend or _default_backend()
    if backend == "ref":
        return jax.vmap(lambda a, b: ref.matmul(a, b, class_id))(x, w)
    e, m, k = x.shape
    n = w.shape[2]
    inst = _instance(class_id, x.dtype, M=m * e, N=n, K=k, E=e)
    cs = _resolve(provider).get(inst)
    return _mm.grouped_matmul(x, w, cs, class_id=class_id, interpret=_interpret())


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    class_id: str = "flash_attention_causal",
                    causal: bool = True, window: int = 0, softcap: float = 0.0,
                    q_offset: int = 0, provider: ScheduleProvider | None = None,
                    backend: str | None = None, chunk: int = 1024) -> jax.Array:
    """q: (B,Hq,Sq,D); k/v: (B,Hkv,Skv,D) — GQA-aware flash attention."""
    backend = backend or _default_backend()
    if backend == "ref":
        return ref.chunked_attention(q, k, v, causal=causal, window=window,
                                     softcap=softcap, q_offset=q_offset, chunk=chunk)
    b, hq, sq, d = q.shape
    inst = _instance(class_id, q.dtype, Q=sq, KV=k.shape[2], H=hq, D=d, B=b,
                     window=window)
    cs = _resolve(provider).get(inst)
    return _fa.flash_attention(q, k, v, cs, causal=causal, window=window,
                               softcap=softcap, q_offset=q_offset,
                               interpret=_interpret())


# ---------------------------------------------------------------------------
# recurrent scans
# ---------------------------------------------------------------------------


def rwkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array, u: jax.Array,
          state: jax.Array, *, provider: ScheduleProvider | None = None,
          backend: str | None = None) -> tuple[jax.Array, jax.Array]:
    backend = backend or _default_backend()
    if backend == "ref":
        return ref.rwkv6_scan(r, k, v, w, u, state)
    b, h, t, d = r.shape
    inst = _instance("rwkv6_scan", r.dtype, T=t, C=h * d, D=d, B=b)
    cs = _resolve(provider).get(inst)
    return _rw.rwkv6_scan(r, k, v, w, u, state, cs, interpret=_interpret())


def rglru(x: jax.Array, a: jax.Array, state: jax.Array, *,
          provider: ScheduleProvider | None = None,
          backend: str | None = None) -> tuple[jax.Array, jax.Array]:
    backend = backend or _default_backend()
    if backend == "ref":
        return ref.rglru_scan(x, a, state)
    b, t, c = x.shape
    inst = _instance("rglru_scan", x.dtype, T=t, C=c, B=b)
    cs = _resolve(provider).get(inst)
    return _rg.rglru_scan(x, a, state, cs, interpret=_interpret())
