"""Public kernel ops: schedule-aware, backend-dispatching wrappers.

Models call these instead of raw jnp so tuned schedules (native or
transfer-tuned) plumb into execution as a first-class feature:

* ``backend="ref"``    — pure-jnp oracle path (XLA).  Default on CPU/this
  container; also the dry-run path, so `.lower()` sees the same sub-
  quadratic structure the Pallas kernels have (chunked attention).
* ``backend="pallas"`` — the Pallas kernels, realizing the resolved
  :class:`ConcreteSchedule` as BlockSpecs.  On CPU this runs in interpret
  mode (functionally exact, used by the tests); on TPU it compiles.

Schedule resolution: a :class:`ScheduleProvider` built from a tuned
:class:`~repro.core.database.ScheduleDB` / transfer-tuning result maps each
runtime kernel instance to its best schedule (exact workload hit → class
transfer → untuned default), mirroring the lookup order of the paper.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Mapping

import jax
import jax.numpy as jnp

from repro.core.schedule import ConcreteSchedule, Schedule, ScheduleInvalid, concretize, default_schedule
from repro.core.workload import KernelInstance
from repro.kernels import flash_attention as _fa
from repro.kernels import matmul as _mm
from repro.kernels import ref
from repro.kernels import rglru_scan as _rg
from repro.kernels import rwkv6_scan as _rw

_state = threading.local()


def _default_backend() -> str:
    return getattr(_state, "backend", "ref")


def set_backend(backend: str) -> None:
    assert backend in ("ref", "pallas")
    _state.backend = backend


@contextlib.contextmanager
def use_backend(backend: str):
    prev = _default_backend()
    set_backend(backend)
    try:
        yield
    finally:
        set_backend(prev)


class ScheduleProvider:
    """Resolves the schedule for each kernel instance the model emits.

    Two sources, either or both may be set:

    * ``schedule_map``: workload_key -> Schedule (e.g. from
      TransferResult.schedule_map() or native tuning records) — a frozen,
      offline-produced mapping;
    * ``service``: a :class:`repro.service.TuningService` — the online path.
      Each resolution goes through the service's tiered lookup (exact →
      transfer probe → default), and misses enqueue background tuning jobs,
      so repeated resolutions upgrade as jobs publish to the registry.

    Lookup order: service (when set) → static map → untuned default.  Invalid
    entries (e.g. a transferred schedule that does not concretize strictly)
    fall back to the default — execution never fails on a bad DB.
    """

    def __init__(self, schedule_map: Mapping[str, Schedule] | None = None,
                 mode: str = "strict", service=None):
        self.schedule_map = dict(schedule_map or {})
        self.mode = mode
        self.service = service
        self.hits = 0
        self.misses = 0

    def _try(self, sched: Schedule | None, instance: KernelInstance
             ) -> ConcreteSchedule | None:
        if sched is None:
            return None
        try:
            return concretize(sched, instance, mode=self.mode)
        except ScheduleInvalid:
            return None

    def get(self, instance: KernelInstance) -> ConcreteSchedule:
        if self.service is not None:
            cs = self._try(self.service.lookup(instance).schedule, instance)
            if cs is not None:
                self.hits += 1
                return cs
        cs = self._try(self.schedule_map.get(instance.workload_key()), instance)
        if cs is not None:
            self.hits += 1
            return cs
        self.misses += 1
        return concretize(default_schedule(instance), instance)


_DEFAULT_PROVIDER = ScheduleProvider()


def set_default_provider(provider: ScheduleProvider | None) -> ScheduleProvider:
    """Install the provider kernels use when no explicit one is passed.

    Returns the previous default so callers can restore it.  ``None``
    reinstalls an empty (all-defaults) provider."""
    global _DEFAULT_PROVIDER
    prev = _DEFAULT_PROVIDER
    _DEFAULT_PROVIDER = provider if provider is not None else ScheduleProvider()
    return prev


def _resolve(provider: ScheduleProvider | None) -> ScheduleProvider:
    return provider if provider is not None else _DEFAULT_PROVIDER


# ---------------------------------------------------------------------------
# matmul family
# ---------------------------------------------------------------------------


def matmul(x: jax.Array, w: jax.Array, *, class_id: str = "matmul",
           bias: jax.Array | None = None, residual: jax.Array | None = None,
           softcap: float = 0.0, provider: ScheduleProvider | None = None,
           backend: str | None = None) -> jax.Array:
    """x: (..., K) @ w: (K, N) with fused epilogue. GLU classes emit N//2."""
    backend = backend or _default_backend()
    *lead, k = x.shape
    n = w.shape[1]
    if backend == "ref":
        return ref.matmul(x, w, class_id, bias=bias, residual=residual, softcap=softcap)
    m = 1
    for s in lead:
        m *= s
    x2 = x.reshape(m, k)
    res2 = residual.reshape(m, -1) if residual is not None else None
    inst = KernelInstance.make(class_id, M=m, N=n, K=k, dtype=str(x.dtype))
    cs = _resolve(provider).get(inst)
    y = _mm.matmul(x2, w, cs, class_id=class_id, bias=bias, residual=res2,
                   softcap=softcap, interpret=_interpret())
    return y.reshape(*lead, y.shape[-1])


def moe_gemm(x: jax.Array, w: jax.Array, *, class_id: str = "moe_gemm",
             provider: ScheduleProvider | None = None,
             backend: str | None = None) -> jax.Array:
    """Grouped expert GEMM: x (E, M, K) @ w (E, K, N)."""
    backend = backend or _default_backend()
    if backend == "ref":
        return jax.vmap(lambda a, b: ref.matmul(a, b, class_id))(x, w)
    e, m, k = x.shape
    n = w.shape[2]
    inst = KernelInstance.make(class_id, M=m * e, N=n, K=k, E=e, dtype=str(x.dtype))
    cs = _resolve(provider).get(inst)
    return _mm.grouped_matmul(x, w, cs, class_id=class_id, interpret=_interpret())


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    class_id: str = "flash_attention_causal",
                    causal: bool = True, window: int = 0, softcap: float = 0.0,
                    q_offset: int = 0, provider: ScheduleProvider | None = None,
                    backend: str | None = None, chunk: int = 1024) -> jax.Array:
    """q: (B,Hq,Sq,D); k/v: (B,Hkv,Skv,D) — GQA-aware flash attention."""
    backend = backend or _default_backend()
    if backend == "ref":
        return ref.chunked_attention(q, k, v, causal=causal, window=window,
                                     softcap=softcap, q_offset=q_offset, chunk=chunk)
    b, hq, sq, d = q.shape
    inst = KernelInstance.make(class_id, Q=sq, KV=k.shape[2], H=hq, D=d, B=b,
                               window=window, dtype=str(q.dtype))
    cs = _resolve(provider).get(inst)
    return _fa.flash_attention(q, k, v, cs, causal=causal, window=window,
                               softcap=softcap, q_offset=q_offset,
                               interpret=_interpret())


# ---------------------------------------------------------------------------
# recurrent scans
# ---------------------------------------------------------------------------


def rwkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array, u: jax.Array,
          state: jax.Array, *, provider: ScheduleProvider | None = None,
          backend: str | None = None) -> tuple[jax.Array, jax.Array]:
    backend = backend or _default_backend()
    if backend == "ref":
        return ref.rwkv6_scan(r, k, v, w, u, state)
    b, h, t, d = r.shape
    inst = KernelInstance.make("rwkv6_scan", T=t, C=h * d, D=d, B=b, dtype=str(r.dtype))
    cs = _resolve(provider).get(inst)
    return _rw.rwkv6_scan(r, k, v, w, u, state, cs, interpret=_interpret())


def rglru(x: jax.Array, a: jax.Array, state: jax.Array, *,
          provider: ScheduleProvider | None = None,
          backend: str | None = None) -> tuple[jax.Array, jax.Array]:
    backend = backend or _default_backend()
    if backend == "ref":
        return ref.rglru_scan(x, a, state)
    b, t, c = x.shape
    inst = KernelInstance.make("rglru_scan", T=t, C=c, B=b, dtype=str(x.dtype))
    cs = _resolve(provider).get(inst)
    return _rg.rglru_scan(x, a, state, cs, interpret=_interpret())


def _interpret() -> bool:
    """Pallas interpret mode: on unless a real TPU backend is present."""
    return jax.default_backend() != "tpu"
