"""Online tuning service: tiered schedule lookup + background transfer-tuning.

The serving path asks one question — "what schedule should this kernel
instance run with, *right now*?" — and must never block on search.
:class:`TuningService` answers it with a tiered policy over a
:class:`~repro.service.registry.ScheduleRegistry` snapshot:

1. **exact** — a published record for this exact workload (Ansor's
   workload-ID reuse; includes upgrades this service published earlier);
2. **transfer** — the best same-class donor candidate, probed through the
   injected :class:`~repro.core.runner.MeasureRunner` (bounded to
   ``probe_candidates`` strongest donors; a shared :class:`CachedRunner`
   makes repeat probes and the later background job free);
3. **default** — the untuned schedule.

Every non-exact lookup enqueues a **background transfer-tuning job** for the
missed workload: deduplicated by workload key, run on a bounded worker pool,
bounded by a total *virtual search seconds* budget, and published atomically
to the registry — so subsequent lookups for that workload upgrade to tier 1.
A published schedule is never downgraded: a job's result is only published
when it beats the best record already visible for that workload.

The background job is exactly the offline pipeline
(:func:`repro.core.transfer.transfer_tune` over the full donor pool with the
service's mode/seed), so a drained service converges to the same schedules an
offline ``transfer_arch`` run would produce for the same workloads, donors,
and budget — the online path trades *when* search happens, not *what* it
finds.
"""
from __future__ import annotations

import dataclasses
import threading
from concurrent.futures import Future, ThreadPoolExecutor, wait
from typing import Sequence

from repro.core.database import Record, ScheduleDB
from repro.core.runner import MeasureRunner, resolve_runner
from repro.core.schedule import Schedule, ScheduleInvalid
from repro.core.transfer import _strongest_first, transfer_tune
from repro.core.workload import KernelInstance, KernelUse
from repro.obs import NULL_TRACER, MetricsRegistry
from repro.targets import target_name


@dataclasses.dataclass(frozen=True)
class LookupResult:
    """Answer to one serving-path schedule query."""

    schedule: Schedule | None    # None -> run the untuned default
    tier: str                    # "exact" | "transfer" | "default"
    seconds: float               # noise-free kernel seconds under the answer
    untuned_seconds: float
    source_model: str = ""       # provenance of the chosen schedule
    generation: int = 0          # registry generation the answer was read at

    @property
    def speedup(self) -> float:
        return self.untuned_seconds / self.seconds if self.seconds else 1.0


@dataclasses.dataclass
class _Job:
    instance: KernelInstance
    future: Future | None = None   # None -> deferred (drained inline)
    started: bool = False
    priority: float = 0.0          # higher drains first (deferred mode)
    seq: int = 0                   # FIFO tiebreak within a priority
    enqueued_t: float = 0.0        # virtual instant the job entered the queue
    skips: int = 0                 # times a later-enqueued job was claimed first
    starved: bool = False          # skips crossed the starvation threshold


class TuningService:
    """Schedule lookups now, transfer-tuning upgrades in the background.

    ``max_workers > 0`` runs jobs on a thread pool as they are enqueued;
    ``max_workers = 0`` defers them until :meth:`drain` — deterministic, used
    by tests and the benchmark's stepwise stream.  ``budget_s`` bounds the
    total virtual search seconds background jobs may charge (probe-tier
    measurement is accounted separately in ``probe_search_s``).  ``donors``
    restricts the candidate pool to the given model ids; by default every
    model in the registry except ``model_id`` (this service's own published
    upgrades) is a donor, which keeps background jobs equivalent to an
    offline run against the donor-only store.

    ``target`` names the chip this service serves: the exact tier only reads
    that target's namespace, every published upgrade lands in it, and the
    donor pool comes from ``donor_target`` (default: ``target``).  Setting
    ``donor_target`` to a different chip is the explicit cross-target serving
    setup — e.g. an edge service transfer-tuning from a server-tuned store —
    with every donor re-validated under ``target``'s spec before it can win.
    """

    #: A queued job passed over by this many later-enqueued, higher-priority
    #: claims is counted as starved (once) — the telemetry that verifies a
    #: priority source (demand counts, the TuningAdvisor) is not freezing
    #: out cold workloads indefinitely.
    STARVATION_SKIPS = 8

    def __init__(self, registry, *, model_id: str = "serving",
                 runner: MeasureRunner | None = None, mode: str = "strict",
                 seed: int = 0, noise_sigma: float = 0.05,
                 donors: Sequence[str] | None = None,
                 budget_s: float = float("inf"), max_workers: int = 2,
                 probe_candidates: int | None = 4,
                 target=None, donor_target=None,
                 metrics: MetricsRegistry | None = None, tracer=None,
                 clock=None):
        self.registry = registry
        self.model_id = model_id
        self.runner, self.target = resolve_runner(runner, target)
        self.donor_target = (target_name(donor_target)
                             if donor_target is not None else self.target)
        self.mode = mode
        self.seed = seed
        self.noise_sigma = noise_sigma
        self.donors = list(donors) if donors is not None else None
        self.budget_s = budget_s
        self.probe_candidates = probe_candidates
        self._pool = ThreadPoolExecutor(max_workers) if max_workers > 0 else None
        self._lock = threading.Lock()
        # Separate from _lock: serializes the check-then-publish pair without
        # making lookups' counter bumps wait on registry fsyncs.
        self._publish_lock = threading.Lock()
        self._jobs: dict[str, _Job] = {}
        self._attempted: set[str] = set()
        #: workload keys in the order background jobs finished — the
        #: observable priority-queue behavior (tests assert hot-first).
        self.completed_order: list[str] = []
        self._job_seq = 0
        self._spent_s = 0.0
        self._probe_s = 0.0
        # Publish log for changed-workload notification: (generation before,
        # generation after, workload key) per publish this service performed.
        self._pub_events: list[tuple[int, int, str]] = []
        # Counters are registry-backed (namespaced by target so a fleet's
        # per-target services share one registry without colliding); the
        # tracer records the tuning timeline (lookups, job spans, publishes)
        # on the ``tuning/<target>`` track.  Increments stay guarded by
        # ``_lock`` exactly as the plain-dict versions were.
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # Queue ages are measured on the owner's clock: fleets pass their
        # virtual ``_now``; the default rides the tracer's bound clock
        # (0.0 under NULL_TRACER — ages degrade to 0, never crash).
        self._clock = clock if clock is not None else self.tracer.now
        self.trace_track = f"tuning/{self.target}"
        self._counters = self.metrics.group(f"tuning.{self.target}", [
            "lookups", "exact_hits", "transfer_hits", "default_misses",
            "jobs_enqueued", "jobs_deduped", "jobs_rejected_budget",
            "jobs_completed", "jobs_failed", "upgrades", "publish_skipped",
            "prefetches", "jobs_cancelled", "jobs_starved"])
        self._job_hist = self.metrics.histogram(
            f"tuning.{self.target}.job_search_s")
        self._queue_age_g = self.metrics.gauge(
            f"tuning.{self.target}.queue_age_mean_s")
        self._oldest_age_g = self.metrics.gauge(
            f"tuning.{self.target}.oldest_unstarted_age_s")

    # -- lookup ---------------------------------------------------------------
    def donor_models(self, db: ScheduleDB) -> list[str]:
        """Donor model ids the transfer tier and background jobs pool over
        (also what the TuningAdvisor estimates class headroom from)."""
        if self.donors is not None:
            return list(self.donors)
        return [m for m in db.models(target=self.donor_target)
                if m != self.model_id]

    def lookup(self, instance: KernelInstance) -> LookupResult:
        snap = self.registry.snapshot()
        # Pool over every mode: a record's mode tag certifies validity under
        # that mode, but candidates are re-validated here under self.mode (the
        # exact tier's seconds query and the probe measurements both raise /
        # invalidate on a bad bind), so cross-mode reuse is safe.
        db = snap.db(None)
        untuned = self.runner.seconds(instance, None)
        with self._lock:
            self._counters["lookups"] += 1

        # Best exact record overall, falling back to the best record published
        # under this service's own mode when the overall winner doesn't bind
        # (e.g. a faster adaptive-mode record shadowing a valid strict one).
        # Both reads stay inside this service's target namespace: a same-shape
        # record from another chip was selected under the wrong roofline (and
        # may not even fit this chip's VMEM), so it is never an exact hit.
        for exact in (db.exact(instance, target=self.target),
                      snap.db(self.mode).exact(instance, target=self.target)):
            if exact is None:
                continue
            try:
                secs = self.runner.seconds(instance, exact.schedule, mode=self.mode)
            except ScheduleInvalid:
                continue
            with self._lock:
                self._counters["exact_hits"] += 1
            self._trace_lookup(instance, "exact", snap.generation)
            return LookupResult(exact.schedule, "exact", secs, untuned,
                                exact.model_id, snap.generation)

        # Miss: queue the upgrade first so serving latency never gates search.
        self._enqueue(instance)

        # Tier 2: probe the strongest same-class donor candidates.
        # probe_candidates: 0 disables the tier (pure background-upgrade
        # serving), None probes the full pool, N > 0 caps serve-path probing.
        candidates: list[Record] = []
        if self.probe_candidates != 0:
            candidates = db.by_class(instance.class_id,
                                     models=self.donor_models(db),
                                     target=self.donor_target)
            if (self.probe_candidates is not None
                    and len(candidates) > self.probe_candidates):
                # Same ranking the offline transfer path truncates with.
                candidates = _strongest_first(candidates, self.probe_candidates,
                                              self.runner)
        if candidates:
            measured = self.runner.measure_many(
                instance, [r.schedule for r in candidates], mode=self.mode,
                seed=self.seed, noise_sigma=self.noise_sigma)
            best_secs, best = untuned, None
            probe_cost = 0.0
            for rec, m in zip(candidates, measured):
                probe_cost += m.measure_cost_s
                if m.valid and not m.pruned and m.seconds < best_secs:
                    best_secs, best = m.seconds, rec
            with self._lock:
                self._probe_s += probe_cost
            if best is not None:
                secs = self.runner.seconds(instance, best.schedule, mode=self.mode)
                with self._lock:
                    self._counters["transfer_hits"] += 1
                self._trace_lookup(instance, "transfer", snap.generation)
                return LookupResult(best.schedule, "transfer", secs, untuned,
                                    best.model_id, snap.generation)

        with self._lock:
            self._counters["default_misses"] += 1
        self._trace_lookup(instance, "default", snap.generation)
        return LookupResult(None, "default", untuned, untuned, "", snap.generation)

    def _trace_lookup(self, instance: KernelInstance, tier: str,
                      generation: int) -> None:
        if self.tracer.enabled:
            self.tracer.event("lookup", self.trace_track,
                              key=instance.workload_key(), tier=tier,
                              target=self.target, generation=generation)

    # -- background jobs ------------------------------------------------------
    def _enqueue(self, instance: KernelInstance, *,
                 priority: float = 0.0) -> bool:
        """Queue a background transfer-tuning job (dedup + budget gated).

        Returns True when a job for the workload is now pending (whether
        this call created it or one was already queued).
        """
        key = instance.workload_key()
        with self._lock:
            job = self._jobs.get(key)
            if job is not None:
                self._counters["jobs_deduped"] += 1
                # A hotter demand signal promotes an already-queued job.
                if not job.started and priority > job.priority:
                    job.priority = priority
                return not job.started
            if key in self._attempted:
                self._counters["jobs_deduped"] += 1
                return False
            if self._spent_s >= self.budget_s:
                self._counters["jobs_rejected_budget"] += 1
                return False
            self._job_seq += 1
            job = _Job(instance, priority=priority, seq=self._job_seq,
                       enqueued_t=self._clock())
            self._jobs[key] = job
            self._counters["jobs_enqueued"] += 1
            if self.tracer.enabled:
                self.tracer.event("enqueue", self.trace_track, key=key,
                                  priority=priority)
            if self._pool is not None:
                # The worker claims the best *unstarted* job at run time
                # rather than being bound to this key: a priority queue in
                # front of the pool, so prefetch promotions reorder work
                # that was submitted earlier but has not started yet.
                job.future = self._pool.submit(self._run_job)
            return True

    def prefetch(self, instance: KernelInstance, *,
                 priority: float = 0.0) -> bool:
        """Demand-driven enqueue: queue (or promote) a tuning job *ahead* of
        a serving miss.

        Fleets call this for the hottest unresolved shapes so upgrades land
        before demand peaks.  ``priority`` orders both the deferred drain
        queue and the threaded pool (higher first; FIFO within a priority):
        workers claim the highest-priority unstarted job when a pool slot
        frees up, so a promotion reorders queued work in either mode.
        Returns True when a job for the workload is pending.
        """
        with self._lock:
            self._counters["prefetches"] += 1
        return self._enqueue(instance, priority=priority)

    def attempted(self, key: str) -> bool:
        """Whether a background job for this workload key already ran
        (whether or not it published).  Advisors treat attempted workloads
        as exhausted: re-running the same deterministic search cannot find
        a different answer, so their priority budget goes elsewhere."""
        with self._lock:
            return key in self._attempted

    def pending_jobs(self) -> list[str]:
        """Workload keys awaiting background tuning, in deferred-drain order
        (highest priority first, then FIFO)."""
        with self._lock:
            jobs = [j for j in self._jobs.values() if not j.started]
        jobs.sort(key=lambda j: (-j.priority, j.seq))
        return [j.instance.workload_key() for j in jobs]

    def cancel_pending(self) -> int:
        """Drop queued jobs that have not started.

        Works in both modes: threaded workers claim jobs under the lock, so
        removing an unstarted job here means no worker will ever run it (its
        already-submitted future completes as a no-op).  The workloads are
        *not* marked attempted: a later lookup or prefetch may legitimately
        re-enqueue them.  Callers shutting down (e.g. a fleet at end of
        trace) use this so ``close()``'s drain does not spend search budget
        tuning shapes nobody is waiting for.
        """
        with self._lock:
            keys = [k for k, j in self._jobs.items() if not j.started]
            for k in keys:
                del self._jobs[k]
            self._counters["jobs_cancelled"] += len(keys)
        if keys and self.tracer.enabled:
            self.tracer.event("cancel", self.trace_track, jobs=len(keys))
        return len(keys)

    def _claim_best_locked(self) -> str | None:
        """Highest-priority unstarted workload key (FIFO within a priority).
        Caller holds ``_lock``."""
        best = None
        for k, j in self._jobs.items():
            if j.started:
                continue
            cand = (-j.priority, j.seq, k)
            if best is None or cand < best:
                best = cand
        return best[2] if best is not None else None

    def _note_claim_locked(self, winner: _Job) -> None:
        """Starvation accounting for one claim.  Caller holds ``_lock``.

        Every still-unstarted job that was enqueued *before* the claimed one
        was just passed over by a higher-priority claim; a job passed over
        more than :data:`STARVATION_SKIPS` times counts as starved (once).
        """
        for j in self._jobs.values():
            if j.started or j is winner or j.seq >= winner.seq:
                continue
            j.skips += 1
            if j.skips > self.STARVATION_SKIPS and not j.starved:
                j.starved = True
                self._counters["jobs_starved"] += 1

    def _run_job(self, key: str | None = None) -> bool:
        """Transfer-tune one missed workload and publish an upgrade.

        ``key=None`` (threaded workers) claims the best unstarted job under
        the lock — claim and mark-started are one critical section, so two
        workers can never pick the same job and none is orphaned.  Returns
        True when a better schedule was published."""
        with self._lock:
            if key is None:
                key = self._claim_best_locked()
                if key is None:
                    return False
            job = self._jobs.get(key)
            if job is None or job.started:
                return False
            # Re-check the budget at run time: jobs admitted while earlier
            # ones were still queued must not run once the budget is spent.
            if self._spent_s >= self.budget_s:
                self._counters["jobs_rejected_budget"] += 1
                self._jobs.pop(key, None)
                return False
            job.started = True
            self._note_claim_locked(job)
        instance = job.instance
        claim_t = self.tracer.now() if self.tracer.enabled else 0.0
        try:
            snap = self.registry.snapshot()
            db = snap.db(None)
            res = transfer_tune(
                [KernelUse(instance)], db, model_id=self.model_id,
                donors=self.donor_models(db), mode=self.mode, seed=self.seed,
                noise_sigma=self.noise_sigma, runner=self.runner,
                target=self.target, donor_target=self.donor_target)
            with self._lock:
                self._spent_s += res.search_time_s
            k = res.kernels[0]
            published = False
            if k.chosen is not None:
                published = self._publish(instance, k.chosen, k.seconds,
                                          k.chosen_from)
            with self._lock:
                self._counters["jobs_completed"] += 1
                self.completed_order.append(key)
            self._job_hist.observe(res.search_time_s)
            if self.tracer.enabled:
                # The span covers the job's *virtual search cost* from its
                # claim instant — the duration the budget was charged.
                self.tracer.add_async_span(
                    "tune", self.trace_track, claim_t,
                    claim_t + res.search_time_s, "tune", key, key=key,
                    priority=job.priority, published=published,
                    search_s=res.search_time_s, target=self.target,
                    donor_target=self.donor_target)
            return published
        except Exception:
            with self._lock:
                self._counters["jobs_failed"] += 1
            raise
        finally:
            with self._lock:
                self._attempted.add(key)
                self._jobs.pop(key, None)

    def _publish(self, instance: KernelInstance, schedule: Schedule,
                 seconds: float, donor: str) -> bool:
        """Publish atomically unless it would downgrade the visible best."""
        with self._publish_lock:
            current = self.registry.snapshot().db(None).exact(
                instance, target=self.target)
            if current is not None and current.seconds <= seconds:
                with self._lock:
                    self._counters["publish_skipped"] += 1
                return False
            gen_before = self.registry.generation
            comp_before = getattr(self.registry, "compactions", 0)
            gen_after = self.registry.publish(
                [Record(instance=instance, schedule=schedule, seconds=seconds,
                        model_id=self.model_id, target=self.target)],
                mode=self.mode)
            comp_delta = getattr(self.registry, "compactions", 0) - comp_before
            # gen_after may exceed gen_before + 1: auto-compaction inside
            # publish (keeps the best record per workload — attributable to
            # this key) or *another process's* publishes folded in when our
            # snapshot was stale (NOT attributable: those may change any
            # workload's answer).  A span wider than 1 + compactions poisons
            # the event (key None -> changed_since reports unknown).
            key = (instance.workload_key()
                   if gen_after == gen_before + 1 + comp_delta else None)
            with self._lock:
                self._counters["upgrades"] += 1
                self._pub_events.append((gen_before, gen_after, key))
                del self._pub_events[:-512]
            if self.tracer.enabled:
                self.tracer.event(
                    "publish", self.trace_track,
                    key=instance.workload_key(), seconds=seconds,
                    donor=donor, gen_before=gen_before, gen_after=gen_after,
                    target=self.target, donor_target=self.donor_target)
            return True

    # -- generation / change notification -------------------------------------
    def generation(self) -> int:
        """Registry generation visible to this service's lookups."""
        return self.registry.generation

    def changed_since(self, generation: int) -> set[str] | None:
        """Workload keys whose published best may have changed since
        ``generation``.

        Returns ``None`` when the generation bumps since then cannot all be
        attributed to this service's own publishes (another writer touched
        the registry, or the publish log was trimmed) — callers must then
        assume anything changed.  Resolution pipelines use this to migrate
        memoized entries across generations instead of re-resolving every
        workload after each background upgrade.
        """
        current = self.registry.generation
        if generation >= current:
            return set()
        with self._lock:
            events = sorted((e for e in self._pub_events if e[1] > generation),
                            key=lambda e: (e[0], e[1]))
        g = generation
        changed: set[str] = set()
        for g_before, g_after, key in events:
            if g_before > g:
                return None  # gap: a publish we did not perform
            if g_after > g:
                if key is None:
                    return None  # poisoned: external publishes folded in
                changed.add(key)
                g = g_after
        return changed if g == current else None

    def drain(self, max_jobs: int | None = None, timeout: float | None = None) -> int:
        """Complete queued background work; returns jobs finished.

        Deferred mode (``max_workers=0``) runs up to ``max_jobs`` queued jobs
        inline, oldest first — the deterministic stepping used by the
        benchmark's serve stream.  Threaded mode waits for in-flight futures.
        """
        finished = 0
        if self._pool is None:
            while True:
                with self._lock:
                    pending = [(-j.priority, j.seq, k)
                               for k, j in self._jobs.items()
                               if j.future is None and not j.started]
                if not pending or (max_jobs is not None and finished >= max_jobs):
                    return finished
                # Highest demand priority first, FIFO within a priority —
                # the order pending_jobs() reports.
                self._run_job(min(pending)[2])
                finished += 1
        while True:
            with self._lock:
                futures = [j.future for j in self._jobs.values()
                           if j.future is not None]
            if not futures:
                return finished
            done, _ = wait(futures, timeout=timeout)
            finished += len(done)
            if timeout is not None:
                return finished

    def close(self) -> None:
        """Drain outstanding work (including deferred jobs) and shut down."""
        self.drain()
        if self._pool is not None:
            self._pool.shutdown(wait=True)

    # -- telemetry ------------------------------------------------------------
    def stats(self) -> dict:
        now = self._clock()
        with self._lock:
            out = dict(self._counters)
            out["in_flight"] = len(self._jobs)
            out["search_seconds_spent"] = self._spent_s
            out["probe_search_s"] = self._probe_s
            out["budget_s"] = self.budget_s
            # Queue health: how long unstarted work has been waiting, and
            # the per-job view (age / skips / starved) for the starvation
            # audit the advisor's priority ordering is checked against.
            unstarted = [j for j in self._jobs.values() if not j.started]
            ages = [max(0.0, now - j.enqueued_t) for j in unstarted]
            out["queue_depth_unstarted"] = len(unstarted)
            out["queue_age_mean_s"] = sum(ages) / len(ages) if ages else 0.0
            out["oldest_unstarted_age_s"] = max(ages, default=0.0)
            out["queue_jobs"] = sorted(
                ({"key": j.instance.workload_key(), "priority": j.priority,
                  "age_s": max(0.0, now - j.enqueued_t), "skips": j.skips,
                  "starved": j.starved} for j in unstarted),
                key=lambda r: -r["age_s"])
        self._queue_age_g.sample(out["queue_age_mean_s"], now)
        self._oldest_age_g.sample(out["oldest_unstarted_age_s"], now)
        out["generation"] = self.registry.generation
        out["target"] = self.target
        out["donor_target"] = self.donor_target
        lookups = out["lookups"] or 1
        out["exact_hit_rate"] = out["exact_hits"] / lookups
        return out
