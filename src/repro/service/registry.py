"""Segmented persistent schedule registry: the online scale path of ScheduleDB.

`ScheduleDB.save()` rewrites the whole store on every change — fine for an
offline batch run, unusable when background tuning jobs publish records while
a serving path reads them.  :class:`ScheduleRegistry` replaces the monolithic
file with an append-only *segmented* store:

* every ``publish()`` writes one new JSONL **segment** (tmp file + fsync +
  ``os.replace``) and then atomically swaps ``MANIFEST.json`` to reference
  it — readers observe either the old or the new generation, never a torn
  store;
* a **generation counter** in the manifest increments on every publish and
  compaction, so cheap staleness checks (``refresh()``) and telemetry work
  across processes;
* **lock-free snapshot reads**: the in-process view is an immutable
  :class:`RegistrySnapshot` swapped wholesale under the writer lock; readers
  (the serving path) just dereference an attribute — no lock, no torn state;
* **compaction** folds all segments into one, keeping the best record per
  ``(workload, mode, target)`` — the serving registry's steady-state
  footprint is one record per workload it has ever answered per chip; with
  ``auto_compact_segments=N`` it fires automatically once a publish pushes
  the segment count past N;
* **merge** of concurrently produced :class:`~repro.core.database.ScheduleDB`
  instances is just ``merge_db()``: each producer lands as its own segment
  and compaction resolves duplicates later.

Crash recovery: segments are only ever appended; a crash mid-write can leave
a partial trailing line, which the reader drops (counted in
``recovered_partial_lines``).  Corruption *before* the tail is a real error.
Segment and manifest headers carry the same schema ``version`` field as
``ScheduleDB.save`` payloads and are validated by the shared
:func:`repro.core.database.check_schema_version`.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import threading
from typing import Iterable, Mapping, Sequence

from repro.core.database import (
    Record,
    SCHEMA_VERSION,
    ScheduleDB,
    check_schema_version,
)

MANIFEST_NAME = "MANIFEST.json"
SEGMENT_DIR = "segments"


class RegistryError(RuntimeError):
    """The registry's on-disk state is unreadable (beyond crash recovery)."""


@dataclasses.dataclass(frozen=True)
class RegistryRecord:
    """One published schedule record plus the transfer mode it is valid under.

    ``mode`` matters because an ``adaptive``-mode transfer may bind a schedule
    that is invalid under ``strict`` concretization — a strict serving path
    must not pick it up.
    """

    record: Record
    mode: str = "strict"

    def to_json(self) -> dict:
        return {"record": self.record.to_json(), "mode": self.mode}

    @staticmethod
    def from_json(d: Mapping) -> "RegistryRecord":
        return RegistryRecord(record=Record.from_json(d["record"]),
                              mode=d.get("mode", "strict"))

    def key(self) -> tuple[str, str, str]:
        # Target is part of the dedup key: compaction must never fold a
        # record tuned for one chip into another chip's namespace.
        return (self.record.instance.workload_key(), self.mode,
                self.record.target)


class RegistrySnapshot:
    """Immutable point-in-time view of the registry.

    Built once per publish/compaction/refresh and swapped atomically into the
    registry, so readers never lock: ``registry.snapshot()`` is a plain
    attribute read and everything reachable from the result is frozen.
    Per-mode :class:`ScheduleDB` views are prebuilt here (not lazily) to keep
    the read path allocation- and lock-free.
    """

    def __init__(self, generation: int, records: Iterable[RegistryRecord]):
        self.generation = generation
        self.records: tuple[RegistryRecord, ...] = tuple(records)
        dbs: dict[str | None, ScheduleDB] = {None: ScheduleDB()}
        for rr in self.records:
            dbs[None].add(rr.record)
            dbs.setdefault(rr.mode, ScheduleDB()).add(rr.record)
        self._dbs = {k: db.freeze() for k, db in dbs.items()}

    def db(self, mode: str | None = None) -> ScheduleDB:
        """Records published under ``mode`` as a ScheduleDB (None = all).

        The returned view is shared between every reader of this snapshot and
        frozen — copy via ``ScheduleDB(view.records())`` to mutate.
        """
        return self._dbs.get(mode) or ScheduleDB().freeze()

    def __len__(self) -> int:
        return len(self.records)


def _atomic_write(path: str, data: str) -> None:
    d = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


class ScheduleRegistry:
    """Directory-backed segmented schedule store with atomic publish.

    Layout::

        root/MANIFEST.json            {"version", "generation", "next_segment",
                                       "segments": [...]}
        root/segments/seg-000001.jsonl   header line + one record per line

    Writers (publish / compact) serialize on an in-process lock; readers are
    lock-free (see :class:`RegistrySnapshot`).  Multi-process publishing is
    last-writer-wins on the manifest — concurrent *producers* should each
    write their own registry (or ScheduleDB) and be folded in with
    :meth:`merge_db`, the pattern the tuning service uses.
    """

    def __init__(self, root: str, *, auto_compact_segments: int | None = None):
        """``auto_compact_segments=N`` makes ``publish()`` fold the store the
        moment the segment count crosses N — a long-lived service otherwise
        accumulates one segment per publish, unboundedly."""
        if auto_compact_segments is not None and auto_compact_segments < 1:
            raise ValueError("auto_compact_segments must be >= 1")
        self.root = os.path.abspath(root)
        os.makedirs(os.path.join(self.root, SEGMENT_DIR), exist_ok=True)
        self._write_lock = threading.Lock()
        self.auto_compact_segments = auto_compact_segments
        self.compactions = 0
        self.recovered_partial_lines = 0
        if not os.path.exists(self._manifest_path()):
            self._write_manifest({"version": SCHEMA_VERSION, "generation": 0,
                                  "next_segment": 1, "segments": []})
        self._snapshot = self._load()

    # -- paths / manifest -----------------------------------------------------
    def _manifest_path(self) -> str:
        return os.path.join(self.root, MANIFEST_NAME)

    def _segment_path(self, name: str) -> str:
        return os.path.join(self.root, SEGMENT_DIR, name)

    def _read_manifest(self) -> dict:
        with open(self._manifest_path()) as f:
            manifest = json.load(f)
        check_schema_version(manifest, source=self._manifest_path())
        return manifest

    def _write_manifest(self, manifest: dict) -> None:
        _atomic_write(self._manifest_path(), json.dumps(manifest, indent=1))

    # -- segment IO -----------------------------------------------------------
    def _read_segment(self, name: str) -> list[RegistryRecord]:
        path = self._segment_path(name)
        with open(path) as f:
            raw = f.read()
        lines = raw.split("\n")
        while lines and lines[-1] == "":
            lines.pop()
        if not lines:
            return []
        try:
            header = json.loads(lines[0])
        except json.JSONDecodeError as e:
            raise RegistryError(f"{path}: unreadable segment header: {e}") from e
        check_schema_version(header, source=path)
        out: list[RegistryRecord] = []
        for i, line in enumerate(lines[1:], start=1):
            try:
                out.append(RegistryRecord.from_json(json.loads(line)))
            except json.JSONDecodeError as e:
                if i == len(lines) - 1:
                    # Crash mid-append: the partial tail never became visible
                    # as a record; drop it and keep the complete prefix.
                    self.recovered_partial_lines += 1
                    break
                raise RegistryError(
                    f"{path}:{i + 1}: corrupt record mid-segment: {e}") from e
        return out

    def _write_segment(self, name: str, records: Sequence[RegistryRecord]) -> None:
        lines = [json.dumps({"version": SCHEMA_VERSION, "kind": "segment"})]
        lines += [json.dumps(rr.to_json()) for rr in records]
        _atomic_write(self._segment_path(name), "\n".join(lines) + "\n")

    def _load(self) -> RegistrySnapshot:
        # A concurrent compaction can swap the manifest and delete a segment
        # between our manifest read and segment read — re-read and retry (the
        # new manifest no longer references the deleted file).
        for _ in range(8):
            manifest = self._read_manifest()
            records: list[RegistryRecord] = []
            try:
                for name in manifest["segments"]:
                    records.extend(self._read_segment(name))
            except FileNotFoundError:
                continue
            return RegistrySnapshot(manifest["generation"], records)
        raise RegistryError(
            f"{self.root}: manifest kept referencing vanished segments across "
            "retries — concurrent writer misbehaving?")

    # -- reads ----------------------------------------------------------------
    @property
    def generation(self) -> int:
        return self._snapshot.generation

    def snapshot(self) -> RegistrySnapshot:
        """Current immutable view — lock-free, safe to hold across publishes."""
        return self._snapshot

    def refresh(self) -> RegistrySnapshot:
        """Re-read the manifest, picking up publishes from other processes."""
        with self._write_lock:
            manifest = self._read_manifest()
            if manifest["generation"] != self._snapshot.generation:
                self._snapshot = self._load()
            return self._snapshot

    def stats(self) -> dict:
        manifest = self._read_manifest()
        return {
            "generation": self._snapshot.generation,
            "records": len(self._snapshot),
            "segments": len(manifest["segments"]),
            "targets": sorted({rr.record.target for rr in self._snapshot.records}),
            "compactions": self.compactions,
            "auto_compact_segments": self.auto_compact_segments,
            "recovered_partial_lines": self.recovered_partial_lines,
        }

    # -- writes ---------------------------------------------------------------
    def publish(self, records: Iterable[Record | RegistryRecord],
                mode: str = "strict") -> int:
        """Atomically publish a batch of records as one new segment.

        Bare :class:`Record` inputs are tagged with ``mode``.  Returns the new
        generation; an empty batch is a no-op returning the current one.
        """
        rrs = [r if isinstance(r, RegistryRecord) else RegistryRecord(r, mode)
               for r in records]
        if not rrs:
            return self.generation
        with self._write_lock:
            manifest = self._read_manifest()
            # Another process may have published since our snapshot was built;
            # appending to the stale in-memory records would hide its segments
            # forever (refresh() no-ops once generations match again).
            stale = manifest["generation"] != self._snapshot.generation
            name = f"seg-{manifest['next_segment']:06d}.jsonl"
            self._write_segment(name, rrs)
            manifest["segments"].append(name)
            manifest["next_segment"] += 1
            manifest["generation"] += 1
            self._write_manifest(manifest)
            if stale:
                self._snapshot = self._load()
            else:
                self._snapshot = RegistrySnapshot(
                    manifest["generation"], self._snapshot.records + tuple(rrs))
            if (self.auto_compact_segments is not None
                    and len(manifest["segments"]) > self.auto_compact_segments):
                self._compact_locked()
            return self._snapshot.generation

    def merge_db(self, db: ScheduleDB, mode: str = "strict") -> int:
        """Fold a concurrently produced ScheduleDB in as one segment."""
        return self.publish(db.records(), mode=mode)

    def compact(self) -> int:
        """Fold all segments into one, keeping the best record per
        (workload, mode, target).  Readers holding the old snapshot are
        unaffected; the manifest swap is atomic and old segment files are
        removed only after it lands."""
        with self._write_lock:
            return self._compact_locked()

    def _compact_locked(self) -> int:
        # Caller holds self._write_lock (publish() auto-compaction re-enters
        # here without re-acquiring the non-reentrant lock).
        manifest = self._read_manifest()
        records: list[RegistryRecord] = []
        for name in manifest["segments"]:
            records.extend(self._read_segment(name))
        best: dict[tuple[str, str, str], RegistryRecord] = {}
        for rr in records:
            cur = best.get(rr.key())
            if cur is None or rr.record.seconds < cur.record.seconds:
                best[rr.key()] = rr
        kept = sorted(
            best.values(),
            key=lambda rr: (rr.record.target, rr.record.instance.class_id,
                            rr.mode, rr.record.instance.workload_key()))
        old_segments = list(manifest["segments"])
        name = f"seg-{manifest['next_segment']:06d}.jsonl"
        self._write_segment(name, kept)
        manifest["segments"] = [name]
        manifest["next_segment"] += 1
        manifest["generation"] += 1
        self._write_manifest(manifest)
        self._snapshot = RegistrySnapshot(manifest["generation"], kept)
        self.compactions += 1
        for old in old_segments:
            if old != name and os.path.exists(self._segment_path(old)):
                os.unlink(self._segment_path(old))
        return self._snapshot.generation
