"""Online schedule-registry service.

Serves tuned schedules behind the serving path and upgrades them with
background transfer-tuning:

    ScheduleRegistry ... segmented persistent store (registry.py)
    TuningService ...... tiered lookup + background jobs (tuning_service.py)
"""
from repro.service.registry import (
    RegistryError,
    RegistryRecord,
    RegistrySnapshot,
    ScheduleRegistry,
)
from repro.service.tuning_service import LookupResult, TuningService

__all__ = [
    "LookupResult",
    "RegistryError",
    "RegistryRecord",
    "RegistrySnapshot",
    "ScheduleRegistry",
    "TuningService",
]
