"""Shared model substrate: norms, RoPE, initializers, GLU weight packing.

Parameters are plain nested dicts of jnp arrays (pytrees) — no framework
dependency.  All init functions are pure in their PRNG key so they can be
traced by ``jax.eval_shape`` for the allocation-free dry-run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key: jax.Array, fan_in: int, fan_out: int, dtype) -> jax.Array:
    scale = (2.0 / (fan_in + fan_out)) ** 0.5
    return (jax.random.normal(key, (fan_in, fan_out), jnp.float32) * scale).astype(dtype)


def embed_init(key: jax.Array, vocab: int, dim: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * dim ** -0.5).astype(dtype)


def pack_glu(w_gate: jax.Array, w_up: jax.Array) -> jax.Array:
    """Interleave gate/up columns: (K, F) + (K, F) -> (K, 2F) with columns
    (g0, u0, g1, u1, ...).  Required by the fused GLU kernel epilogue —
    each N-block then holds complete (gate, up) pairs."""
    k, f = w_gate.shape
    return jnp.stack([w_gate, w_up], axis=2).reshape(k, 2 * f)


def glu_init(key: jax.Array, d: int, f: int, dtype) -> jax.Array:
    kg, ku = jax.random.split(key)
    return pack_glu(dense_init(kg, d, f, dtype), dense_init(ku, d, f, dtype))


# ---------------------------------------------------------------------------
# Norms (computed in f32, cast back)
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return y.astype(x.dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return y.astype(x.dtype)


def norm_params(d: int, kind: str, dtype) -> dict:
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def apply_norm(p: dict, x: jax.Array, kind: str) -> jax.Array:
    if kind == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, H, S, D); positions: (B, S) or (S,) absolute positions."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (D/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(ang)[:, None, :, :]
    sin = jnp.sin(ang)[:, None, :, :]
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., 0::2], xf[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    return jnp.stack([y1, y2], axis=-1).reshape(x.shape).astype(x.dtype)


def count_params(tree) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(tree))
