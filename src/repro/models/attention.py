"""Attention blocks: GQA/MQA projections, RoPE, global + local/SWA variants,
logit softcapping, and KV caches (full for global layers, ring buffer sized
to the window for local/SWA layers — what makes long_500k decode feasible).

Three entry points per layer kind:
  * ``attn_forward``   — full-sequence (train / prefill), returns new cache
  * ``attn_decode``    — single-token step against the cache
  * ``init_attn_cache``

All heavy math routes through :mod:`repro.kernels.ops` so tuned schedules
(transfer-tuned or native) apply.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops
from repro.models.common import apply_norm, apply_rope, dense_init, dtype_of, norm_params


def attn_params(key: jax.Array, cfg: ArchConfig) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    dt = dtype_of(cfg.dtype)
    return {
        "wq": dense_init(kq, d, cfg.n_heads * hd, dt),
        "wk": dense_init(kk, d, cfg.n_kv_heads * hd, dt),
        "wv": dense_init(kv, d, cfg.n_kv_heads * hd, dt),
        "wo": dense_init(ko, cfg.n_heads * hd, d, dt),
    }


def _attn_class(cfg: ArchConfig, kind: str, cross: bool = False) -> str:
    if cross:
        return "flash_attention_cross"
    if kind == "L":
        return "flash_attention_swa" if len(set(cfg.layer_kinds)) == 1 else "flash_attention_local"
    if cfg.attn_softcap > 0:
        return "flash_attention_softcap"
    return "flash_attention_causal"


def _qkv(p: dict, cfg: ArchConfig, x: jax.Array, provider) -> tuple[jax.Array, jax.Array, jax.Array]:
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = ops.matmul(x, p["wq"], provider=provider).reshape(b, s, cfg.n_heads, hd)
    k = ops.matmul(x, p["wk"], provider=provider).reshape(b, s, cfg.n_kv_heads, hd)
    v = ops.matmul(x, p["wv"], provider=provider).reshape(b, s, cfg.n_kv_heads, hd)
    return q, k, v


def _rope_qk(cfg: ArchConfig, q, k, positions):
    if cfg.pos != "rope":
        return q, k
    return (apply_rope(q, positions, cfg.rope_theta),
            apply_rope(k, positions, cfg.rope_theta))


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


def init_attn_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int) -> dict:
    """Full cache for global layers; window-sized ring for local/SWA."""
    size = max_len if (kind == "G" or cfg.window == 0) else min(cfg.window, max_len)
    dt = dtype_of(cfg.dtype)
    return {
        "k": jnp.zeros((batch, cfg.n_kv_heads, size, cfg.head_dim), dt),
        "v": jnp.zeros((batch, cfg.n_kv_heads, size, cfg.head_dim), dt),
    }


def _cache_size(cache: dict) -> int:
    return cache["k"].shape[2]


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def attn_forward(p: dict, cfg: ArchConfig, x: jax.Array, kind: str, *,
                 positions: jax.Array, provider=None,
                 cache: dict | None = None) -> tuple[jax.Array, dict | None]:
    """x: (B, S, D) normalized input. Returns (attn_out, updated_cache)."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, cfg, x, provider)
    q = jnp.swapaxes(q, 1, 2)  # (B, H, S, hd)
    k = jnp.swapaxes(k, 1, 2)
    v = jnp.swapaxes(v, 1, 2)
    q, k = _rope_qk(cfg, q, k, positions)

    window = cfg.window if kind == "L" else 0
    out = ops.flash_attention(
        q, k, v,
        class_id=_attn_class(cfg, kind),
        causal=True,
        window=window,
        softcap=cfg.attn_softcap if kind == "G" else 0.0,
        provider=provider,
    )
    out = jnp.swapaxes(out, 1, 2).reshape(b, s, cfg.n_heads * cfg.head_dim)
    y = ops.matmul(out, p["wo"], provider=provider)

    new_cache = None
    if cache is not None:
        size = _cache_size(cache)
        if size >= s:
            new_cache = {
                "k": jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0)),
                "v": jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0)),
            }
        else:  # ring prefill: keep last `size` positions, slot convention p % size
            shift = (s - size) % size
            new_cache = {
                "k": jnp.roll(k[:, :, s - size:, :], shift, axis=2),
                "v": jnp.roll(v[:, :, s - size:, :], shift, axis=2),
            }
    return y, new_cache


# ---------------------------------------------------------------------------
# Chunked prefill (a prompt slice against a partially filled cache)
# ---------------------------------------------------------------------------


def attn_chunk(p: dict, cfg: ArchConfig, x: jax.Array, kind: str, *,
               positions: jax.Array, off: jax.Array, cache: dict,
               provider=None) -> tuple[jax.Array, dict]:
    """One prefill chunk: queries at absolute positions ``off .. off+C-1``
    attend to the cache prefix (positions ``< off``) plus the chunk itself.

    ``off`` may be a traced scalar — masks are position arithmetic, so one
    trace per chunk *length* covers every offset.  Full-length caches get
    the chunk spliced in before a causally masked pass over the whole
    buffer; ring caches attend over [ring prefix ‖ chunk] with explicit
    position masks and are updated *after* attention (pre-writing a chunk
    into the ring would overwrite positions earlier in-chunk queries still
    need).
    """
    b, s, _ = x.shape
    off = jnp.asarray(off, jnp.int32)
    q, k, v = _qkv(p, cfg, x, provider)
    q = jnp.swapaxes(q, 1, 2)  # (B, H, C, hd)
    k = jnp.swapaxes(k, 1, 2)  # (B, KV, C, hd)
    v = jnp.swapaxes(v, 1, 2)
    q, k = _rope_qk(cfg, q, k, positions)

    size = _cache_size(cache)
    window = cfg.window if kind == "L" else 0
    if kind == "G" or cfg.window == 0:
        # Full-length buffer: splice the chunk at [off, off+C), then one
        # causal pass over the whole buffer — positions beyond off+C hold
        # garbage but the causal mask (kv_pos <= q_pos < off+C) hides them.
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, 0, off, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, 0, off, 0))
        out = ops.flash_attention(
            q, ck, cv,
            class_id=_attn_class(cfg, kind),
            causal=True, window=0,
            softcap=cfg.attn_softcap if kind == "G" else 0.0,
            q_offset=off, provider=provider,
        )
        new_cache = {"k": ck, "v": cv}
    else:
        # Ring cache (slot convention p % size): reconstruct each slot's
        # absolute position — the latest p < off congruent to the slot —
        # and attend over [ring ‖ chunk] under causal+window+validity masks.
        slots = jnp.arange(size)
        ring_pos = off - 1 - jnp.mod(off - 1 - slots, size)  # < 0 -> unwritten
        kv_pos = jnp.concatenate([ring_pos, off + jnp.arange(s)])
        q_pos = off + jnp.arange(s)
        ok = (kv_pos[None, :] >= 0) & (kv_pos[None, :] <= q_pos[:, None])
        if window > 0:
            ok = ok & (kv_pos[None, :] > q_pos[:, None] - window)
        kk = jnp.concatenate([cache["k"].astype(k.dtype), k], axis=2)
        vv = jnp.concatenate([cache["v"].astype(v.dtype), v], axis=2)
        out = _masked_chunk_attention(q, kk, vv, ok, cfg,
                                      softcap=cfg.attn_softcap if kind == "G" else 0.0)
        # Write-after-attention: slot (off+i) % size takes position off+i;
        # ascending i means later (newer) positions win on wrap.
        if s >= size:
            shift = jnp.mod(off + s, size)
            new_cache = {
                "k": jnp.roll(k[:, :, s - size:, :].astype(cache["k"].dtype),
                              shift, axis=2),
                "v": jnp.roll(v[:, :, s - size:, :].astype(cache["v"].dtype),
                              shift, axis=2),
            }
        else:
            wslots = jnp.mod(off + jnp.arange(s), size)
            new_cache = {
                "k": cache["k"].at[:, :, wslots, :].set(k.astype(cache["k"].dtype)),
                "v": cache["v"].at[:, :, wslots, :].set(v.astype(cache["v"].dtype)),
            }
    out = jnp.swapaxes(out, 1, 2).reshape(b, s, cfg.n_heads * cfg.head_dim)
    y = ops.matmul(out, p["wo"], provider=provider)
    return y, new_cache


def _masked_chunk_attention(q, k, v, valid_mask, cfg: ArchConfig,
                            softcap: float = 0.0):
    """Multi-query attention with an explicit (C, T) validity mask — the
    chunk analogue of :func:`_masked_decode_attention` (ring semantics need
    per-position masks the flash kernel's causal/window params can't say)."""
    b, hq, c, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    qg = q.reshape(b, hkv, group, c, d).astype(jnp.float32) * d ** -0.5
    s = jnp.einsum("bhgqd,bhtd->bhgqt", qg, k.astype(jnp.float32))
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(valid_mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqt,bhtd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, c, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode (single token against cache)
# ---------------------------------------------------------------------------


def attn_decode(p: dict, cfg: ArchConfig, x: jax.Array, kind: str, *,
                pos: jax.Array, cache: dict, provider=None) -> tuple[jax.Array, dict]:
    """x: (B, 1, D); pos: (B,) per-sequence absolute positions (continuous
    batching: every slot may be at a different decode position)."""
    b = x.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    q, k, v = _qkv(p, cfg, x, provider)
    q = jnp.swapaxes(q, 1, 2)   # (B, H, 1, hd)
    k = jnp.swapaxes(k, 1, 2)   # (B, KV, 1, hd)
    v = jnp.swapaxes(v, 1, 2)
    q, k = _rope_qk(cfg, q, k, pos[:, None])

    size = _cache_size(cache)
    slot = jnp.where(size > pos, pos, pos % size)           # (B,) ring for local
    bi = jnp.arange(b)[:, None]
    hi = jnp.arange(cfg.n_kv_heads)[None, :]
    ck = cache["k"].at[bi, hi, slot[:, None], :].set(k[:, :, 0, :].astype(cache["k"].dtype))
    cv = cache["v"].at[bi, hi, slot[:, None], :].set(v[:, :, 0, :].astype(cache["v"].dtype))

    window = cfg.window if kind == "L" else 0
    slots = jnp.arange(size)[None, :]                       # (1, size)
    if window and size <= window:
        # Ring cache: live slots hold the last `size` (≤ window) positions,
        # so the window constraint holds by construction; only not-yet-
        # written slots (before the ring wraps) need masking.
        valid = slots < jnp.minimum(pos + 1, size)[:, None]
        out = _masked_decode_attention(q, ck, cv, valid, cfg)
    else:
        valid = slots <= pos[:, None]
        out = _masked_decode_attention(q, ck, cv, valid, cfg,
                                       softcap=cfg.attn_softcap if kind == "G" else 0.0)
    out = jnp.swapaxes(out, 1, 2).reshape(b, 1, cfg.n_heads * cfg.head_dim)
    y = ops.matmul(out, p["wo"], provider=provider)
    return y, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# Speculative verify (k+1 draft positions against cache, per-lane offsets)
# ---------------------------------------------------------------------------


def attn_verify(p: dict, cfg: ArchConfig, x: jax.Array, kind: str, *,
                off: jax.Array, cache: dict, provider=None) -> tuple[jax.Array, dict]:
    """x: (B, C, D); off: (B,) per-lane absolute write offsets.

    The speculative analogue of :func:`attn_chunk`, batched across lanes
    that each sit at a *different* cache offset (continuous batching), which
    is exactly what ``attn_chunk``'s shared scalar ``off`` cannot express.
    Batching matters: verifying lanes one at a time streams the full weights
    per lane (memory-bound, ≈ one decode step each) and erases the
    speculative win; one batched call streams them once.

    Rows ``off+C .. size-1`` may hold garbage from a previous over-write
    (rejected draft positions) — the validity mask hides them, and later
    steps overwrite them in order, so no explicit rollback pass is needed.
    Full-length caches only (ring/local layers lose rejected-position
    history); callers gate on :func:`repro.serving.speculative.spec_exact_reason`.
    """
    b, s, _ = x.shape
    off = jnp.broadcast_to(jnp.asarray(off, jnp.int32), (b,))
    q, k, v = _qkv(p, cfg, x, provider)
    q = jnp.swapaxes(q, 1, 2)   # (B, H, C, hd)
    k = jnp.swapaxes(k, 1, 2)   # (B, KV, C, hd)
    v = jnp.swapaxes(v, 1, 2)
    positions = off[:, None] + jnp.arange(s)                # (B, C)
    q, k = _rope_qk(cfg, q, k, positions)

    size = _cache_size(cache)
    bi = jnp.arange(b)[:, None, None]
    hi = jnp.arange(cfg.n_kv_heads)[None, :, None]
    rows = positions[:, None, :]                            # (B, 1, C)
    ck = cache["k"].at[bi, hi, rows, :].set(k.astype(cache["k"].dtype))
    cv = cache["v"].at[bi, hi, rows, :].set(v.astype(cache["v"].dtype))

    slots = jnp.arange(size)
    ok = slots[None, None, :] <= positions[:, :, None]      # (B, C, T)
    out = _masked_verify_attention(q, ck, cv, ok, cfg,
                                   softcap=cfg.attn_softcap if kind == "G" else 0.0)
    out = jnp.swapaxes(out, 1, 2).reshape(b, s, cfg.n_heads * cfg.head_dim)
    y = ops.matmul(out, p["wo"], provider=provider)
    return y, {"k": ck, "v": cv}


def _masked_verify_attention(q, k, v, valid_mask, cfg: ArchConfig,
                             softcap: float = 0.0):
    """Multi-query attention with a per-lane (B, C, T) validity mask — the
    verify analogue of :func:`_masked_chunk_attention`, whose (C, T) mask is
    shared across the batch and cannot express per-lane offsets."""
    b, hq, c, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    qg = q.reshape(b, hkv, group, c, d).astype(jnp.float32) * d ** -0.5
    s = jnp.einsum("bhgqd,bhtd->bhgqt", qg, k.astype(jnp.float32))
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(valid_mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqt,bhtd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, c, d).astype(q.dtype)


def _masked_decode_attention(q, k, v, valid_mask, cfg: ArchConfig, softcap: float = 0.0):
    """Single-query attention over the whole cache with an explicit (B, size)
    validity mask (handles causal prefix and ring-buffer semantics)."""
    b, hq, _, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    qg = q.reshape(b, hkv, group, d).astype(jnp.float32) * d ** -0.5
    s = jnp.einsum("bhgd,bhkd->bhgk", qg, k.astype(jnp.float32))
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(valid_mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, 1, d).astype(q.dtype)
