"""Model facade: one uniform interface over all 10 architecture families.

``build_model(cfg)`` returns a :class:`Model` bundling init / forward / loss /
prefill / decode_step / init_cache / input_specs.  ``input_specs`` produces
``jax.ShapeDtypeStruct`` stand-ins for every model input of a shape cell
(the dry-run contract: weak-type-correct, shardable, no allocation) — for
[audio]/[vlm] archs this is where the stub frontend lives (precomputed
frame/patch embeddings).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import encdec, lm
from repro.models.common import dtype_of


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable[[jax.Array], Any]
    forward: Callable[..., tuple[jax.Array, jax.Array]]
    loss_fn: Callable[..., tuple[jax.Array, dict]]
    prefill: Callable[..., tuple[jax.Array, Any]]
    decode_step: Callable[..., tuple[jax.Array, Any]]
    init_cache: Callable[[int, int], Any]
    # chunked prefill (paged serving); None for families without it (audio)
    prefill_chunk: Callable[..., tuple[jax.Array, Any]] | None = None
    # speculative verify (k+1 positions, per-lane offsets); None for audio
    verify_step: Callable[..., tuple[jax.Array, Any]] | None = None

    def input_specs(self, shape: ShapeConfig, *, batch_override: int | None = None) -> dict:
        return input_specs(self.cfg, shape, batch_override=batch_override)

    def abstract_params(self):
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    def abstract_cache(self, batch: int, max_len: int):
        return jax.eval_shape(lambda: self.init_cache(batch, max_len))


def build_model(cfg: ArchConfig) -> Model:
    if cfg.family == "audio":
        return Model(
            cfg=cfg,
            init=lambda key: encdec.init_params(key, cfg),
            forward=lambda p, batch, **kw: encdec.forward(p, cfg, batch, **kw),
            loss_fn=lambda p, batch, **kw: encdec.loss_fn(p, cfg, batch, **kw),
            prefill=lambda p, batch, **kw: encdec.prefill(p, cfg, batch, **kw),
            decode_step=lambda p, cache, tok, **kw: encdec.decode_step(p, cfg, cache, tok, **kw),
            init_cache=lambda b, n: encdec.init_cache(cfg, b, n),
        )
    return Model(
        cfg=cfg,
        init=lambda key: lm.init_params(key, cfg),
        forward=lambda p, batch, **kw: lm.forward(p, cfg, batch, **kw),
        loss_fn=lambda p, batch, **kw: lm.loss_fn(p, cfg, batch, **kw),
        prefill=lambda p, batch, **kw: lm.prefill(p, cfg, batch, **kw),
        decode_step=lambda p, cache, tok, **kw: lm.decode_step(p, cfg, cache, tok, **kw),
        init_cache=lambda b, n: lm.init_cache(cfg, b, n),
        prefill_chunk=lambda p, cache, tok, off, **kw: lm.prefill_chunk(
            p, cfg, cache, tok, off, **kw),
        verify_step=lambda p, cache, tok, off, **kw: lm.verify_step(
            p, cfg, cache, tok, off, **kw),
    )


def input_specs(cfg: ArchConfig, shape: ShapeConfig, *,
                batch_override: int | None = None) -> dict:
    """ShapeDtypeStruct stand-ins for one shape cell's model inputs.

    train/prefill: token batch (+ stub modality embeddings);
    decode: one token per sequence + the KV/recurrent cache of length seq_len.
    """
    b = batch_override if batch_override is not None else shape.global_batch
    s = shape.seq_len
    act = dtype_of(cfg.dtype)
    i32 = jnp.int32

    if shape.kind in ("train", "prefill"):
        specs: dict[str, Any] = {}
        if cfg.family == "audio":
            specs["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model), act)
            specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        elif cfg.vision_tokens:
            specs["patch_embeds"] = jax.ShapeDtypeStruct((b, cfg.vision_tokens, cfg.d_model), act)
            specs["tokens"] = jax.ShapeDtypeStruct((b, s - cfg.vision_tokens), i32)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        return specs

    # decode: one new token against a cache of seq_len context
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(b, s))
    return {"tokens": jax.ShapeDtypeStruct((b,), i32), "cache": cache}
