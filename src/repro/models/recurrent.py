"""Recurrent blocks: RWKV6 (Finch) and Griffin's RG-LRU recurrent block.

RWKV6 block = time-mix (token-shift interpolation, r/k/v/gate projections,
data-dependent decay via a low-rank adapter, the wkv scan, per-head group
norm, output gate) + channel-mix (token-shift, squared-relu FFN with
receptance gating).  Decode keeps (wkv state, last hidden) per layer.

Griffin recurrent block = two branches from the residual stream:
gelu-gated branch, and conv1d → RG-LRU branch; multiplied and projected
out.  Gates are per-channel (diagonal) — a recorded simplification vs the
paper's block-dense gates (DESIGN.md).  Decode keeps (lru state, conv tail).

Scans route through :mod:`repro.kernels.ops` (rwkv6 / rglru kernels).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops
from repro.models.common import dense_init, dtype_of, rmsnorm

DECAY_LORA = 64


# ---------------------------------------------------------------------------
# RWKV6
# ---------------------------------------------------------------------------


def rwkv_params(key: jax.Array, cfg: ArchConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    h, hd = cfg.n_heads, cfg.head_dim
    dt = dtype_of(cfg.dtype)
    ks = jax.random.split(key, 10)
    return {
        # time-mix
        "mu": jnp.full((5, d), 0.5, dt),               # shift mix for r,k,v,w,g
        "wr": dense_init(ks[0], d, d, dt),
        "wk": dense_init(ks[1], d, d, dt),
        "wv": dense_init(ks[2], d, d, dt),
        "wg": dense_init(ks[3], d, d, dt),
        "w0": jnp.full((d,), -6.0, jnp.float32),       # base decay (exp(-exp(.)))
        "wa": dense_init(ks[4], d, DECAY_LORA, dt),    # decay adapter
        "wb": dense_init(ks[5], DECAY_LORA, d, dt),
        "u": (jax.random.normal(ks[6], (h, hd), jnp.float32) * 0.1).astype(jnp.float32),
        "ln_x": jnp.ones((d,), dt),                    # per-head group norm scale
        "wo": dense_init(ks[7], d, d, dt),
        # channel-mix
        "mu_c": jnp.full((2, d), 0.5, dt),
        "ck": dense_init(ks[8], d, f, dt),
        "cv": dense_init(ks[9], f, d, dt),
        "cr": dense_init(jax.random.fold_in(key, 11), d, d, dt),
    }


def _token_shift(x: jax.Array, last: jax.Array) -> jax.Array:
    """shifted[t] = x[t-1]; position 0 takes `last` (decode carry)."""
    return jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)


def init_rwkv_cache(cfg: ArchConfig, batch: int) -> dict:
    h, hd, d = cfg.n_heads, cfg.head_dim, cfg.d_model
    dt = dtype_of(cfg.dtype)
    return {
        "state": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "last_tm": jnp.zeros((batch, d), dt),
        "last_cm": jnp.zeros((batch, d), dt),
    }


def rwkv_block(p: dict, cfg: ArchConfig, x: jax.Array, *, cache: dict | None,
               provider=None) -> tuple[jax.Array, dict | None]:
    """Full RWKV6 block (time-mix + channel-mix) on normalized inputs is NOT
    assumed: this block applies its own norms like the reference model.
    x: (B, S, D) residual stream."""
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim

    # ---- time mix ----
    xn = rmsnorm(x, jnp.zeros((d,), x.dtype))
    last_tm = cache["last_tm"] if cache is not None else jnp.zeros((b, d), x.dtype)
    xs = _token_shift(xn, last_tm)
    mu = p["mu"].astype(jnp.float32)
    mix = lambda i: (xn.astype(jnp.float32) * mu[i] + xs.astype(jnp.float32) * (1 - mu[i])).astype(x.dtype)
    r = ops.matmul(mix(0), p["wr"], provider=provider).reshape(b, s, h, hd)
    k = ops.matmul(mix(1), p["wk"], provider=provider).reshape(b, s, h, hd)
    v = ops.matmul(mix(2), p["wv"], provider=provider).reshape(b, s, h, hd)
    g = ops.matmul(mix(4), p["wg"], provider=provider)
    dw = jnp.tanh(ops.matmul(mix(3), p["wa"], provider=provider).astype(jnp.float32))
    dw = dw @ p["wb"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(p["w0"] + dw)).reshape(b, s, h, hd)   # decay in (0,1)

    tr = lambda a: jnp.swapaxes(a, 1, 2)  # (B, H, S, hd)
    state0 = cache["state"] if cache is not None else jnp.zeros((b, h, hd, hd), jnp.float32)
    y, state = ops.rwkv6(tr(r), tr(k), tr(v), tr(w.astype(x.dtype)), p["u"],
                         state0, provider=provider)
    y = jnp.swapaxes(y, 1, 2).reshape(b, s, d)
    # per-head group norm + silu output gate
    yh = y.reshape(b, s, h, hd).astype(jnp.float32)
    yh = yh * jax.lax.rsqrt(jnp.mean(yh * yh, axis=-1, keepdims=True) + 1e-6)
    y = (yh.reshape(b, s, d) * p["ln_x"].astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    x = x + ops.matmul(y, p["wo"], provider=provider)

    # ---- channel mix ----
    xn2 = rmsnorm(x, jnp.zeros((d,), x.dtype))
    last_cm = cache["last_cm"] if cache is not None else jnp.zeros((b, d), x.dtype)
    xs2 = _token_shift(xn2, last_cm)
    mc = p["mu_c"].astype(jnp.float32)
    mixc = lambda i: (xn2.astype(jnp.float32) * mc[i] + xs2.astype(jnp.float32) * (1 - mc[i])).astype(x.dtype)
    kk = ops.matmul(mixc(0), p["ck"], provider=provider)
    kk = jnp.square(jax.nn.relu(kk.astype(jnp.float32))).astype(x.dtype)
    vv = ops.matmul(kk, p["cv"], provider=provider)
    rr = jax.nn.sigmoid(ops.matmul(mixc(1), p["cr"], provider=provider).astype(jnp.float32))
    x = x + (rr * vv.astype(jnp.float32)).astype(x.dtype)

    new_cache = None
    if cache is not None:
        new_cache = {"state": state, "last_tm": xn[:, -1, :], "last_cm": xn2[:, -1, :]}
    return x, new_cache


# ---------------------------------------------------------------------------
# Griffin / RG-LRU recurrent block
# ---------------------------------------------------------------------------


def griffin_params(key: jax.Array, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    w = cfg.rnn_width or d
    dt = dtype_of(cfg.dtype)
    ks = jax.random.split(key, 4)
    return {
        "w_gate": dense_init(ks[0], d, w, dt),     # gelu branch
        "w_x": dense_init(ks[1], d, w, dt),        # recurrent branch input
        "conv": (jax.random.normal(ks[2], (cfg.conv_width, w), jnp.float32) * 0.1).astype(dt),
        "lambda": jnp.full((w,), 2.0, jnp.float32),   # a = sigmoid(λ)^(c·r_t)
        "gate_a": jnp.zeros((w,), jnp.float32),       # diagonal recurrence gate
        "gate_i": jnp.zeros((w,), jnp.float32),       # diagonal input gate
        "w_out": dense_init(ks[3], w, d, dt),
    }


def init_griffin_cache(cfg: ArchConfig, batch: int) -> dict:
    w = cfg.rnn_width or cfg.d_model
    dt = dtype_of(cfg.dtype)
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dt),
    }


_RGLRU_C = 8.0


def _rglru_decay(xc: jax.Array, p: dict) -> jax.Array:
    """Per-step decay a_t ∈ (0,1): a = exp(c · log σ(λ) · σ(x·g_a))."""
    r = jax.nn.sigmoid(xc.astype(jnp.float32) * p["gate_a"])
    log_a = _RGLRU_C * jax.nn.log_sigmoid(p["lambda"]) * r
    return jnp.exp(log_a)


def griffin_block(p: dict, cfg: ArchConfig, x: jax.Array, *, cache: dict | None,
                  provider=None) -> tuple[jax.Array, dict | None]:
    """Griffin recurrent block on the *normalized* input x: (B, S, D).
    Returns the block output (caller adds the residual)."""
    b, s, d = x.shape
    gate = jax.nn.gelu(ops.matmul(x, p["w_gate"], provider=provider).astype(jnp.float32))
    xr = ops.matmul(x, p["w_x"], provider=provider)        # (B, S, W)

    # temporal conv1d (causal, width cw)
    cw = cfg.conv_width
    tail = cache["conv"] if cache is not None else jnp.zeros((b, cw - 1, xr.shape[-1]), xr.dtype)
    xpad = jnp.concatenate([tail, xr], axis=1)             # (B, S+cw-1, W)
    conv = sum(
        xpad[:, i:i + s, :].astype(jnp.float32) * p["conv"][i].astype(jnp.float32)
        for i in range(cw)
    ).astype(xr.dtype)

    i_gate = jax.nn.sigmoid(conv.astype(jnp.float32) * p["gate_i"])
    a = _rglru_decay(conv, p)
    h0 = cache["h"] if cache is not None else jnp.zeros((b, xr.shape[-1]), jnp.float32)
    y, h_final = ops.rglru((i_gate * conv.astype(jnp.float32)).astype(xr.dtype),
                           a.astype(xr.dtype), h0, provider=provider)

    out = (y.astype(jnp.float32) * gate).astype(x.dtype)
    out = ops.matmul(out, p["w_out"], provider=provider)

    new_cache = None
    if cache is not None:
        new_cache = {"h": h_final, "conv": xpad[:, xpad.shape[1] - (cw - 1):, :]}
    return out, new_cache
