"""Encoder-decoder model (whisper-medium backbone).

The conv/mel frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, encoder_seq, D).  Encoder = bidirectional
attention blocks; decoder = causal self-attention + cross-attention blocks.
Serving: cross K/V are computed once at prefill and reused every decode step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.context import constrain, remat_policy
from repro.kernels import ops
from repro.models import attention as attn
from repro.models import mlp as mlpm
from repro.models.common import apply_norm, dense_init, dtype_of, embed_init, norm_params

MAX_DECODE_POS = 32768  # learned position table size (≥ decode_32k cell)


def _enc_block_params(key: jax.Array, cfg: ArchConfig) -> dict:
    k1, k2 = jax.random.split(key)
    dt = dtype_of(cfg.dtype)
    return {
        "ln1": norm_params(cfg.d_model, cfg.norm, dt),
        "attn": attn.attn_params(k1, cfg),
        "ln2": norm_params(cfg.d_model, cfg.norm, dt),
        "mlp": mlpm.mlp_params(k2, cfg),
    }


def _dec_block_params(key: jax.Array, cfg: ArchConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    dt = dtype_of(cfg.dtype)
    return {
        "ln1": norm_params(cfg.d_model, cfg.norm, dt),
        "self_attn": attn.attn_params(k1, cfg),
        "ln_x": norm_params(cfg.d_model, cfg.norm, dt),
        "cross_attn": attn.attn_params(k2, cfg),
        "ln2": norm_params(cfg.d_model, cfg.norm, dt),
        "mlp": mlpm.mlp_params(k3, cfg),
    }


def init_params(key: jax.Array, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 8)
    dt = dtype_of(cfg.dtype)
    enc_layers = [_enc_block_params(k, cfg) for k in jax.random.split(ks[0], cfg.encoder_layers)]
    dec_layers = [_dec_block_params(k, cfg) for k in jax.random.split(ks[1], cfg.n_layers)]
    stack = lambda ls: jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ls)
    return {
        "embed": embed_init(ks[2], cfg.vocab_size, cfg.d_model, dt),
        "enc_pos": (jax.random.normal(ks[3], (cfg.encoder_seq, cfg.d_model), jnp.float32) * 0.01).astype(dt),
        "dec_pos": (jax.random.normal(ks[4], (MAX_DECODE_POS, cfg.d_model), jnp.float32) * 0.01).astype(dt),
        "encoder": stack(enc_layers),
        "enc_norm": norm_params(cfg.d_model, cfg.norm, dt),
        "decoder": stack(dec_layers),
        "final_norm": norm_params(cfg.d_model, cfg.norm, dt),
        "lm_head": dense_init(ks[5], cfg.d_model, cfg.vocab_size, dt),
    }


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------


def encode(params: dict, cfg: ArchConfig, frames: jax.Array, *, remat: bool = True,
           provider=None) -> jax.Array:
    """frames: (B, enc_seq, D) stub embeddings -> encoder hidden states."""
    h = frames.astype(dtype_of(cfg.dtype)) + params["enc_pos"][None, : frames.shape[1]]
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(hh, p):
        xn = apply_norm(p["ln1"], hh, cfg.norm)
        q = ops.matmul(xn, p["attn"]["wq"], provider=provider).reshape(b, s, cfg.n_heads, cfg.head_dim)
        k = ops.matmul(xn, p["attn"]["wk"], provider=provider).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
        v = ops.matmul(xn, p["attn"]["wv"], provider=provider).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
        tr = lambda x: jnp.swapaxes(x, 1, 2)
        o = ops.flash_attention(tr(q), tr(k), tr(v), class_id="flash_attention_bidir",
                                causal=False, provider=provider)
        o = jnp.swapaxes(o, 1, 2).reshape(b, s, -1)
        hh = hh + ops.matmul(o, p["attn"]["wo"], provider=provider)
        xn2 = apply_norm(p["ln2"], hh, cfg.norm)
        hh = hh + mlpm.mlp_apply(p["mlp"], cfg, xn2, provider=provider)
        return constrain(hh), None

    fn = jax.checkpoint(body, policy=remat_policy()) if remat else body
    h, _ = jax.lax.scan(fn, h, params["encoder"])
    return apply_norm(params["enc_norm"], h, cfg.norm)


# ---------------------------------------------------------------------------
# Decoder
# ---------------------------------------------------------------------------


def _cross_attend(p: dict, cfg: ArchConfig, x: jax.Array, ck: jax.Array,
                  cv: jax.Array, provider=None) -> jax.Array:
    """x: (B, S, D) attends to precomputed cross K/V (B, Hkv, Senc, hd)."""
    b, s, _ = x.shape
    q = ops.matmul(x, p["wq"], provider=provider).reshape(b, s, cfg.n_heads, cfg.head_dim)
    o = ops.flash_attention(jnp.swapaxes(q, 1, 2), ck, cv,
                            class_id="flash_attention_cross", causal=False,
                            provider=provider)
    o = jnp.swapaxes(o, 1, 2).reshape(b, s, -1)
    return ops.matmul(o, p["wo"], provider=provider)


def _cross_kv(p: dict, cfg: ArchConfig, enc: jax.Array, provider=None):
    b, s, _ = enc.shape
    k = ops.matmul(enc, p["wk"], provider=provider).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = ops.matmul(enc, p["wv"], provider=provider).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    return jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2)


def forward(params: dict, cfg: ArchConfig, batch: dict, *, remat: bool = True,
            provider=None) -> tuple[jax.Array, jax.Array]:
    """batch: frames (B, enc_seq, D) + tokens (B, S). Returns (logits, aux=0)."""
    enc = encode(params, cfg, batch["frames"], remat=remat, provider=provider)
    tokens = batch["tokens"]
    b, s = tokens.shape
    h = params["embed"][tokens] + params["dec_pos"][None, :s]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(hh, p):
        xn = apply_norm(p["ln1"], hh, cfg.norm)
        a, _ = attn.attn_forward(p["self_attn"], cfg, xn, "G", positions=positions,
                                 provider=provider)
        hh = hh + a
        xc = apply_norm(p["ln_x"], hh, cfg.norm)
        ck, cv = _cross_kv(p["cross_attn"], cfg, enc, provider=provider)
        hh = hh + _cross_attend(p["cross_attn"], cfg, xc, ck, cv, provider=provider)
        xn2 = apply_norm(p["ln2"], hh, cfg.norm)
        hh = hh + mlpm.mlp_apply(p["mlp"], cfg, xn2, provider=provider)
        return constrain(hh), None

    fn = jax.checkpoint(body, policy=remat_policy()) if remat else body
    h, _ = jax.lax.scan(fn, h, params["decoder"])
    h = apply_norm(params["final_norm"], h, cfg.norm)
    logits = ops.matmul(h, params["lm_head"], class_id="matmul_lmhead", provider=provider)
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(params: dict, cfg: ArchConfig, batch: dict, *, remat: bool = True,
            provider=None) -> tuple[jax.Array, dict]:
    logits, aux = forward(params, cfg, batch, remat=remat, provider=provider)
    tgt = batch["tokens"][:, 1:]
    logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1).squeeze(-1)
    ce = nll.mean()
    return ce, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def prefill(params: dict, cfg: ArchConfig, batch: dict, *, max_len: int,
            provider=None, true_len=None) -> tuple[jax.Array, dict]:
    """``true_len``: number of real decoder tokens when the prompt is
    right-padded to a trace bucket (see :func:`repro.models.lm.prefill`)."""
    enc = encode(params, cfg, batch["frames"], remat=False, provider=provider)
    tokens = batch["tokens"]
    b, s = tokens.shape
    h = params["embed"][tokens] + params["dec_pos"][None, :s]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(hh, p):
        xn = apply_norm(p["ln1"], hh, cfg.norm)
        c0 = attn.init_attn_cache(cfg, "G", b, max_len)
        a, c = attn.attn_forward(p["self_attn"], cfg, xn, "G", positions=positions,
                                 cache=c0, provider=provider)
        hh = hh + a
        xc = apply_norm(p["ln_x"], hh, cfg.norm)
        ck, cv = _cross_kv(p["cross_attn"], cfg, enc, provider=provider)
        hh = hh + _cross_attend(p["cross_attn"], cfg, xc, ck, cv, provider=provider)
        xn2 = apply_norm(p["ln2"], hh, cfg.norm)
        hh = hh + mlpm.mlp_apply(p["mlp"], cfg, xn2, provider=provider)
        return constrain(hh), {"self": c, "cross_k": ck, "cross_v": cv}

    h, caches = jax.lax.scan(body, h, params["decoder"])
    if true_len is None:
        t = jnp.asarray(s, jnp.int32)
        h_last = h[:, -1:, :]
    else:
        t = jnp.asarray(true_len, jnp.int32)
        h_last = jax.lax.dynamic_slice_in_dim(h, t - 1, 1, axis=1)
    h = apply_norm(params["final_norm"], h_last, cfg.norm)
    logits = ops.matmul(h, params["lm_head"], class_id="matmul_lmhead", provider=provider)
    return logits[:, 0, :], {"layers": caches, "t": jnp.full((b,), t, jnp.int32)}


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    """Decode cache specs (self KV per layer + precomputed cross KV)."""
    dt = dtype_of(cfg.dtype)
    per_layer = {
        "self": attn.init_attn_cache(cfg, "G", batch, max_len),
        "cross_k": jnp.zeros((batch, cfg.n_kv_heads, cfg.encoder_seq, cfg.head_dim), dt),
        "cross_v": jnp.zeros((batch, cfg.n_kv_heads, cfg.encoder_seq, cfg.head_dim), dt),
    }
    layers = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), per_layer
    )
    return {"layers": layers, "t": jnp.zeros((batch,), jnp.int32)}


def decode_step(params: dict, cfg: ArchConfig, cache: dict, tokens: jax.Array, *,
                provider=None) -> tuple[jax.Array, dict]:
    pos = cache["t"]                                   # (B,) per-slot positions
    b = tokens.shape[0]
    h = params["embed"][tokens[:, None]] + params["dec_pos"][pos][:, None, :]

    def body(hh, xs):
        p, c = xs
        xn = apply_norm(p["ln1"], hh, cfg.norm)
        a, c_self = attn.attn_decode(p["self_attn"], cfg, xn, "G", pos=pos,
                                     cache=c["self"], provider=provider)
        hh = hh + a
        xc = apply_norm(p["ln_x"], hh, cfg.norm)
        hh = hh + _cross_attend(p["cross_attn"], cfg, xc, c["cross_k"], c["cross_v"],
                                provider=provider)
        xn2 = apply_norm(p["ln2"], hh, cfg.norm)
        hh = hh + mlpm.mlp_apply(p["mlp"], cfg, xn2, provider=provider)
        return hh, {"self": c_self, "cross_k": c["cross_k"], "cross_v": c["cross_v"]}

    h, layers = jax.lax.scan(body, h, (params["decoder"], cache["layers"]))
    h = apply_norm(params["final_norm"], h, cfg.norm)
    logits = ops.matmul(h, params["lm_head"], class_id="matmul_lmhead", provider=provider)
    return logits[:, 0, :], {"layers": layers, "t": pos + 1}
