from repro.models.build import Model, build_model, input_specs

__all__ = ["Model", "build_model", "input_specs"]
