"""MLP and Mixture-of-Experts blocks.

Dense MLPs: gated (swiglu/geglu, interleaved-packed for the fused kernel)
and plain gelu (optionally biased — starcoder2/whisper).

MoE: token-dropping sort-based dispatch (Megablocks/MaxText style, adapted
to XLA): token-expert pairs are sorted by expert id, packed into a fixed
(E, capacity, D) buffer (overflow drops), pushed through grouped GEMMs, and
combined back with router weights.  This avoids the O(T·E·cap) GShard
dispatch mask — the structure that makes 4k×256-token MoE layers compile
at dbrx/mixtral scale.  Capacity factor 1.25 by default.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.context import constrain_named
from repro.kernels import ops
from repro.models.common import dense_init, dtype_of, glu_init

CAPACITY_FACTOR = 1.25


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------


def mlp_params(key: jax.Array, cfg: ArchConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    dt = dtype_of(cfg.dtype)
    k1, k2 = jax.random.split(key)
    if cfg.mlp_kind in ("swiglu", "geglu"):
        p = {"w_in": glu_init(k1, d, f, dt), "w_out": dense_init(k2, f, d, dt)}
    else:
        p = {"w_in": dense_init(k1, d, f, dt), "w_out": dense_init(k2, f, d, dt)}
        if cfg.mlp_bias:
            p["b_in"] = jnp.zeros((f,), dt)
            p["b_out"] = jnp.zeros((d,), dt)
    return p


def mlp_apply(p: dict, cfg: ArchConfig, x: jax.Array, provider=None) -> jax.Array:
    if cfg.mlp_kind == "swiglu":
        h = ops.matmul(x, p["w_in"], class_id="matmul_silu_glu", provider=provider)
        return ops.matmul(h, p["w_out"], provider=provider)
    if cfg.mlp_kind == "geglu":
        h = ops.matmul(x, p["w_in"], class_id="matmul_gelu_glu", provider=provider)
        return ops.matmul(h, p["w_out"], provider=provider)
    bias_in = p.get("b_in")
    bias_out = p.get("b_out")
    h = ops.matmul(x, p["w_in"], class_id="matmul_bias_gelu", bias=bias_in, provider=provider)
    cls = "matmul_bias" if bias_out is not None else "matmul"
    return ops.matmul(h, p["w_out"], class_id=cls, bias=bias_out, provider=provider)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def moe_params(key: jax.Array, cfg: ArchConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = dtype_of(cfg.dtype)
    kr, ki, ko = jax.random.split(key, 3)
    w_in = jnp.stack([glu_init(k, d, f, dt) for k in jax.random.split(ki, e)])
    w_out = jnp.stack([dense_init(k, f, d, dt) for k in jax.random.split(ko, e)])
    return {
        "router": dense_init(kr, d, e, jnp.float32),  # router kept f32
        "w_in": w_in,    # (E, D, 2F) interleaved glu packing
        "w_out": w_out,  # (E, F, D)
    }


def moe_apply(p: dict, cfg: ArchConfig, x: jax.Array, provider=None,
              capacity_factor: float = CAPACITY_FACTOR) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D). Returns (out, aux_loss) — aux is the load-balance loss."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.moe_topk
    t = b * s
    xf = x.reshape(t, d)

    logits = ops.matmul(xf.astype(jnp.float32), p["router"],
                        class_id="moe_router", provider=provider)   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)                 # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Load-balance auxiliary loss (Switch-style): E * Σ_e f_e · p_e
    me = probs.mean(axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[expert_idx.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)

    # --- sort-based dispatch ------------------------------------------------
    # Dropless for small token counts (decode steps, small eval batches):
    # worst-case per-expert load is `t`, so cap=t guarantees no drops there.
    # At training scale the usual capacity-factor dropping applies.
    if t * k <= 4096:
        cap = t
    else:
        cap = int(max(1, round(t * k / e * capacity_factor)))
    flat_e = expert_idx.reshape(-1)                                  # (T*k,)
    flat_t = jnp.repeat(jnp.arange(t), k)
    flat_g = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    counts = jnp.zeros((e,), jnp.int32).at[se].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(t * k) - starts[se]
    keep = pos < cap
    slot = jnp.where(keep, se * cap + pos, e * cap)                  # overflow row

    buf = jnp.zeros((e * cap + 1, d), x.dtype).at[slot].set(xf[st])
    buf = buf[:-1].reshape(e, cap, d)
    # Pin the dispatch buffer's layout: without this, GSPMD materializes the
    # scatter through a replicated buffer + all-reduce per layer (measured
    # ~160 GiB/step on mixtral train_4k — see EXPERIMENTS.md §Perf).
    buf = constrain_named(buf, "moe_buf")

    h = ops.moe_gemm(buf, p["w_in"], class_id="moe_gemm_silu_glu", provider=provider)
    y = ops.moe_gemm(h, p["w_out"], class_id="moe_gemm", provider=provider)  # (E, cap, D)
    y = constrain_named(y, "moe_buf")

    y_flat = y.reshape(e * cap, d)
    contrib = jnp.where(keep, sg, 0.0)[:, None].astype(x.dtype)
    gathered = y_flat[jnp.where(keep, se * cap + pos, 0)] * contrib
    out = jnp.zeros((t, d), x.dtype).at[st].add(gathered)
    out = constrain_named(out, "moe_out")   # combine lands in the token layout
    return out.reshape(b, s, d), aux
