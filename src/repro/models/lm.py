"""Decoder-only LM stack covering dense / MoE / SSM / hybrid / VLM families.

Layers follow ``cfg.layer_pattern`` (e.g. gemma2 ("L","G"), griffin
("R","R","L")).  The stack is executed as ``jax.lax.scan`` over *pattern
groups* — params are stacked with leading dim = full pattern repeats — plus
explicit tail layers for the remainder (griffin's 26 = 8×3 + 2).  Scan keeps
the HLO (and compile time) independent of depth; the group body is wrapped
in ``jax.checkpoint`` for training (save-residual-boundaries remat policy).

Three entry points (built per-config by :mod:`repro.models.build`):
  forward(params, batch)          — full-sequence logits (+aux), train/eval
  prefill(params, batch, max_len) — logits of last position + filled cache
  decode_step(params, cache, tok) — one token, updated cache
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.context import constrain, remat_policy
from repro.kernels import ops
from repro.models import attention as attn
from repro.models import mlp as mlpm
from repro.models import recurrent as rec
from repro.models.common import apply_norm, dense_init, dtype_of, embed_init, norm_params


# ---------------------------------------------------------------------------
# Per-block params / apply
# ---------------------------------------------------------------------------


def block_params(key: jax.Array, cfg: ArchConfig, kind: str) -> dict:
    if kind == "R":
        if cfg.family == "ssm":
            return rec.rwkv_params(key, cfg)
        k1, k2, k3, k4 = jax.random.split(key, 4)
        return {
            "ln1": norm_params(cfg.d_model, cfg.norm, dtype_of(cfg.dtype)),
            "rnn": rec.griffin_params(k1, cfg),
            "ln2": norm_params(cfg.d_model, cfg.norm, dtype_of(cfg.dtype)),
            "mlp": mlpm.mlp_params(k2, cfg),
        }
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": norm_params(cfg.d_model, cfg.norm, dtype_of(cfg.dtype)),
        "attn": attn.attn_params(k1, cfg),
        "ln2": norm_params(cfg.d_model, cfg.norm, dtype_of(cfg.dtype)),
    }
    if cfg.n_experts > 0:
        p["moe"] = mlpm.moe_params(k2, cfg)
    else:
        p["mlp"] = mlpm.mlp_params(k2, cfg)
    return p


def apply_block(p: dict, cfg: ArchConfig, kind: str, x: jax.Array, *,
                positions: jax.Array | None, pos: jax.Array | None,
                cache: dict | None, decode: bool, off: jax.Array | None = None,
                verify: bool = False,
                provider=None) -> tuple[jax.Array, dict | None, jax.Array]:
    """Returns (x, new_cache, aux_loss).  ``off`` selects the chunked-prefill
    attention path: the slice starts at absolute position ``off`` against a
    partially filled cache (recurrent blocks already carry state through
    their cache, so R layers need no separate chunk path).  ``verify``
    reinterprets ``off`` as per-lane (B,) offsets for the speculative
    verify path (attention layers only)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "R":
        if verify:
            raise ValueError("speculative verify does not support recurrent layers")
        if cfg.family == "ssm":
            x, c = rec.rwkv_block(p, cfg, x, cache=cache, provider=provider)
            return x, c, aux
        xn = apply_norm(p["ln1"], x, cfg.norm)
        out, c = rec.griffin_block(p["rnn"], cfg, xn, cache=cache, provider=provider)
        x = constrain(x + out)
        xn2 = apply_norm(p["ln2"], x, cfg.norm)
        x = constrain(x + mlpm.mlp_apply(p["mlp"], cfg, xn2, provider=provider))
        return x, c, aux

    xn = apply_norm(p["ln1"], x, cfg.norm)
    if decode:
        a, c = attn.attn_decode(p["attn"], cfg, xn, kind, pos=pos, cache=cache,
                                provider=provider)
    elif verify:
        a, c = attn.attn_verify(p["attn"], cfg, xn, kind, off=off, cache=cache,
                                provider=provider)
    elif off is not None:
        a, c = attn.attn_chunk(p["attn"], cfg, xn, kind, positions=positions,
                               off=off, cache=cache, provider=provider)
    else:
        a, c = attn.attn_forward(p["attn"], cfg, xn, kind, positions=positions,
                                 cache=cache, provider=provider)
    # constrain the residual after every sub-block: otherwise GSPMD
    # replicates intermediate residuals inside multi-layer pattern groups
    # and pays full all-reduces instead of staying D-sharded (§Perf it-6)
    x = constrain(x + a)
    xn2 = apply_norm(p["ln2"], x, cfg.norm)
    if cfg.n_experts > 0:
        y, aux = mlpm.moe_apply(p["moe"], cfg, xn2, provider=provider)
        x = constrain(x + y)
    else:
        x = constrain(x + mlpm.mlp_apply(p["mlp"], cfg, xn2, provider=provider))
    return x, c, aux


def init_block_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int) -> dict:
    if kind == "R":
        if cfg.family == "ssm":
            return rec.init_rwkv_cache(cfg, batch)
        return rec.init_griffin_cache(cfg, batch)
    return attn.init_attn_cache(cfg, kind, batch, max_len)


# ---------------------------------------------------------------------------
# Stack construction
# ---------------------------------------------------------------------------


def _pattern_split(cfg: ArchConfig) -> tuple[tuple[str, ...], int, tuple[str, ...]]:
    pat = cfg.layer_pattern
    reps, rem = divmod(cfg.n_layers, len(pat))
    return pat, reps, pat[:rem]


def init_params(key: jax.Array, cfg: ArchConfig) -> dict:
    pat, reps, tail = _pattern_split(cfg)
    keys = jax.random.split(key, 8)
    dt = dtype_of(cfg.dtype)
    params: dict[str, Any] = {"embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dt)}
    if cfg.vision_tokens:
        params["vis_proj"] = dense_init(keys[1], cfg.d_model, cfg.d_model, dt)

    group: dict[str, Any] = {}
    gkeys = jax.random.split(keys[2], max(reps, 1) * len(pat)).reshape(max(reps, 1), len(pat), 2)
    for i, kind in enumerate(pat):
        layers = [block_params(gkeys[r, i], cfg, kind) for r in range(reps)]
        group[str(i)] = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *layers) if layers else {}
    params["groups"] = group
    params["tail"] = [
        block_params(k, cfg, kind)
        for k, kind in zip(jax.random.split(keys[3], max(len(tail), 1)), tail)
    ]
    params["final_norm"] = norm_params(cfg.d_model, cfg.norm, dt)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[4], cfg.d_model, cfg.vocab_size, dt)
    return params


def _lm_head(params: dict, cfg: ArchConfig, h: jax.Array, provider=None) -> jax.Array:
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    if cfg.final_softcap > 0:
        return ops.matmul(h, w, class_id="matmul_lmhead_softcap",
                          softcap=cfg.final_softcap, provider=provider)
    return ops.matmul(h, w, class_id="matmul_lmhead", provider=provider)


def _embed(params: dict, cfg: ArchConfig, tokens: jax.Array) -> jax.Array:
    h = params["embed"][tokens]
    if cfg.tie_embeddings:  # gemma-family embedding scaling
        h = (h.astype(jnp.float32) * cfg.d_model ** 0.5).astype(h.dtype)
    return h


# ---------------------------------------------------------------------------
# Full-sequence pass (train / eval / prefill)
# ---------------------------------------------------------------------------


def _stack_pass(params: dict, cfg: ArchConfig, h: jax.Array, *,
                positions: jax.Array, caches: dict | None, remat: bool,
                off: jax.Array | None = None, verify: bool = False,
                provider=None) -> tuple[jax.Array, dict | None, jax.Array]:
    """Run all layers. caches: {"groups": {i: stacked}, "tail": [...]} or None.
    ``off`` (with caches) runs the chunked-prefill path for attention layers;
    ``verify`` the speculative verify path (``off`` per-lane)."""
    pat, reps, tail = _pattern_split(cfg)

    def group_body(carry, xs):
        hh, aux = carry
        layer_params, layer_cache = xs
        new_cache = {}
        for i, kind in enumerate(pat):
            c_in = layer_cache[str(i)] if layer_cache is not None else None
            hh, c_out, a = apply_block(layer_params[str(i)], cfg, kind, hh,
                                       positions=positions, pos=None, cache=c_in,
                                       decode=False, off=off, verify=verify,
                                       provider=provider)
            aux = aux + a
            if c_out is not None:
                new_cache[str(i)] = c_out
        return (constrain(hh), aux), new_cache

    body = jax.checkpoint(group_body, policy=remat_policy()) if remat else group_body

    aux = jnp.zeros((), jnp.float32)
    new_caches = {"groups": {}, "tail": []} if caches is not None else None
    if reps > 0:
        if caches is None:
            (h, aux), _ = jax.lax.scan(
                lambda c, lp: body(c, (lp, None)), (h, aux), params["groups"]
            )
        else:
            (h, aux), ys = jax.lax.scan(body, (h, aux), (params["groups"], caches["groups"]))
            new_caches["groups"] = ys
    for j, kind in enumerate(tail):
        c_in = caches["tail"][j] if caches is not None else None
        h, c_out, a = apply_block(params["tail"][j], cfg, kind, h,
                                  positions=positions, pos=None, cache=c_in,
                                  decode=False, off=off, verify=verify,
                                  provider=provider)
        aux = aux + a
        if caches is not None:
            new_caches["tail"].append(c_out)
    return h, new_caches, aux


def forward(params: dict, cfg: ArchConfig, batch: dict, *, remat: bool = True,
            provider=None) -> tuple[jax.Array, jax.Array]:
    """Full-sequence logits. batch: tokens (B,S) [+ patch_embeds (B,P,D)].
    Returns (logits over the full (vlm-prefixed) sequence, aux_loss)."""
    tokens = batch["tokens"]
    h = _embed(params, cfg, tokens)
    if cfg.vision_tokens:
        vis = ops.matmul(batch["patch_embeds"].astype(h.dtype), params["vis_proj"],
                         provider=provider)
        h = jnp.concatenate([vis, h], axis=1)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h, _, aux = _stack_pass(params, cfg, h, positions=positions, caches=None,
                            remat=remat, provider=provider)
    h = apply_norm(params["final_norm"], h, cfg.norm)
    return _lm_head(params, cfg, h, provider=provider), aux


def loss_fn(params: dict, cfg: ArchConfig, batch: dict, *, remat: bool = True,
            provider=None) -> tuple[jax.Array, dict]:
    logits, aux = forward(params, cfg, batch, remat=remat, provider=provider)
    p = cfg.vision_tokens
    tokens = batch["tokens"]
    if p:
        pred = logits[:, p - 1:-1, :]   # positions P-1 .. P+S-2 predict tokens 0..S-1
        tgt = tokens
    else:
        pred = logits[:, :-1, :]
        tgt = tokens[:, 1:]
    logp = jax.nn.log_softmax(pred.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1).squeeze(-1)
    mask = batch.get("mask")
    if mask is not None:
        m = (mask[:, 1:] if not p else mask).astype(jnp.float32)
        ce = (nll * m).sum() / jnp.maximum(m.sum(), 1.0)
    else:
        ce = nll.mean()
    total = ce + 0.01 * aux
    return total, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    """max_len counts *text* positions; the vision prefix is added here."""
    max_len = max_len + cfg.vision_tokens
    pat, reps, tail = _pattern_split(cfg)
    groups = {}
    for i, kind in enumerate(pat):
        layers = [init_block_cache(cfg, kind, batch, max_len) for _ in range(reps)]
        groups[str(i)] = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *layers) if layers else {}
    return {
        "groups": groups,
        "tail": [init_block_cache(cfg, kind, batch, max_len) for kind in tail],
        "t": jnp.zeros((batch,), jnp.int32),   # per-slot decode positions
    }


def prefill(params: dict, cfg: ArchConfig, batch: dict, *, max_len: int,
            provider=None, true_len=None) -> tuple[jax.Array, dict]:
    """Process the prompt; returns (last-position logits, cache).

    ``true_len`` (static or traced int) marks the number of *real* text
    tokens when the prompt is right-padded to a trace bucket: logits come
    from the last real position and the cache's decode position starts
    there.  Right padding is inert for causal attention (real positions
    never attend to pads; pad cache rows sit beyond the decode position and
    are overwritten before they become visible)."""
    tokens = batch["tokens"]
    h = _embed(params, cfg, tokens)
    if cfg.vision_tokens:
        vis = ops.matmul(batch["patch_embeds"].astype(h.dtype), params["vis_proj"],
                         provider=provider)
        h = jnp.concatenate([vis, h], axis=1)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    caches = init_cache(cfg, b, max_len)
    h, new_caches, _ = _stack_pass(params, cfg, h, positions=positions,
                                   caches=caches, remat=False, provider=provider)
    if true_len is None:
        t = jnp.asarray(s, jnp.int32)
        h_last = h[:, -1:, :]
    else:
        t = jnp.asarray(true_len, jnp.int32) + cfg.vision_tokens
        h_last = jax.lax.dynamic_slice_in_dim(h, t - 1, 1, axis=1)
    new_caches["t"] = jnp.full((b,), t, jnp.int32)
    h_last = apply_norm(params["final_norm"], h_last, cfg.norm)
    logits = _lm_head(params, cfg, h_last, provider=provider)
    return logits[:, 0, :], new_caches


def prefill_chunk(params: dict, cfg: ArchConfig, cache: dict, tokens: jax.Array,
                  off, *, provider=None) -> tuple[jax.Array, dict]:
    """Process one prompt chunk against a partially filled cache.

    ``tokens``: (B, C) — the prompt slice covering absolute positions
    ``off .. off+C-1``; ``off`` may be traced, so one trace per chunk
    *length* serves every offset (the paged engine always runs the final
    chunk at its exact remainder length — no padding anywhere, which both
    eliminates padding waste and keeps ring/recurrent state exact).

    Returns (last-position logits (B, V), updated cache).  Calling with
    ``off=0`` then successive offsets is numerically identical to one-shot
    :func:`prefill` — the equivalence tests assert it bit-exactly.
    """
    if cfg.vision_tokens:
        raise ValueError("chunked prefill does not support vision-prefix archs")
    b, s = tokens.shape
    off = jnp.asarray(off, jnp.int32)
    h = _embed(params, cfg, tokens)
    positions = jnp.broadcast_to(off + jnp.arange(s, dtype=jnp.int32), (b, s))
    h, new_caches, _ = _stack_pass(params, cfg, h, positions=positions,
                                   caches=cache, remat=False, off=off,
                                   provider=provider)
    new_caches["t"] = jnp.full((b,), off + s, jnp.int32)
    h_last = apply_norm(params["final_norm"], h[:, -1:, :], cfg.norm)
    logits = _lm_head(params, cfg, h_last, provider=provider)
    return logits[:, 0, :], new_caches


def verify_step(params: dict, cfg: ArchConfig, cache: dict, tokens: jax.Array,
                off, *, provider=None) -> tuple[jax.Array, dict]:
    """Speculative verify: run ``tokens`` (B, C) — the pending token plus the
    draft burst — through the stack at per-lane absolute offsets ``off``
    (B,), returning logits for *every* position (B, C, V) plus the updated
    cache.

    ``logits[:, j]`` is the target distribution after the first ``j`` draft
    tokens, so greedy acceptance compares ``argmax(logits[:, j])`` against
    draft token ``j+1``.  The cache gains all C rows; rejected rows are
    "rolled back" implicitly — validity masks hide rows at or beyond each
    lane's committed length, and later bursts overwrite them in order
    (full-length caches only; see :func:`repro.models.attention.attn_verify`).
    """
    if cfg.vision_tokens:
        raise ValueError("speculative verify does not support vision-prefix archs")
    b, s = tokens.shape
    off = jnp.broadcast_to(jnp.asarray(off, jnp.int32), (b,))
    h = _embed(params, cfg, tokens)
    positions = off[:, None] + jnp.arange(s, dtype=jnp.int32)
    h, new_caches, _ = _stack_pass(params, cfg, h, positions=positions,
                                   caches=cache, remat=False, off=off,
                                   verify=True, provider=provider)
    new_caches["t"] = off + s
    h = apply_norm(params["final_norm"], h, cfg.norm)
    logits = _lm_head(params, cfg, h, provider=provider)
    return logits, new_caches


def decode_step(params: dict, cfg: ArchConfig, cache: dict, tokens: jax.Array, *,
                provider=None) -> tuple[jax.Array, dict]:
    """tokens: (B,) — one new token per sequence. Returns (logits (B,V), cache)."""
    pat, reps, tail = _pattern_split(cfg)
    pos = cache["t"]
    h = _embed(params, cfg, tokens[:, None])

    def group_body(carry, xs):
        hh = carry
        layer_params, layer_cache = xs
        new_cache = {}
        for i, kind in enumerate(pat):
            hh, c_out, _ = apply_block(layer_params[str(i)], cfg, kind, hh,
                                       positions=None, pos=pos, cache=layer_cache[str(i)],
                                       decode=True, provider=provider)
            new_cache[str(i)] = c_out
        return hh, new_cache

    new_cache = {"groups": {}, "tail": [], "t": pos + 1}
    if reps > 0:
        h, ys = jax.lax.scan(group_body, h, (params["groups"], cache["groups"]))
        new_cache["groups"] = ys
    for j, kind in enumerate(tail):
        h, c_out, _ = apply_block(params["tail"][j], cfg, kind, h,
                                  positions=None, pos=pos, cache=cache["tail"][j],
                                  decode=True, provider=provider)
        new_cache["tail"].append(c_out)
    h = apply_norm(params["final_norm"], h, cfg.norm)
    logits = _lm_head(params, cfg, h, provider=provider)
    return logits[:, 0, :], new_cache
