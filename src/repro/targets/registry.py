"""Hardware-target registry: named, pluggable tuning targets.

The paper's headline result is evaluated on *two* platforms — a server-class
CPU and a constrained edge CPU — and its key finding is that transfer-tuning's
advantage widens on the constrained device.  Reproducing that axis requires
the target to be a first-class dimension of the whole tuning stack rather
than a hardcoded ``TPU_V5E`` constant:

* a :class:`Target` binds a name, a :class:`~repro.hw.specs.ChipSpec`, and a
  tier ("server" / "edge") — resolvable from CLI flags and configs;
* every schedule record, registry entry, and service lookup is *namespaced*
  by target name, so schedules tuned for one chip never silently serve
  another (a v5e schedule may overflow the lite chip's VMEM, and even a
  structurally valid one was selected under the wrong roofline);
* cross-target reuse is an *explicit* API
  (:func:`repro.core.transfer.cross_target_transfer`): donors tuned on
  target A are re-validated and re-measured under target B's spec, and
  edge-infeasible donors surface as invalid transfers (the paper's −1 bars)
  instead of crashing.

Three targets ship registered: ``tpu-v5e`` (the seed server chip),
``tpu-v5e-lite`` (constrained edge analogue), and ``tpu-v5p`` (larger).
``register_target`` adds more without touching the tuning stack.
"""
from __future__ import annotations

import dataclasses

from repro.hw.specs import TPU_V5E, TPU_V5E_LITE, TPU_V5P, ChipSpec

#: The target every pre-subsystem API call implicitly tuned for; also the
#: value persisted records without a ``target`` field are attributed to.
DEFAULT_TARGET = "tpu-v5e"


@dataclasses.dataclass(frozen=True)
class Target:
    """A named hardware target: the unit tuning namespaces are keyed by."""

    name: str
    spec: ChipSpec
    tier: str = "server"          # "server" | "edge" — the paper's platform axis
    description: str = ""

    def __post_init__(self):
        if not self.name:
            raise ValueError("target name must be non-empty")
        if self.tier not in ("server", "edge"):
            raise ValueError(f"unknown target tier {self.tier!r}")


_REGISTRY: dict[str, Target] = {}


def register_target(target: Target, *, overwrite: bool = False) -> Target:
    """Register a target by name; re-registration requires ``overwrite``."""
    if target.name in _REGISTRY and not overwrite:
        raise ValueError(f"target {target.name!r} already registered")
    _REGISTRY[target.name] = target
    return target


def get_target(name: str) -> Target:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown target {name!r}; registered targets: {list_targets()}"
        ) from None


def list_targets() -> list[str]:
    return sorted(_REGISTRY)


def target_name(target: "str | Target | ChipSpec | None") -> str:
    """The namespace key for a target-ish value (no registry lookup).

    Accepts a name, a :class:`Target`, a bare :class:`ChipSpec`, or ``None``
    (the default target).  Used by stores that only need the *key*, not the
    spec — unregistered names pass through so foreign DBs stay readable.
    """
    if target is None:
        return DEFAULT_TARGET
    if isinstance(target, str):
        return target
    return target.name


def resolve_target(target: "str | Target | ChipSpec | None") -> Target:
    """Resolve a target-ish value to a full :class:`Target` (spec included).

    Names go through the registry (unknown names raise with the available
    list); a bare :class:`ChipSpec` resolves to its registered target when
    the name matches, else wraps as an anonymous server-tier target.
    """
    if target is None:
        return get_target(DEFAULT_TARGET)
    if isinstance(target, Target):
        return target
    if isinstance(target, ChipSpec):
        known = _REGISTRY.get(target.name)
        if known is not None:
            if known.spec == target:
                return known
            # A different chip wearing a registered name would alias two
            # hardware namespaces — records measured on one would be served
            # as exact hits on the other.
            raise ValueError(
                f"ChipSpec named {target.name!r} differs from the registered "
                "target of that name; register it under a distinct name")
        return Target(name=target.name, spec=target)
    return get_target(target)


register_target(Target(
    name="tpu-v5e", spec=TPU_V5E, tier="server",
    description="seed server-class chip; the paper's high-end platform"))
register_target(Target(
    name="tpu-v5e-lite", spec=TPU_V5E_LITE, tier="edge",
    description="constrained edge analogue: 1 MXU, narrow memory, 8 MiB VMEM"))
register_target(Target(
    name="tpu-v5p", spec=TPU_V5P, tier="server",
    description="pod-scale chip: more FLOPs, HBM2e bandwidth, larger VMEM"))
