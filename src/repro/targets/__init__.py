"""Multi-target hardware subsystem.

Makes the hardware target a first-class, pluggable dimension of the tuning
and serving stack: a named-target registry (registry.py), target-namespaced
schedule stores, and explicit cross-target schedule transfer.
"""
from repro.targets.registry import (
    DEFAULT_TARGET,
    Target,
    get_target,
    list_targets,
    register_target,
    resolve_target,
    target_name,
)

__all__ = [
    "DEFAULT_TARGET",
    "Target",
    "get_target",
    "list_targets",
    "register_target",
    "resolve_target",
    "target_name",
]
