"""Iteration-level continuous batching over a paged KV cache.

The :class:`PagedServingEngine` replaces the slot engine's fixed batch with
a lane/page design:

* **Lanes** — ``decode_batch`` decode lanes share one batched cache, as in
  the slot engine, but requests flow through lanes at *iteration* (decode
  step) granularity: every :meth:`step` admits waiting requests into free
  lanes, advances prefills by one chunk each, decodes every decoding lane,
  and retires finished requests — no request ever blocks behind another's
  prefill, and a freed lane is reusable on the very next step.
* **Pages** — the full-length KV leaves (the length-scaling memory) live in
  one flat pool of fixed-size pages (:class:`~repro.serving.pages.PageTable`)
  instead of per-lane ``max_ctx`` strips.  A request holds exactly
  ``ceil(tokens / page_size)`` pages at any instant, so memory tracks the
  *actual* context in flight rather than the worst case; decode gathers each
  lane's pages into a dense per-lane view (numerically identical to a
  contiguous cache — the equivalence tests assert bit-exact logits) and
  scatters back only the one newly written row.  Ring (windowed) caches and
  recurrent state are O(window)/O(1) per lane and stay dense lane strips.
* **Chunked prefill** — prompts advance ``chunk`` tokens per step,
  interleaved with decode.  The final chunk always runs at its exact
  remainder length: no padding anywhere (the slot engine's power-of-two
  buckets padded up to 2x), and exact-length chunks are what keep ring and
  recurrent state correct.  Trace count is bounded by ``chunk`` distinct
  chunk lengths.
* **Preemption** — when the pool cannot grow a decoding request, the
  youngest decoding request is evicted: its pages are freed and it is
  re-queued at the *front* of the waiting queue with recompute-on-resume
  (prompt + generated so far re-prefilled, the pending token re-fed), the
  vLLM recompute idiom.

Execution plans key on (decode-batch, page-size):
:func:`~repro.core.resolution.plan_serving_paged` freezes the paged decode
cell plus one ``chunk_prefill`` cell per chunk length, and the engine
re-plans at step boundaries exactly like the slot engine.
"""
from __future__ import annotations

from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.resolution import ExecutionPlan, plan_serving_paged
from repro.models.build import Model
from repro.obs import NULL_TRACER
from repro.serving.engine import Request, SlotsFull
from repro.serving.pages import PagesExhausted, PageTable
from repro.serving.speculative import spec_exact_reason


class PagedServingEngine:
    """Continuous-batching engine over a paged KV pool.

    ``max_ctx`` is the per-request context bound (prompt + generation);
    ``pool_pages`` bounds *total* tokens in flight across all lanes
    (default: enough for every lane at full context — no preemption unless
    oversubscribed on purpose).
    """

    def __init__(self, model: Model, params: Any, *, decode_batch: int,
                 max_ctx: int, page_size: int = 8, pool_pages: int | None = None,
                 chunk: int = 8, chunks_per_step: int | None = None,
                 admit_cap: int | None = None,
                 defrag_threshold: float | None = None, provider=None,
                 plan: ExecutionPlan | None = None,
                 record_logits: bool = False,
                 draft_model: Model | None = None, draft_params: Any = None,
                 spec_k: int = 0):
        cfg = model.cfg
        if model.prefill_chunk is None or cfg.family == "audio":
            raise ValueError(f"paged serving does not support {cfg.family!r}")
        if cfg.vision_tokens:
            raise ValueError("paged serving does not support vision-prefix archs")
        if max_ctx % page_size:
            raise ValueError("max_ctx must be a multiple of page_size")
        self.spec_k = int(spec_k)
        self._spec = draft_model is not None and self.spec_k > 0
        if self._spec:
            for c in (cfg, draft_model.cfg):
                reason = spec_exact_reason(c)
                if reason:
                    raise ValueError(
                        f"speculative decoding unsupported for {c.name}: {reason}")
            if draft_params is None:
                raise ValueError("speculative decoding needs draft_params")
            if draft_model.cfg.vocab_size != cfg.vocab_size:
                raise ValueError("draft and target must share a vocabulary")
            if self.spec_k + 1 > max_ctx:
                raise ValueError("spec_k + 1 exceeds max_ctx")
        self.draft_model = draft_model if self._spec else None
        self.draft_params = draft_params if self._spec else None
        self.model = model
        self.params = params
        self.cfg = cfg
        self.decode_batch = decode_batch
        self.max_ctx = max_ctx
        self.page_size = page_size
        self.chunk = max(1, min(chunk, max_ctx))
        self.chunks_per_step = (chunks_per_step if chunks_per_step is not None
                                else max(2, decode_batch // 4))
        self.admit_cap = admit_cap if admit_cap is not None else 2 * decode_batch
        self.pages_per_seq = max_ctx // page_size
        if pool_pages is None:
            pool_pages = decode_batch * self.pages_per_seq + 1  # +1: trash
        self.table = PageTable(pool_pages, page_size)
        if defrag_threshold is not None and not 0.0 < defrag_threshold < 1.0:
            raise ValueError("defrag_threshold must lie in (0, 1)")
        self.defrag_threshold = defrag_threshold
        self.record_logits = record_logits

        # ---- cache leaf classification (shape probes, no allocation) -------
        probe_a = jax.eval_shape(lambda: model.init_cache(2, max_ctx))
        probe_b = jax.eval_shape(lambda: model.init_cache(3, max_ctx))
        probe_c = jax.eval_shape(lambda: model.init_cache(2, max_ctx - 1))
        la_, self._treedef = jax.tree_util.tree_flatten(probe_a)
        lb_ = jax.tree_util.tree_leaves(probe_b)
        lc_ = jax.tree_util.tree_leaves(probe_c)
        self._info: list[tuple[int, int | None]] = []
        for a, b, c in zip(la_, lb_, lc_):
            ba = next(i for i in range(a.ndim) if a.shape[i] != b.shape[i])
            diff = [i for i in range(a.ndim) if a.shape[i] != c.shape[i]]
            self._info.append((ba, diff[0] if diff else None))
        self._t_idx = _t_leaf_index(probe_a)

        # ---- draft model cache (dense lane strips; the draft is small) ----
        self._draft_ctx: dict[int, int] = {}      # uid -> draft rows in sync
        if self._spec:
            dm = draft_model
            dp_a = jax.eval_shape(lambda: dm.init_cache(2, max_ctx))
            dp_b = jax.eval_shape(lambda: dm.init_cache(3, max_ctx))
            dl_a, self._draft_treedef = jax.tree_util.tree_flatten(dp_a)
            dl_b = jax.tree_util.tree_leaves(dp_b)
            self._draft_info = [
                next(i for i in range(a.ndim) if a.shape[i] != b.shape[i])
                for a, b in zip(dl_a, dl_b)]
            self._draft_t_idx = _t_leaf_index(dp_a)
            self._draft_leaves = jax.tree_util.tree_leaves(
                dm.init_cache(decode_batch, max_ctx))
        # worst-case page growth of one lane in one step (the admission
        # watermark reserve): a speculative burst writes spec_k+1 rows
        self._growth_pages = (-(-(self.spec_k + 1) // page_size)
                              if self._spec else 1)

        # ---- storage: paged leaves -> pool-flat, lane leaves -> dense -----
        dense = jax.tree_util.tree_leaves(model.init_cache(decode_batch, max_ctx))
        rows = pool_pages * page_size
        self.leaves: list[jax.Array] = []
        for leaf, (ba, la) in zip(dense, self._info):
            if la is None:
                self.leaves.append(leaf)
            else:
                shape = list(leaf.shape)
                del shape[ba]
                shape[self._pool_axis(ba, la)] = rows
                self.leaves.append(jnp.zeros(shape, leaf.dtype))

        # ---- host-side request state --------------------------------------
        self.waiting: deque[Request] = deque()
        self.lanes: list[Request | None] = [None] * decode_batch
        self._prefill_fifo: list[int] = []   # uids in admission order
        self._off: dict[int, int] = {}       # uid -> prefill progress (tokens)
        self._ctx: dict[int, int] = {}       # uid -> cache positions written
        self._ptoks: dict[int, list[int]] = {}   # uid -> tokens to prefill
        self._skip_emit: set[int] = set()    # resumed victims: no re-emit
        self._uid = 0
        self._traced_chunk_lens: set[int] = set()
        self.last_logits = None
        self.chunk_logits: dict[int, np.ndarray] = {}
        self.preemptions = 0
        # speculative-decode counters + event feed (fleet drains the events
        # into its per-class acceptance tracker)
        self.spec_bursts = 0
        self.spec_proposed = 0
        self.spec_accepted = 0
        self.spec_committed = 0
        self._spec_events: list[dict] = []
        self.defrags = 0                     # pool compactions actually applied
        self.prefill_true_tokens = 0
        self.prefill_padded_tokens = 0       # == true: chunked prefill pads nothing

        # Observability (same contract as the slot engine: the owner
        # rebinds, the default is a one-attribute-check no-op).
        self.tracer = NULL_TRACER
        self.trace_track = "engine"
        self.trace_compute = True

        # ---- execution plan ------------------------------------------------
        self.provider = provider
        self.plan = plan
        self.replans = 0
        self.plan_history: list[tuple[int, int]] = []
        self._steps = 0
        if provider is not None and getattr(provider, "pipeline", None) is not None:
            if self.plan is None:
                self.plan = plan_serving_paged(
                    cfg, provider.pipeline, decode_batch=decode_batch,
                    page_size=page_size, pages_per_seq=self.pages_per_seq,
                    chunk_lens=tuple(range(1, self.chunk + 1)),
                    spec_k=self.spec_k if self._spec else 0,
                    draft_cfg=draft_model.cfg if self._spec else None)
            provider.plan = self.plan
        self._make_fns()

    # ------------------------------------------------------------------
    # jitted entry points
    # ------------------------------------------------------------------
    @staticmethod
    def _pool_axis(ba: int, la: int) -> int:
        """Length axis of the pool-flat leaf (dense leaf minus batch axis)."""
        return la - 1 if ba < la else la

    def _make_fns(self) -> None:
        """(Re)build jitted fns; called at init and after every re-plan."""
        model, provider, info = self.model, self.provider, self._info
        treedef, B = self._treedef, self.decode_batch
        pool_axis = self._pool_axis

        def gather(leaf, idx, ba, la):
            """Pool leaf + (..., T) row indices -> dense leaf rows."""
            pa = pool_axis(ba, la)
            taken = jnp.take(leaf, idx, axis=pa)
            return taken, pa

        def decode_fn(params, leaves, toks, idx, rows, active):
            dense = []
            for leaf, (ba, la) in zip(leaves, info):
                if la is None:
                    dense.append(leaf)
                else:
                    taken, pa = gather(leaf, idx, ba, la)  # (B, T) at pa
                    dense.append(jnp.moveaxis(taken, (pa, pa + 1), (ba, la)))
            cache = jax.tree_util.tree_unflatten(treedef, dense)
            pos = cache["t"]
            logits, new_cache = model.decode_step(params, cache, toks,
                                                  provider=provider)
            new_dense = jax.tree_util.tree_leaves(new_cache)
            out = []
            for leaf, new, (ba, la) in zip(leaves, new_dense, info):
                if la is None:
                    mshape = [1] * leaf.ndim
                    mshape[ba] = B
                    mask = active.reshape(mshape)
                    out.append(jnp.where(mask, new.astype(leaf.dtype), leaf))
                else:
                    pa = pool_axis(ba, la)
                    dn = jnp.moveaxis(new, (ba, la), (0, 1))   # (B, T, *rest)
                    rowvals = dn[jnp.arange(B), pos]           # (B, *rest)
                    pm = jnp.moveaxis(leaf, pa, 0)
                    # inactive lanes carry rows == 0: garbage lands on the
                    # trash page, which nothing ever attends to
                    pm = pm.at[rows].set(rowvals.astype(leaf.dtype))
                    out.append(jnp.moveaxis(pm, 0, pa))
            return logits, out

        def chunk_fn(params, leaves, toks, off, lane, idx_lane):
            C = toks.shape[1]
            view = []
            for leaf, (ba, la) in zip(leaves, info):
                if la is None:
                    view.append(jax.lax.dynamic_slice_in_dim(leaf, lane, 1,
                                                             axis=ba))
                else:
                    taken, pa = gather(leaf, idx_lane, ba, la)
                    view.append(jnp.expand_dims(taken, ba))
            cache = jax.tree_util.tree_unflatten(treedef, view)
            logits, new_cache = model.prefill_chunk(params, cache, toks, off,
                                                    provider=provider)
            new_view = jax.tree_util.tree_leaves(new_cache)
            out = []
            for leaf, new, (ba, la) in zip(leaves, new_view, info):
                if la is None:
                    out.append(jax.lax.dynamic_update_slice_in_dim(
                        leaf, new.astype(leaf.dtype), lane, axis=ba))
                else:
                    pa = pool_axis(ba, la)
                    dn = jnp.moveaxis(new, (ba, la), (0, 1))[0]  # (T, *rest)
                    vals = jax.lax.dynamic_slice_in_dim(dn, off, C, axis=0)
                    rows_c = jax.lax.dynamic_slice(idx_lane, (off,), (C,))
                    pm = jnp.moveaxis(leaf, pa, 0)
                    pm = pm.at[rows_c].set(vals.astype(leaf.dtype))
                    out.append(jnp.moveaxis(pm, 0, pa))
            return logits[0], out

        def reset_fn(leaves, lane):
            """Zero one lane's strip of every lane leaf (fresh recurrent /
            ring state for a new occupant; paged rows need no reset — the
            causal masks never read beyond what a request has written)."""
            out = []
            for leaf, (ba, la) in zip(leaves, info):
                if la is None:
                    zero_shape = list(leaf.shape)
                    zero_shape[ba] = 1
                    out.append(jax.lax.dynamic_update_slice_in_dim(
                        leaf, jnp.zeros(zero_shape, leaf.dtype), lane, axis=ba))
                else:
                    out.append(leaf)
            return out

        self._decode = jax.jit(decode_fn)
        self._chunk = jax.jit(chunk_fn)   # one trace per chunk length
        self._reset = jax.jit(reset_fn)

        if not self._spec:
            return
        draft, K = self.draft_model, self.spec_k
        draft_info, dtreedef = self._draft_info, self._draft_treedef

        def verify_fn(params, leaves, toks, offs, idx, active):
            """Batched speculative verify: toks (B, K+1) at per-lane cache
            offsets ``offs`` — the verify analogue of decode_fn.  One call
            for all lanes: per-lane verify would stream the full weights per
            lane (memory-bound ≈ one decode each) and erase the spec win."""
            dense = []
            for leaf, (ba, la) in zip(leaves, info):
                if la is None:
                    dense.append(leaf)
                else:
                    taken, pa = gather(leaf, idx, ba, la)
                    dense.append(jnp.moveaxis(taken, (pa, pa + 1), (ba, la)))
            cache = jax.tree_util.tree_unflatten(treedef, dense)
            logits, new_cache = model.verify_step(params, cache, toks, offs,
                                                  provider=provider)
            C = toks.shape[1]
            posn = offs[:, None] + jnp.arange(C)                # (B, C)
            rows = jnp.take_along_axis(idx, posn, axis=1)       # (B, C)
            new_dense = jax.tree_util.tree_leaves(new_cache)
            out = []
            for leaf, new, (ba, la) in zip(leaves, new_dense, info):
                if la is None:
                    mshape = [1] * leaf.ndim
                    mshape[ba] = B
                    out.append(jnp.where(active.reshape(mshape),
                                         new.astype(leaf.dtype), leaf))
                else:
                    pa = pool_axis(ba, la)
                    dn = jnp.moveaxis(new, (ba, la), (0, 1))    # (B, T, *rest)
                    rowvals = dn[jnp.arange(B)[:, None], posn]  # (B, C, *rest)
                    pm = jnp.moveaxis(leaf, pa, 0)
                    # inactive lanes carry idx == 0: their C rows land on the
                    # trash page (duplicate writes race harmlessly there)
                    pm = pm.at[rows].set(rowvals.astype(leaf.dtype))
                    out.append(jnp.moveaxis(pm, 0, pa))
            return logits, out

        def draft_burst_fn(dparams, leaves, toks, active):
            """K+1 greedy draft decode steps in one scan: proposals d1..dK
            plus one extra step that only ingests dK's KV row, so an
            all-accept burst leaves the draft cache fully caught up."""
            cache = jax.tree_util.tree_unflatten(dtreedef, leaves)

            def body(carry, _):
                c, tok = carry
                logits, c = draft.decode_step(dparams, c, tok, provider=provider)
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                return (c, nxt), nxt

            (cache, _), props = jax.lax.scan(body, (cache, toks), None,
                                             length=K + 1)
            out = []
            for leaf, new, ba in zip(leaves, jax.tree_util.tree_leaves(cache),
                                     draft_info):
                mshape = [1] * leaf.ndim
                mshape[ba] = B
                out.append(jnp.where(active.reshape(mshape),
                                     new.astype(leaf.dtype), leaf))
            return props, out

        def draft_chunk_fn(dparams, leaves, toks, off, lane):
            """Mirror one target prefill chunk into the draft's dense cache
            (keeps the draft in sync so bursts start from committed state)."""
            view = [jax.lax.dynamic_slice_in_dim(leaf, lane, 1, axis=ba)
                    for leaf, ba in zip(leaves, draft_info)]
            cache = jax.tree_util.tree_unflatten(dtreedef, view)
            _, new_cache = draft.prefill_chunk(dparams, cache, toks, off,
                                               provider=provider)
            new_view = jax.tree_util.tree_leaves(new_cache)
            return [jax.lax.dynamic_update_slice_in_dim(
                        leaf, new.astype(leaf.dtype), lane, axis=ba)
                    for leaf, new, ba in zip(leaves, new_view, draft_info)]

        def draft_reset_fn(leaves, lane):
            out = []
            for leaf, ba in zip(leaves, draft_info):
                zshape = list(leaf.shape)
                zshape[ba] = 1
                out.append(jax.lax.dynamic_update_slice_in_dim(
                    leaf, jnp.zeros(zshape, leaf.dtype), lane, axis=ba))
            return out

        self._verify = jax.jit(verify_fn)
        self._draft_burst = jax.jit(draft_burst_fn)
        self._draft_chunk = jax.jit(draft_chunk_fn)
        self._draft_reset = jax.jit(draft_reset_fn)

    # ------------------------------------------------------------------
    # admission surfaces (router-compatible)
    # ------------------------------------------------------------------
    @property
    def active(self) -> dict[int, Request]:
        """All in-flight requests (waiting + laned), keyed by uid — truthy
        whenever the engine has work, mirroring the slot engine contract."""
        out = {r.uid: r for r in self.lanes if r is not None}
        out.update({r.uid: r for r in self.waiting})
        return out

    @property
    def in_flight(self) -> int:
        return len(self.waiting) + sum(1 for r in self.lanes if r is not None)

    @property
    def free_slots(self) -> int:
        """Admission headroom (queue slots, not lanes: lanes turn over every
        iteration, so admission capacity is what routers should see)."""
        return max(0, self.admit_cap - self.in_flight)

    def utilization(self) -> float:
        """Fraction of the page pool held — the real memory pressure gauge."""
        return self.table.used_pages / self.table.usable_pages

    def kv_used_tokens(self) -> int:
        return sum(self._ctx.get(r.uid, 0)
                   for r in self.lanes if r is not None)

    def kv_capacity_tokens(self) -> int:
        return self.table.capacity_tokens

    def bucket_for(self, prompt_len: int) -> int:
        """Chunk length a prompt of this length mostly runs at (demand
        trackers and routers key on it; no padding is implied)."""
        return min(max(prompt_len, 1), self.chunk)

    @property
    def prefill_trace_count(self) -> int:
        """Distinct chunk lengths traced — bounded by ``chunk``."""
        return len(self._traced_chunk_lens)

    # ------------------------------------------------------------------
    # request admission
    # ------------------------------------------------------------------
    def add_request(self, prompt: list[int], max_new_tokens: int = 16,
                    eos_id: int | None = None, *,
                    speculative: bool | None = None,
                    request_class: str = "") -> Request:
        """Enqueue a request; prefill happens chunk-by-chunk inside
        :meth:`step` (no synchronous work here — admission is O(1)).

        ``speculative=None`` follows the engine default (speculate whenever
        a draft model is configured); an explicit False pins the request to
        plain decode (the fleet's acceptance-aware router uses this).

        Raises :class:`SlotsFull` at the admission cap and ``ValueError``
        for a request the pool can never hold.
        """
        n = len(prompt)
        if n < 1:
            raise ValueError("empty prompt")
        total = n + max(max_new_tokens, 0)
        if total > self.max_ctx:
            raise ValueError(
                f"prompt {n} + max_new_tokens {max_new_tokens} exceeds "
                f"max_ctx {self.max_ctx} (per-request max_len)")
        if self.table.pages_for(total) > self.table.usable_pages:
            raise ValueError(
                f"request needs {self.table.pages_for(total)} pages; pool "
                f"has {self.table.usable_pages}")
        if self.in_flight >= self.admit_cap:
            raise SlotsFull(
                f"admission cap {self.admit_cap} reached")
        self._uid += 1
        req = Request(self._uid, list(prompt), max_new_tokens, eos_id,
                      speculative=(self._spec if speculative is None
                                   else bool(speculative) and self._spec),
                      request_class=request_class)
        self.waiting.append(req)
        self._ptoks[req.uid] = list(prompt)
        return req

    # ------------------------------------------------------------------
    # scheduling (pure: both the step executor and the fleet cost preview)
    # ------------------------------------------------------------------
    def _schedule(self) -> dict:
        """Decide this iteration's work from current state, deterministically.

        Returns admits / chunks / decode lanes / preemptions.  Page
        feasibility is *simulated* against the live table so execution
        (which allocates in the same order) can never hit
        :class:`PagesExhausted` unexpectedly.  Called by :meth:`step` right
        before executing and by :meth:`planned_work` for the fleet's cost
        model — same state, same answer.
        """
        held = {uid: len(self.table.pages(uid)) for uid in self.table.holders()}
        sim_free = self.table.free_pages
        pages_for = self.table.pages_for

        # Admission gate (the vLLM watermark idiom): only admit when the
        # pool can hold the request's whole prompt on top of worst-case
        # decode growth this step — admitting into a pool that cannot feed
        # the prefill just converts the new request into preemption churn.
        admits: list[tuple[Request, int]] = []
        free_lanes = [i for i, r in enumerate(self.lanes) if r is None]
        admit_free = sim_free - sum(
            self._growth_pages if (self._spec and r.speculative) else 1
            for r in self.lanes if r is not None)
        for lane, req in zip(free_lanes, self.waiting):
            need = pages_for(len(self._ptoks[req.uid]))
            if need > admit_free:
                break  # FIFO: later arrivals do not jump the page queue
            admit_free -= need
            admits.append((req, lane))

        # prefill chunks: strict FIFO, bounded per step
        prefilling: list[Request] = []
        by_uid = {r.uid: r for r in self.lanes if r is not None}
        for uid in self._prefill_fifo:
            r = by_uid.get(uid)
            if r is not None and self._off[uid] < len(self._ptoks[uid]):
                prefilling.append(r)
        prefilling.extend(r for r, _ in admits)
        chunks: list[tuple[int, int, int, bool]] = []
        draft_sync: list[int] = []           # chunk mirrors into the draft
        budget = self.chunks_per_step
        for r in prefilling:
            if budget <= 0:
                break
            off = self._off.get(r.uid, 0)
            n = len(self._ptoks[r.uid])
            # Shrink the chunk to what the pool can hold right now: a
            # partial chunk keeps a long prefill moving under page pressure
            # instead of head-of-line blocking every prefill behind it
            # (chunked prefill is exact at any split point).
            cap = (held.get(r.uid, 0) + sim_free) * self.page_size - off
            c = min(self.chunk, n - off, cap)
            if c <= 0:
                continue  # no pages for even one token: skip, not stall
            need = pages_for(off + c) - held.get(r.uid, 0)
            sim_free -= max(need, 0)
            held[r.uid] = held.get(r.uid, 0) + max(need, 0)
            chunks.append((r.uid, off, c, off + c >= n))
            if self._spec and r.speculative:
                draft_sync.append(c)
            budget -= 1

        # decode lanes + page-pressure preemption (evict youngest decoders)
        chunk_uids = {c[0] for c in chunks}
        decoders = [r for r in self.lanes
                    if r is not None and r.uid not in chunk_uids
                    and self._off.get(r.uid, 0) >= len(self._ptoks[r.uid])]
        spec_set = {r.uid for r in decoders if self._spec_ready(r)}
        needs = {r.uid: pages_for(self._ctx[r.uid]
                                  + (self.spec_k + 1 if r.uid in spec_set else 1)
                                  ) - held.get(r.uid, 0)
                 for r in decoders}
        preempts: list[int] = []
        total_need = sum(max(v, 0) for v in needs.values())
        if total_need > sim_free:
            for victim in sorted(decoders, key=lambda r: -r.uid):
                preempts.append(victim.uid)
                sim_free += held.get(victim.uid, 0)
                total_need -= max(needs[victim.uid], 0)
                if total_need <= sim_free:
                    break
        decode_uids = [r.uid for r in decoders if r.uid not in preempts]
        spec_uids = [u for u in decode_uids if u in spec_set]

        # deadlock breaker: >= 2 prefilling holders, none can grow, nothing
        # decoding to release pages naturally -> evict the youngest holder
        stall_preempts: list[int] = []
        if not chunks and not decode_uids and not preempts and prefilling:
            holders = [r for r in prefilling if held.get(r.uid, 0) > 0]
            if len(holders) > 1:
                stall_preempts.append(max(h.uid for h in holders))
        return {"admits": admits, "chunks": chunks,
                "decode_uids": decode_uids, "spec_uids": spec_uids,
                "draft_sync_lens": draft_sync, "preempts": preempts,
                "stall_preempts": stall_preempts}

    def _spec_ready(self, req: Request) -> bool:
        """Can this decoding lane run a draft-then-verify burst next step?

        Pure state inspection (scheduler contract: :meth:`planned_work`'s
        preview must equal :meth:`step`'s execution).  A lane whose draft
        cache fell out of sync — it ran plain steps near the context or
        token budget bound — stays plain: both bounds only tighten as the
        request ages, so the lane could never speculate again anyway.
        """
        if not self._spec or not req.speculative:
            return False
        ctx = self._ctx[req.uid]
        if self._draft_ctx.get(req.uid) != ctx:
            return False
        if ctx + self.spec_k + 1 > self.max_ctx:
            return False
        # fewer than 2 tokens of budget left: a burst cannot beat one
        # plain decode step (the correction token alone finishes it)
        return req.max_new_tokens - len(req.generated) >= 2

    def planned_work(self) -> dict:
        """Preview of the next :meth:`step`'s work for external cost models:
        chunk lengths to run, whether a batched decode runs, and admissions."""
        acts = self._schedule()
        plain = len(acts["decode_uids"]) - len(acts["spec_uids"])
        return {
            "chunk_lens": [c for _, _, c, _ in acts["chunks"]],
            "decode": plain > 0,
            "decode_lanes": plain,
            "spec_lanes": len(acts["spec_uids"]),
            "draft_steps": self.spec_k + 1 if acts["spec_uids"] else 0,
            "verify_len": self.spec_k + 1 if acts["spec_uids"] else 0,
            "draft_sync_lens": list(acts["draft_sync_lens"]),
            "admits": len(acts["admits"]),
            "preempts": len(acts["preempts"]) + len(acts["stall_preempts"]),
        }

    # ------------------------------------------------------------------
    # plan upkeep (identical contract to the slot engine)
    # ------------------------------------------------------------------
    def _maybe_replan(self) -> None:
        if self.plan is None or self.provider is None:
            return
        if self.provider.pipeline.generation() == self.plan.generation:
            return
        self.plan = self.plan.refresh(self.provider.pipeline)
        self.provider.plan = self.plan
        self.replans += 1
        self._make_fns()
        if self.tracer.enabled:
            self.tracer.event("replan", self.trace_track,
                              generation=self.plan.generation,
                              replans=self.replans)

    def refresh_plan(self) -> bool:
        before = self.replans
        self._maybe_replan()
        return self.replans != before

    # ------------------------------------------------------------------
    # lifecycle: withdrawal (drain-retire support)
    # ------------------------------------------------------------------
    def withdraw_waiting(self) -> list[int]:
        """Remove and return the uids of waiting requests with no progress.

        Used when this engine is being drain-retired: requests it accepted
        but never started (no chunk run, no token emitted) can be replayed
        elsewhere verbatim.  Preempted victims carrying generated tokens are
        *kept* — they hold partial output only this engine can finish.
        Withdrawn requests hold no pages (pages are allocated lane-side), so
        no pool cleanup is needed.
        """
        kept: deque[Request] = deque()
        out: list[int] = []
        while self.waiting:
            r = self.waiting.popleft()
            if r.generated or r.uid in self._skip_emit:
                kept.append(r)
                continue
            self._ptoks.pop(r.uid, None)
            out.append(r.uid)
        self.waiting = kept
        return out

    # ------------------------------------------------------------------
    # defragmentation
    # ------------------------------------------------------------------
    def _defrag(self) -> int:
        """Compact the page pool and replay the moves on the KV rows.

        :meth:`PageTable.defrag` rewrites the table and returns
        ``(src, dst)`` page moves whose destinations were free — so copying
        src rows over dst rows in each pool-flat leaf never clobbers live
        data, in any order.  Generations are bit-exact across a defrag: the
        same rows hold the same values, only at new pool offsets, and
        ``flat_rows`` already points at them.
        """
        moves = self.table.defrag()
        if not moves:
            return 0
        ps = self.page_size
        src = jnp.asarray(np.concatenate(
            [np.arange(s * ps, (s + 1) * ps) for s, _ in moves]))
        dst = jnp.asarray(np.concatenate(
            [np.arange(d * ps, (d + 1) * ps) for _, d in moves]))
        for i, (leaf, (ba, la)) in enumerate(zip(self.leaves, self._info)):
            if la is None:
                continue
            pa = self._pool_axis(ba, la)
            pm = jnp.moveaxis(leaf, pa, 0)
            pm = pm.at[dst].set(pm[src])
            self.leaves[i] = jnp.moveaxis(pm, 0, pa)
        self.defrags += 1
        if self.tracer.enabled:
            self.tracer.event("defrag", self.trace_track, moves=len(moves))
        return len(moves)

    # ------------------------------------------------------------------
    # the iteration
    # ------------------------------------------------------------------
    def _preempt(self, uid: int) -> None:
        """Evict a request: free pages, requeue at the FRONT of waiting with
        recompute-on-resume (re-prefill prompt + tokens so far; the pending
        token is re-fed, not re-emitted)."""
        lane = next(i for i, r in enumerate(self.lanes)
                    if r is not None and r.uid == uid)
        req = self.lanes[lane]
        self.lanes[lane] = None
        self.table.release(uid)
        if uid in self._prefill_fifo:
            self._prefill_fifo.remove(uid)
        self._off.pop(uid, None)
        self._ctx.pop(uid, None)
        self._draft_ctx.pop(uid, None)
        if req.generated:
            self._ptoks[uid] = req.prompt + req.generated[:-1]
            self._skip_emit.add(uid)
        else:
            self._ptoks[uid] = list(req.prompt)
        self.waiting.appendleft(req)
        self.preemptions += 1
        if self.tracer.enabled:
            self.tracer.event("preempt", self.trace_track, uid=uid,
                              generated=len(req.generated))

    def _release(self, req: Request) -> None:
        uid = req.uid
        lane = next(i for i, r in enumerate(self.lanes)
                    if r is not None and r.uid == uid)
        self.lanes[lane] = None
        self.table.release(uid)
        if uid in self._prefill_fifo:
            self._prefill_fifo.remove(uid)
        self._off.pop(uid, None)
        self._ctx.pop(uid, None)
        self._draft_ctx.pop(uid, None)
        self._ptoks.pop(uid, None)
        self._skip_emit.discard(uid)

    def drain_spec_events(self) -> list[dict]:
        """Hand off accumulated per-burst speculative events (uid, class,
        proposed, accepted, committed) — the fleet feeds these into its
        per-request-class acceptance tracker."""
        out, self._spec_events = self._spec_events, []
        return out

    def _spec_step(self, spec_uids: list[int]) -> list[Request]:
        """One draft-then-verify burst over the speculating lanes.

        Draft proposes K tokens (K+1 scanned decode steps — the extra step
        ingests the last proposal's KV row so an all-accept burst leaves the
        draft caught up), the target verifies all lanes in ONE batched
        ``verify_step``, and greedy acceptance commits the longest agreeing
        prefix plus the target's correction token — bit-exact vs plain
        greedy decode.  Rejected cache rows need no explicit rollback: the
        host-side ``_ctx`` is the truth, the decode-position leaf is
        rewritten from it below, and stale rows are masked (validity masks
        key on position) until later writes overwrite them in order.

        Exactly three host syncs per burst regardless of lane count:
        proposals, greedy verify argmax, and nothing per-lane.
        """
        K, B = self.spec_k, self.decode_batch
        toks = np.zeros(B, np.int32)
        offs = np.zeros(B, np.int32)
        idx = np.zeros((B, self.max_ctx), np.int32)
        active = np.zeros(B, bool)
        spec_lanes: list[tuple[int, Request]] = []
        for lane, req in enumerate(self.lanes):
            if req is None or req.uid not in spec_uids:
                continue
            uid, ctx = req.uid, self._ctx[req.uid]
            self.table.ensure(uid, ctx + K + 1)   # simulation guaranteed it
            toks[lane] = req.generated[-1]
            offs[lane] = ctx
            idx[lane] = self.table.flat_rows(uid, self.max_ctx)
            active[lane] = True
            spec_lanes.append((lane, req))

        # Rebuild the draft's decode positions from host truth: the leaf
        # still carries the previous burst's full K+1 advance, which the
        # acceptance decision may have partially rolled back.
        dt = np.zeros(B, np.int32)
        for lane, req in spec_lanes:
            dt[lane] = self._draft_ctx[req.uid]
        self._draft_leaves[self._draft_t_idx] = jnp.asarray(dt)
        if self.tracer.enabled and self.trace_compute:
            with self.tracer.span("draft_burst", self.trace_track,
                                  lanes=len(spec_lanes), k=K):
                props, self._draft_leaves = self._draft_burst(
                    self.draft_params, self._draft_leaves,
                    jnp.asarray(toks), jnp.asarray(active))
        else:
            props, self._draft_leaves = self._draft_burst(
                self.draft_params, self._draft_leaves,
                jnp.asarray(toks), jnp.asarray(active))
        props_host = np.asarray(props)            # (K+1, B); row K is ingest-only

        vt = np.zeros((B, K + 1), np.int32)
        vt[:, 0] = toks                            # pending token first
        vt[:, 1:] = props_host[:K].T
        if self.tracer.enabled and self.trace_compute:
            with self.tracer.span("verify", self.trace_track,
                                  lanes=len(spec_lanes), k=K):
                logits, self.leaves = self._verify(
                    self.params, self.leaves, jnp.asarray(vt),
                    jnp.asarray(offs), jnp.asarray(idx), jnp.asarray(active))
        else:
            logits, self.leaves = self._verify(
                self.params, self.leaves, jnp.asarray(vt), jnp.asarray(offs),
                jnp.asarray(idx), jnp.asarray(active))
        greedy = np.asarray(jnp.argmax(logits, axis=-1))   # (B, K+1)

        finished: list[Request] = []
        for lane, req in spec_lanes:
            uid = req.uid
            d = props_host[:K, lane]
            g = greedy[lane]
            a = 0
            while a < K and int(g[a]) == int(d[a]):
                a += 1
            done = False
            committed = 0
            for tok in [int(x) for x in d[:a]] + [int(g[a])]:
                req.generated.append(tok)
                committed += 1
                if (req.eos_id is not None and tok == req.eos_id) or \
                        len(req.generated) >= req.max_new_tokens:
                    done = True
                    break
            self.spec_bursts += 1
            self.spec_proposed += K
            self.spec_accepted += a
            self.spec_committed += committed
            new_ctx = len(req.prompt) + len(req.generated) - 1
            self._ctx[uid] = new_ctx
            self._draft_ctx[uid] = new_ctx
            self._spec_events.append({
                "uid": uid, "request_class": req.request_class,
                "proposed": K, "accepted": a, "committed": committed})
            if self.tracer.enabled:
                self.tracer.event("spec_burst", self.trace_track, uid=uid,
                                  accepted=a, proposed=K, committed=committed,
                                  request_class=req.request_class)
            if done:
                req.done = True
                finished.append(req)
                self._release(req)

        # Wholesale decode-position rollback: overwrite the t leaf from the
        # host _ctx map (verify advanced every speculating lane by K+1; the
        # accepted prefix may be shorter).  Non-speculating lanes keep their
        # exact current positions, so this is a no-op for them.
        t_host = np.zeros(B, np.int32)
        for lane, req in enumerate(self.lanes):
            if req is not None and req.uid in self._ctx:
                t_host[lane] = self._ctx[req.uid]
        self.leaves[self._t_idx] = jnp.asarray(t_host)
        return finished

    def step(self) -> list[Request]:
        """One iteration: admit, one prefill chunk each (bounded), one
        batched decode over decoding lanes.  Returns finished requests."""
        self._maybe_replan()
        if not self.in_flight:
            return []
        # Step boundary is the one safe instant to move pages: no chunk or
        # decode is mid-flight, so the table and the pool rows agree.
        if self.defrag_threshold is not None and \
                self.table.fragmentation() > self.defrag_threshold:
            self._defrag()
        self._steps += 1
        if self.plan is not None and (
                not self.plan_history
                or self.plan_history[-1][1] != self.plan.generation):
            self.plan_history.append((self._steps, self.plan.generation))

        acts = self._schedule()
        if self.tracer.enabled:
            self.tracer.event(
                "schedule", self.trace_track, step=self._steps,
                admits=len(acts["admits"]), chunks=len(acts["chunks"]),
                decode_lanes=len(acts["decode_uids"]),
                spec_lanes=len(acts["spec_uids"]),
                preempts=len(acts["preempts"]) + len(acts["stall_preempts"]),
                waiting=len(self.waiting))
        finished: list[Request] = []

        for req, lane in acts["admits"]:
            assert self.waiting and self.waiting[0] is req
            self.waiting.popleft()
            self.lanes[lane] = req
            self._prefill_fifo.append(req.uid)
            self._off[req.uid] = 0
            self._ctx[req.uid] = 0
            self.leaves = self._reset(self.leaves, lane)
            if self._spec and req.speculative:
                self._draft_ctx[req.uid] = 0
                self._draft_leaves = self._draft_reset(self._draft_leaves, lane)

        # final-chunk emissions are batched into one argmax + one host pull
        # at the end of the loop (the old per-request int(jnp.argmax(...))
        # forced one device sync per finishing prefill)
        pending_finals: list[tuple[int, Request, jax.Array]] = []
        for uid, off, c, final in acts["chunks"]:
            self.table.ensure(uid, off + c)   # simulation guarantees success
            req = next(r for r in self.lanes if r is not None and r.uid == uid)
            lane = self.lanes.index(req)
            toks = self._ptoks[uid][off:off + c]
            idx_lane = jnp.asarray(self.table.flat_rows(uid, self.max_ctx))
            self._traced_chunk_lens.add(c)
            if self.tracer.enabled and self.trace_compute:
                with self.tracer.span("chunk", self.trace_track, uid=uid,
                                      len=c, final=final):
                    logits, self.leaves = self._chunk(
                        self.params, self.leaves,
                        jnp.asarray([toks], jnp.int32),
                        jnp.asarray(off, jnp.int32),
                        jnp.asarray(lane, jnp.int32), idx_lane)
            else:
                logits, self.leaves = self._chunk(
                    self.params, self.leaves,
                    jnp.asarray([toks], jnp.int32), jnp.asarray(off, jnp.int32),
                    jnp.asarray(lane, jnp.int32), idx_lane)
            if self._spec and req.speculative:
                if self.tracer.enabled and self.trace_compute:
                    with self.tracer.span("draft_sync", self.trace_track,
                                          uid=uid, len=c):
                        self._draft_leaves = self._draft_chunk(
                            self.draft_params, self._draft_leaves,
                            jnp.asarray([toks], jnp.int32),
                            jnp.asarray(off, jnp.int32),
                            jnp.asarray(lane, jnp.int32))
                else:
                    self._draft_leaves = self._draft_chunk(
                        self.draft_params, self._draft_leaves,
                        jnp.asarray([toks], jnp.int32),
                        jnp.asarray(off, jnp.int32),
                        jnp.asarray(lane, jnp.int32))
                self._draft_ctx[uid] = off + c
            self._off[uid] = off + c
            self._ctx[uid] = off + c
            self.prefill_true_tokens += c
            self.prefill_padded_tokens += c   # exact-length: zero waste
            if final:
                if uid in self._skip_emit:
                    self._skip_emit.discard(uid)   # resume: token already held
                else:
                    pending_finals.append((uid, req, logits))

        if pending_finals:
            first = np.asarray(jnp.argmax(
                jnp.stack([l for _, _, l in pending_finals]), axis=-1))
            for (uid, req, logits), tok in zip(pending_finals, first):
                if self.record_logits:
                    self.chunk_logits[uid] = np.asarray(logits)
                tok = int(tok)
                req.generated.append(tok)
                if req.max_new_tokens <= 0 or (
                        req.eos_id is not None and tok == req.eos_id) or \
                        len(req.generated) >= req.max_new_tokens:
                    req.done = True
                    finished.append(req)
                    self._release(req)

        for uid in acts["preempts"] + acts["stall_preempts"]:
            self._preempt(uid)

        if acts["spec_uids"]:
            finished.extend(self._spec_step(acts["spec_uids"]))

        spec_set = set(acts["spec_uids"])
        decode_uids = [u for u in acts["decode_uids"] if u not in spec_set]
        if decode_uids:
            B = self.decode_batch
            toks = np.zeros(B, np.int32)
            idx = np.zeros((B, self.max_ctx), np.int32)
            rows = np.zeros(B, np.int32)
            active = np.zeros(B, bool)
            lanes_decoding = []
            for lane, req in enumerate(self.lanes):
                if req is None or req.uid not in decode_uids:
                    continue
                uid, ctx = req.uid, self._ctx[req.uid]
                self.table.ensure(uid, ctx + 1)
                pages = self.table.pages(uid)
                toks[lane] = req.generated[-1]
                idx[lane] = self.table.flat_rows(uid, self.max_ctx)
                rows[lane] = (pages[ctx // self.page_size] * self.page_size
                              + ctx % self.page_size)
                active[lane] = True
                lanes_decoding.append((lane, req))
            if self.tracer.enabled and self.trace_compute:
                with self.tracer.span("decode", self.trace_track,
                                      lanes=len(lanes_decoding)):
                    logits, self.leaves = self._decode(
                        self.params, self.leaves, jnp.asarray(toks),
                        jnp.asarray(idx), jnp.asarray(rows),
                        jnp.asarray(active))
            else:
                logits, self.leaves = self._decode(
                    self.params, self.leaves, jnp.asarray(toks),
                    jnp.asarray(idx), jnp.asarray(rows), jnp.asarray(active))
            self.last_logits = logits
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            for lane, req in lanes_decoding:
                tok = int(nxt[lane])
                req.generated.append(tok)
                self._ctx[req.uid] += 1
                if (req.eos_id is not None and tok == req.eos_id) or \
                        len(req.generated) >= req.max_new_tokens:
                    req.done = True
                    finished.append(req)
                    self._release(req)
        return finished

    def run_to_completion(self, max_steps: int = 4096) -> None:
        for _ in range(max_steps):
            if not self.in_flight:
                break
            self.step()


def _t_leaf_index(cache_tree) -> int:
    """Flat-leaf index of the cache's top-level ``t`` (decode positions)
    vector — the one leaf speculative acceptance rewrites wholesale from
    host state after each burst."""
    paths = jax.tree_util.tree_flatten_with_path(cache_tree)[0]
    for i, (path, _) in enumerate(paths):
        if len(path) == 1 and getattr(path[0], "key", None) == "t":
            return i
    raise ValueError("cache pytree has no top-level 't' leaf")
