"""Paged KV-cache bookkeeping: a fixed page pool + per-request page tables.

The paged serving engine stores every *length-scaling* cache leaf (the full
KV buffers of global-attention layers) in one flat pool of fixed-size pages
instead of one dense ``(batch, ..., max_len, ...)`` buffer per decode slot.
A :class:`PageTable` maps each live request to an ordered page list; token
position ``t`` of a request lives at pool row ``pages[t // page_size] *
page_size + t % page_size``.  Decode gathers each lane's rows into a dense
per-lane view (so the model's decode step is *numerically identical* to the
contiguous cache — the equivalence tests assert bit-exact logits) and
scatters only the newly written row back.

Page 0 is reserved as the *trash page*: inactive decode lanes and
positions beyond a request's allocation map to it, so masked writes need no
branches — garbage lands in rows nothing ever attends to.

Ring (windowed) and recurrent-state leaves are O(window)/O(1) per lane and
stay dense per lane — paging them would buy nothing (see DESIGN.md §8).
"""
from __future__ import annotations

import numpy as np


class PagesExhausted(RuntimeError):
    """Raised when an allocation needs more pages than the pool has free —
    the engine's preemption signal (evict a request or defer the work)."""


class PageTable:
    """Fixed pool of ``num_pages`` pages of ``page_size`` token slots each.

    Page 0 is reserved (the trash page); ``usable_pages`` is what requests
    can actually hold.  Allocation is deterministic — lowest-numbered free
    page first — so identical request streams produce identical layouts.
    """

    TRASH_PAGE = 0

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is reserved)")
        if page_size < 1:
            raise ValueError("page_size must be positive")
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: list[int] = list(range(1, num_pages))  # kept sorted
        self._pages: dict[int, list[int]] = {}             # uid -> page list
        self.allocs = 0
        self.releases = 0
        self.defrags = 0

    # -- accounting -----------------------------------------------------------
    @property
    def usable_pages(self) -> int:
        return self.num_pages - 1

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.usable_pages - len(self._free)

    @property
    def capacity_tokens(self) -> int:
        """Token slots the pool can hold (trash page excluded)."""
        return self.usable_pages * self.page_size

    def pages(self, uid: int) -> list[int]:
        return list(self._pages.get(uid, ()))

    def holders(self) -> list[int]:
        """uids currently holding pages (insertion order)."""
        return list(self._pages)

    def held_tokens(self, uid: int) -> int:
        """Token capacity of the pages ``uid`` holds."""
        return len(self._pages.get(uid, ())) * self.page_size

    def pages_for(self, tokens: int) -> int:
        """Pages needed to hold ``tokens`` token slots."""
        return -(-max(tokens, 0) // self.page_size)

    # -- alloc / free ----------------------------------------------------------
    def ensure(self, uid: int, tokens: int) -> list[int]:
        """Grow ``uid``'s allocation to cover ``tokens`` token positions.

        Returns the pages newly allocated (empty when already covered).
        Raises :class:`PagesExhausted` — without allocating anything — when
        the pool cannot satisfy the growth.
        """
        have = self._pages.setdefault(uid, [])
        need = self.pages_for(tokens) - len(have)
        if need <= 0:
            return []
        if need > len(self._free):
            if not have:
                del self._pages[uid]
            raise PagesExhausted(
                f"uid {uid} needs {need} pages, {len(self._free)} free")
        new = self._free[:need]
        del self._free[:need]
        have.extend(new)
        self.allocs += len(new)
        return new

    def release(self, uid: int) -> int:
        """Free every page ``uid`` holds; returns the count freed."""
        pages = self._pages.pop(uid, [])
        if pages:
            self._free.extend(pages)
            self._free.sort()
            self.releases += len(pages)
        return len(pages)

    # -- pool-row addressing ---------------------------------------------------
    def flat_rows(self, uid: int, length: int) -> np.ndarray:
        """Pool-flat row index per token position ``0..length-1``.

        Positions beyond ``uid``'s allocation (or of an unknown uid) map to
        the trash page — the caller masks them, so any value is safe.
        """
        ps = self.page_size
        rows = np.zeros(length, np.int32)  # trash rows by default
        pages = self._pages.get(uid)
        if not pages:
            return rows
        pos = np.arange(length)
        page_idx = pos // ps
        valid = page_idx < len(pages)
        page_arr = np.asarray(pages, np.int32)
        rows[valid] = page_arr[page_idx[valid]] * ps + (pos[valid] % ps)
        return rows

    # -- fragmentation ---------------------------------------------------------
    def fragmentation(self) -> float:
        """1 − (longest contiguous free run / free pages): 0.0 when the free
        space is one block (or empty), approaching 1.0 when it is shredded
        into single pages — the gauge the defragmenter watches."""
        if not self._free:
            return 0.0
        longest = run = 1
        for a, b in zip(self._free, self._free[1:]):
            run = run + 1 if b == a + 1 else 1
            longest = max(longest, run)
        return 1.0 - longest / len(self._free)

    def defrag(self) -> list[tuple[int, int]]:
        """Compact allocations into the lowest page numbers.

        Only pages *above* the compaction watermark move, and they move into
        pages that are currently free — so the returned ``(src, dst)`` moves
        never overwrite live data and may be applied in any order (the owner
        of the physical pool copies src rows over dst rows).  The table is
        already rewritten when this returns; allocation order per request is
        preserved, so ``flat_rows`` stays position-consistent.
        """
        used = [p for pages in self._pages.values() for p in pages]
        k = len(used)
        target = set(range(1, k + 1))
        dst_slots = sorted(target.difference(used))     # free low pages
        movers = sorted(p for p in used if p > k)       # high pages to move
        mapping = dict(zip(movers, dst_slots))
        moves = sorted(mapping.items())
        if moves:
            for pages in self._pages.values():
                for i, p in enumerate(pages):
                    if p in mapping:
                        pages[i] = mapping[p]
            self.defrags += 1
        self._free = list(range(k + 1, self.num_pages))
        return moves

    def stats(self) -> dict:
        return {
            "num_pages": self.num_pages,
            "page_size": self.page_size,
            "used_pages": self.used_pages,
            "free_pages": self.free_pages,
            "holders": len(self._pages),
            "fragmentation": self.fragmentation(),
            "allocs": self.allocs,
            "releases": self.releases,
            "defrags": self.defrags,
        }
