"""Speculative-decoding support: acceptance math, exactness gating, and a
self-draft constructor for tests/benchmarks.

Greedy draft-then-verify (Leviathan et al. 2023; the serving-side analogue of
the tuner's Pruner draft/verify seam from PR 1): a small draft model proposes
``k`` tokens per burst, the target verifies all of them — plus the correction
token — in one batched ``verify_step``.  With greedy acceptance the committed
stream is *bit-exact* vs plain greedy decode, so speculation is purely a
throughput knob.

The economics only work because verify is batched across lanes: decode is
memory-bound, so a burst costs roughly (k+1 cheap draft steps + one
decode-priced verify) and commits ``expected_committed_tokens(k, alpha)``
tokens — the quantity the acceptance-aware cost model divides by.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def expected_committed_tokens(k: int, alpha: float) -> float:
    """E[tokens committed per burst] for draft length ``k`` and per-token
    acceptance probability ``alpha`` (i.i.d. model): 1 + a + ... + a^k.

    Every burst commits at least 1 (the correction token); all-accept commits
    k+1 (k drafts + the free extra token from the verify logits).
    """
    if k <= 0:
        return 1.0
    a = min(max(float(alpha), 0.0), 1.0)
    if a >= 1.0:
        return float(k + 1)
    return (1.0 - a ** (k + 1)) / (1.0 - a)


def spec_gain(k: int, alpha: float, *, draft_cost_s: float, verify_cost_s: float,
              decode_cost_s: float) -> float:
    """Throughput multiplier of speculating vs plain decode: tokens/s ratio.

    Plain decode commits 1 token per ``decode_cost_s``.  A burst costs
    ``(k+1) * draft_cost_s + verify_cost_s`` (the draft runs k+1 steps so its
    cache covers the all-accept case) and commits E(k, alpha) tokens.
    """
    if k <= 0 or decode_cost_s <= 0:
        return 1.0
    burst = (k + 1) * draft_cost_s + verify_cost_s
    if burst <= 0:
        return 1.0
    return expected_committed_tokens(k, alpha) * decode_cost_s / burst


def spec_exact_reason(cfg: ArchConfig) -> str:
    """"" if ``cfg`` supports bit-exact speculative verify, else why not.

    Verify needs every rejected KV row to be recoverable by plain overwrite,
    which only full-length caches give: ring (windowed local) caches lose
    history on wrap, and recurrent state cannot be partially rolled back.
    """
    if cfg.family == "audio":
        return "audio encdec family has no chunked/verify path"
    if cfg.vision_tokens:
        return "vision-prefix archs lack the chunked/verify path"
    kinds = set(cfg.layer_kinds)
    if "R" in kinds:
        return "recurrent layers: state cannot roll back rejected tokens"
    if "L" in kinds and cfg.window > 0:
        return "windowed local layers: ring cache loses rejected-row history"
    return ""


def make_self_draft(cfg: ArchConfig, params: dict, *, keep_layers: int,
                    damp: float = 0.0) -> tuple[ArchConfig, dict, dict]:
    """Build a truncated self-draft: ``(draft_cfg, draft_params, target_params)``.

    The draft is the target's first ``keep_layers`` layers sharing the
    embedding / final norm / lm head; the returned *target* params have every
    deeper layer's residual contribution (attn ``wo``, mlp ``w_out``) scaled
    by ``damp``.  ``damp=0`` makes the damped target exactly equal to the
    draft (acceptance rate 1); small ``damp`` yields a high-but-partial
    acceptance rate.  This gives tests and benchmarks a draft/target pair
    with *controllable* agreement and zero extra training.

    Requires a single-kind layer pattern with no tail remainder (e.g.
    minitron-4b's ("G",)).
    """
    if len(cfg.layer_pattern) != 1 or cfg.n_layers % len(cfg.layer_pattern):
        raise ValueError("self-draft needs a single-group layer pattern")
    if not 0 < keep_layers <= cfg.n_layers:
        raise ValueError(f"keep_layers must be in 1..{cfg.n_layers}")

    stacked = params["groups"]["0"]
    damped = dict(stacked)
    for block, key in (("attn", "wo"), ("mlp", "w_out")):
        w = stacked[block][key]
        factor = jnp.where(jnp.arange(w.shape[0]) < keep_layers, 1.0, damp)
        damped[block] = dict(stacked[block])
        damped[block][key] = (w * factor.reshape((-1,) + (1,) * (w.ndim - 1))
                              ).astype(w.dtype)

    target_params = dict(params)
    target_params["groups"] = {"0": damped}

    draft_cfg = dataclasses.replace(cfg, name=f"{cfg.name}-draft{keep_layers}",
                                    n_layers=keep_layers)
    draft_params = dict(params)
    draft_params["groups"] = {
        "0": jax.tree_util.tree_map(lambda x: x[:keep_layers], params["groups"]["0"])
    }
    draft_params["tail"] = []
    return draft_cfg, draft_params, target_params
