"""Batched serving engine: slot-based continuous batching.

The engine owns a fixed number of decode *slots* (the serving batch) and a
single batched cache whose ``t`` vector tracks a per-slot decode position —
sequences at different lengths decode together in one ``decode_step`` call.
New requests are prefilled (batch=1) into a free slot by splicing that
slot's rows of every cache leaf; finished sequences (EOS / max-tokens) free
their slot immediately, keeping the decode batch dense.

This is the TPU-idiomatic shape of continuous batching for fixed-size
caches; ring buffers (windowed layers) and recurrent states come from the
model substrate unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.build import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int
    eos_id: int | None = None
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, model: Model, params: Any, *, slots: int, max_len: int,
                 extras: dict | None = None):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.extras = {k: jnp.asarray(v) for k, v in (extras or {}).items()}
        self.cache = model.init_cache(slots, max_len)
        self.active: dict[int, Request] = {}
        self.last_logits = None   # (slots, vocab) from the latest decode step
        self._uid = 0
        self._decode = jax.jit(model.decode_step)

    # -- request admission ---------------------------------------------------
    def add_request(self, prompt: list[int], max_new_tokens: int = 16,
                    eos_id: int | None = None) -> Request | None:
        """Admit a request into a free slot (None if the batch is full)."""
        free = [s for s in range(self.slots) if s not in self.active]
        if not free:
            return None
        slot = free[0]
        self._uid += 1
        req = Request(self._uid, list(prompt), max_new_tokens, eos_id)
        batch = {"tokens": jnp.asarray([req.prompt], jnp.int32)}
        for k, v in self.extras.items():
            batch[k] = v[None] if v.ndim == 2 else v  # (1, ..., D) stub inputs
        logits, cache1 = self.model.prefill(self.params, batch, max_len=self.max_len)
        req.generated.append(int(jnp.argmax(logits[0])))
        self.cache = jax.tree_util.tree_map(
            lambda full, one: _splice_slot(full, one, slot), self.cache, cache1
        )
        self.active[slot] = req
        return req

    # -- decode ----------------------------------------------------------------
    def step(self) -> list[Request]:
        """One batched decode step for all active slots; returns finished."""
        if not self.active:
            return []
        toks = np.zeros(self.slots, np.int32)
        for slot, req in self.active.items():
            toks[slot] = req.generated[-1]
        logits, self.cache = self._decode(self.params, self.cache, jnp.asarray(toks))
        self.last_logits = logits
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        finished = []
        for slot, req in list(self.active.items()):
            tok = int(nxt[slot])
            req.generated.append(tok)
            if (req.eos_id is not None and tok == req.eos_id) or \
                    len(req.generated) > req.max_new_tokens:
                req.done = True
                finished.append(req)
                del self.active[slot]
        return finished

    def run_to_completion(self, max_steps: int = 512) -> None:
        for _ in range(max_steps):
            if not self.active:
                break
            self.step()


def _splice_slot(full: jax.Array, one: jax.Array, slot: int) -> jax.Array:
    """Write the batch=1 cache leaf `one` into row `slot` of the batched
    leaf `full` (the batch axis is wherever their shapes differ)."""
    for ax in range(one.ndim):
        if full.shape[ax] != one.shape[ax]:
            idx = [slice(None)] * one.ndim
            idx[ax] = slice(slot, slot + 1)
            return full.at[tuple(idx)].set(one.astype(full.dtype))
    # identical shapes: single-slot engine — the whole leaf is this slot's
    return one.astype(full.dtype)
