"""Batched serving engine: slot-based continuous batching.

The engine owns a fixed number of decode *slots* (the serving batch) and a
single batched cache whose ``t`` vector tracks a per-slot decode position —
sequences at different lengths decode together in one ``decode_step`` call.
New requests are prefilled (batch=1) into a free slot by splicing that
slot's rows of every cache leaf; finished sequences (EOS / max-tokens) free
their slot immediately, keeping the decode batch dense.

Two serving-cost refinements live here:

* **Execution plans** — when constructed with a plan-capable
  :class:`~repro.kernels.ops.ScheduleProvider`, the engine pre-resolves its
  kernel set into an :class:`~repro.core.resolution.ExecutionPlan`
  (:func:`plan_serving`) and checks the resolution pipeline's generation
  *between* decode steps: when background tuning publishes an upgrade, the
  engine re-plans and re-traces at the step boundary — never mid-step — so
  schedules published to a live registry reach a running server without a
  restart.  ``plan_history`` records the (step, generation) transition
  points; ``replans`` counts swaps.
* **Prefill buckets** — prompts are padded (right, causal-safe) to
  power-of-two length buckets so the prefill trace count is O(log max_len)
  instead of one per distinct prompt length.  The model is told the true
  length (``true_len``) so logits and cache positions are exact.  Bucketing
  is enabled only where padding is provably inert: attention-only stacks
  (a recurrent scan would fold pad steps into its state) and pad lengths
  that fit the smallest KV cache (a ring/SWA cache would wrap pad rows over
  real ones); everything else falls back to exact-length prefill.

This is the TPU-idiomatic shape of continuous batching for fixed-size
caches; ring buffers (windowed layers) and recurrent states come from the
model substrate unchanged.

**Retirement path**: :class:`~repro.serving.paged.PagedServingEngine`
supersedes this engine for LM serving — iteration-level admission, a paged
KV pool, and chunked (padding-free) prefill remove the two structural
costs measured here (power-of-two prefill padding waste and prefill
head-of-line blocking; see ``benchmarks/bench_paged.py``).  The slot engine
remains the baseline the paged bench compares against, the reference
semantics for the equivalence tests, and the fallback for families the
paged path does not cover (audio encoder-decoder, vision-prefixed
prompts).  New serving features should land in the paged engine; this
engine is frozen apart from bug fixes.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.resolution import ExecutionPlan, plan_serving
from repro.models.build import Model
from repro.obs import NULL_TRACER


class SlotsFull(RuntimeError):
    """Raised by :meth:`ServingEngine.add_request` when every decode slot is
    occupied — the engine-level backpressure signal (routers queue or shed on
    it instead of probing for a ``None`` return)."""


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int
    eos_id: int | None = None
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # speculative decode (paged engine only; the slot engine ignores both):
    speculative: bool = False
    request_class: str = ""


class ServingEngine:
    def __init__(self, model: Model, params: Any, *, slots: int, max_len: int,
                 extras: dict | None = None, provider=None,
                 plan: ExecutionPlan | None = None,
                 prefill_buckets: bool = True):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.extras = {k: jnp.asarray(v) for k, v in (extras or {}).items()}
        self.cache = model.init_cache(slots, max_len)
        self.active: dict[int, Request] = {}
        self.last_logits = None   # (slots, vocab) from the latest decode step
        self._uid = 0

        cfg = model.cfg
        kinds = set(cfg.layer_kinds)
        self.prefill_buckets = (prefill_buckets and cfg.family != "audio"
                                and "R" not in kinds)
        # Largest pad length that cannot corrupt a cache: the ring (windowed)
        # caches hold min(window, max_len) positions and wrap beyond that.
        self._bucket_cap = (max_len if (cfg.window == 0 or "L" not in kinds)
                            else min(cfg.window, max_len))
        self._prefill_lengths: set[int] = set()  # distinct padded lengths traced
        # padding-waste ledger: true prompt tokens vs padded tokens computed
        # (the paged engine's chunked prefill holds these equal)
        self.prefill_true_tokens = 0
        self.prefill_padded_tokens = 0

        # Observability: the owner (fleet / launch driver) rebinds these
        # after construction; the no-op default keeps the hot path at one
        # attribute check.  trace_compute gates wall-clock spans around the
        # jitted calls — fleets disable it (their tracer runs on the virtual
        # clock, where a jitted call is zero-width).
        self.tracer = NULL_TRACER
        self.trace_track = "engine"
        self.trace_compute = True

        # Execution plan: pre-resolve the decode batch + prefill buckets.
        self.provider = provider
        self.plan = plan
        self.replans = 0
        # (step, plan generation) at each plan *transition* (first step and
        # every swap) — bounded by the number of re-plans, not the number of
        # decode steps, so a long-lived server never accumulates history.
        self.plan_history: list[tuple[int, int]] = []
        self._steps = 0
        if provider is not None and getattr(provider, "pipeline", None) is not None:
            if self.plan is None:
                self.plan = plan_serving(
                    cfg, provider.pipeline, slots=slots, max_len=max_len,
                    prefill_lengths=self._bucket_lengths())
            provider.plan = self.plan
        self._make_fns()

    # -- tracing --------------------------------------------------------------
    def _make_fns(self) -> None:
        """(Re)build the jitted entry points.

        Called at init and after every re-plan: schedules are resolved at
        trace time, so a plan swap must drop stale traces to take effect.
        """
        model, provider, max_len = self.model, self.provider, self.max_len

        def prefill_fn(params, batch, true_len):
            return model.prefill(params, batch, max_len=max_len,
                                 true_len=true_len, provider=provider)

        def decode_fn(params, cache, toks):
            return model.decode_step(params, cache, toks, provider=provider)

        self._prefill = jax.jit(prefill_fn)
        self._decode = jax.jit(decode_fn)

    # -- prefill buckets -------------------------------------------------------
    def _pad_len(self, n: int) -> int:
        """Power-of-two bucket for a prompt of n tokens (n itself when
        bucketing is off or the bucket would overflow the smallest cache)."""
        if not self.prefill_buckets or n >= self._bucket_cap:
            return n
        b = 1
        while b < n:
            b *= 2
        return min(b, self._bucket_cap)

    def _bucket_lengths(self) -> list[int]:
        """Every pad length prefill can be traced at (for plan coverage)."""
        if not self.prefill_buckets:
            return []
        out, b = [], 1
        while b < self._bucket_cap:
            out.append(b)
            b *= 2
        out.append(self._bucket_cap)
        return out

    @property
    def prefill_trace_count(self) -> int:
        """Distinct prefill shapes traced so far (bounded by the buckets)."""
        return len(self._prefill_lengths)

    def bucket_for(self, prompt_len: int) -> int:
        """The prefill bucket a prompt of this length pads to (routers and
        demand trackers key on it)."""
        return self._pad_len(prompt_len)

    # -- admission accessors ---------------------------------------------------
    @property
    def free_slots(self) -> int:
        """Decode slots currently available for admission."""
        return self.slots - len(self.active)

    def utilization(self) -> float:
        """Fraction of decode slots occupied (0.0 idle .. 1.0 full)."""
        return len(self.active) / self.slots

    # -- capacity gauges (comparable with the paged engine's) ------------------
    def kv_used_tokens(self) -> int:
        """Cache positions actually holding tokens across active slots."""
        return sum(len(r.prompt) + len(r.generated) - 1
                   for r in self.active.values())

    def kv_capacity_tokens(self) -> int:
        """Every slot reserves max_len rows whether used or not — the
        stranded-capacity denominator."""
        return self.slots * self.max_len

    # -- request admission ---------------------------------------------------
    def add_request(self, prompt: list[int], max_new_tokens: int = 16,
                    eos_id: int | None = None) -> Request:
        """Admit a request into a free slot.

        Raises :class:`SlotsFull` when the batch is full and ``ValueError``
        for a prompt the cache cannot hold.  A request the prefill already
        finishes — ``max_new_tokens <= 0``, or the prefill token is EOS — is
        returned ``done`` without ever occupying a slot.
        """
        n = len(prompt)
        if n > self.max_len:
            raise ValueError(
                f"prompt length {n} exceeds max_len {self.max_len}")
        free = [s for s in range(self.slots) if s not in self.active]
        if not free:
            raise SlotsFull(f"all {self.slots} decode slots are occupied")
        slot = free[0]
        self._uid += 1
        req = Request(self._uid, list(prompt), max_new_tokens, eos_id)
        pad = self._pad_len(n)
        self._prefill_lengths.add(pad)
        self.prefill_true_tokens += n
        self.prefill_padded_tokens += pad
        toks = req.prompt + [0] * (pad - n)
        batch = {"tokens": jnp.asarray([toks], jnp.int32)}
        for k, v in self.extras.items():
            batch[k] = v[None] if v.ndim == 2 else v  # (1, ..., D) stub inputs
        if self.tracer.enabled and self.trace_compute:
            with self.tracer.span("prefill", self.trace_track,
                                  uid=req.uid, true_len=n, bucket=pad):
                logits, cache1 = self._prefill(self.params, batch,
                                               jnp.asarray(n, jnp.int32))
        else:
            logits, cache1 = self._prefill(self.params, batch,
                                           jnp.asarray(n, jnp.int32))
        # np.asarray forces the single host transfer here; int(jnp.argmax(...))
        # would add a second device sync for the scalar read.
        tok = int(np.asarray(jnp.argmax(logits[0])))
        req.generated.append(tok)
        if max_new_tokens <= 0 or (eos_id is not None and tok == eos_id) or \
                len(req.generated) >= max_new_tokens:
            # The prefill token is the whole response: the slot stays free
            # (its cache rows are overwritten by the next admission).
            req.done = True
            return req
        self.cache = jax.tree_util.tree_map(
            lambda full, one: _splice_slot(full, one, slot), self.cache, cache1
        )
        self.active[slot] = req
        return req

    # -- decode ----------------------------------------------------------------
    def _maybe_replan(self) -> None:
        """Swap in a fresh plan when background tuning moved the generation.

        Only ever called at a step boundary: a plan (and its traces) is
        immutable for the duration of one decode step.
        """
        if self.plan is None or self.provider is None:
            return
        if self.provider.pipeline.generation() == self.plan.generation:
            return
        self.plan = self.plan.refresh(self.provider.pipeline)
        self.provider.plan = self.plan
        self.replans += 1
        self._make_fns()
        if self.tracer.enabled:
            self.tracer.event("replan", self.trace_track,
                              generation=self.plan.generation,
                              replans=self.replans)

    def refresh_plan(self) -> bool:
        """Adopt any newer published schedule generation *now* — the same
        boundary check :meth:`step` performs, without decoding a token.
        Returns True when the plan was swapped."""
        before = self.replans
        self._maybe_replan()
        return self.replans != before

    def step(self) -> list[Request]:
        """One batched decode step for all active slots; returns finished."""
        self._maybe_replan()
        if not self.active:
            return []
        self._steps += 1
        if self.plan is not None and (
                not self.plan_history
                or self.plan_history[-1][1] != self.plan.generation):
            self.plan_history.append((self._steps, self.plan.generation))
        toks = np.zeros(self.slots, np.int32)
        for slot, req in self.active.items():
            toks[slot] = req.generated[-1]
        if self.tracer.enabled and self.trace_compute:
            with self.tracer.span("decode_step", self.trace_track,
                                  active=len(self.active)):
                logits, self.cache = self._decode(self.params, self.cache,
                                                  jnp.asarray(toks))
        else:
            logits, self.cache = self._decode(self.params, self.cache,
                                              jnp.asarray(toks))
        self.last_logits = logits
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        finished = []
        for slot, req in list(self.active.items()):
            tok = int(nxt[slot])
            req.generated.append(tok)
            if (req.eos_id is not None and tok == req.eos_id) or \
                    len(req.generated) >= req.max_new_tokens:
                req.done = True
                finished.append(req)
                del self.active[slot]
        return finished

    def run_to_completion(self, max_steps: int = 512) -> None:
        for _ in range(max_steps):
            if not self.active:
                break
            self.step()


def _splice_slot(full: jax.Array, one: jax.Array, slot: int) -> jax.Array:
    """Write the batch=1 cache leaf `one` into row `slot` of the batched
    leaf `full` (the batch axis is wherever their shapes differ)."""
    for ax in range(one.ndim):
        if full.shape[ax] != one.shape[ax]:
            idx = [slice(None)] * one.ndim
            idx[ax] = slice(slot, slot + 1)
            return full.at[tuple(idx)].set(one.astype(full.dtype))
    # identical shapes: single-slot engine — the whole leaf is this slot's
    return one.astype(full.dtype)
