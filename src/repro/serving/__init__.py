from repro.serving.engine import Request, ServingEngine, SlotsFull
from repro.serving.paged import PagedServingEngine
from repro.serving.pages import PagesExhausted, PageTable
from repro.serving.speculative import (
    expected_committed_tokens,
    make_self_draft,
    spec_exact_reason,
    spec_gain,
)

__all__ = ["PagedServingEngine", "PageTable", "PagesExhausted", "Request",
           "ServingEngine", "SlotsFull", "expected_committed_tokens",
           "make_self_draft", "spec_exact_reason", "spec_gain"]
