from repro.serving.engine import Request, ServingEngine, SlotsFull
from repro.serving.paged import PagedServingEngine
from repro.serving.pages import PagesExhausted, PageTable

__all__ = ["PagedServingEngine", "PageTable", "PagesExhausted", "Request",
           "ServingEngine", "SlotsFull"]
