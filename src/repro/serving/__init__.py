from repro.serving.engine import Request, ServingEngine, SlotsFull

__all__ = ["Request", "ServingEngine", "SlotsFull"]
