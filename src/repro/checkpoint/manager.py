"""Fault-tolerant checkpointing: sharded leaf files + manifest, async save,
atomic commit, retention, and reshard-on-restore (elastic scaling).

Layout:  <dir>/step_000123/
            manifest.json       {step, leaves: [{path, shape, dtype, file}]}
            <leaf-000>.npy ...
A checkpoint directory is written under a ``.tmp`` name and atomically
renamed on completion, so a preemption mid-save never corrupts the latest
checkpoint.  ``restore`` accepts an optional sharding tree: arrays are
device_put with the *new* shardings — restoring a 512-chip checkpoint onto
a 256-chip (or 8-host-device test) mesh is the same code path.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Callable

import jax
import ml_dtypes
import numpy as np


def _np_dtype(name: str) -> np.dtype:
    """Resolve a dtype name, including ml_dtypes extensions (bfloat16...)."""
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))


def _leaf_paths(tree: Any) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = True) -> None:
        """Snapshot to host then write. blocking=False writes in background
        (async checkpointing): training resumes immediately after snapshot."""
        self.wait()
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)), tree)
        if blocking:
            self._write(step, host_tree)
        else:
            self._thread = threading.Thread(target=self._write_guard, args=(step, host_tree))
            self._thread.start()

    def _write_guard(self, step: int, host_tree: Any) -> None:
        try:
            self._write(step, host_tree)
        except BaseException as e:  # surfaced on next wait()/save()
            self._error = e

    def _write(self, step: int, host_tree: Any) -> None:
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves = []
        for i, (path, leaf) in enumerate(_leaf_paths(host_tree)):
            fname = f"leaf_{i:05d}.npy"
            # raw-byte payload: custom dtypes (bfloat16 etc.) round-trip
            # without pickling; true shape/dtype live in the manifest.
            np.save(os.path.join(tmp, fname),
                    np.frombuffer(np.ascontiguousarray(leaf).tobytes(), np.uint8),
                    allow_pickle=False)
            leaves.append({"path": path, "file": fname,
                           "shape": list(leaf.shape), "dtype": str(leaf.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "leaves": leaves}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)

    # -- restore ----------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: int | None = None,
                shardings: Any = None) -> tuple[int, Any]:
        """Restore into the structure of `template`.

        ``shardings``: optional pytree (same structure) of jax.sharding
        objects — leaves are device_put with them (reshard-on-restore)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_path = {l["path"]: l for l in manifest["leaves"]}

        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        shard_flat = (jax.tree_util.tree_flatten(shardings)[0] if shardings is not None
                      else [None] * len(flat))
        leaves = []
        for (kp, tmpl), shard in zip(flat, shard_flat):
            path = jax.tree_util.keystr(kp)
            if path not in by_path:
                raise KeyError(f"checkpoint missing leaf {path}")
            entry = by_path[path]
            raw = np.load(os.path.join(d, entry["file"]))
            arr = np.frombuffer(raw.tobytes(), _np_dtype(entry["dtype"])) \
                .reshape(entry["shape"])
            if tuple(arr.shape) != tuple(tmpl.shape):
                raise ValueError(f"shape mismatch for {path}: ckpt {arr.shape} vs {tmpl.shape}")
            if shard is not None:
                leaves.append(jax.device_put(arr.astype(tmpl.dtype), shard))
            else:
                leaves.append(jax.numpy.asarray(arr, dtype=tmpl.dtype))
        return manifest["step"], treedef.unflatten(leaves)
