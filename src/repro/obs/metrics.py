"""Fleet-wide metrics registry: named counters, gauges, and histograms.

Before this module every subsystem kept its own ad-hoc numbers —
``FleetMetrics`` lists, ``TuningService._counters`` dicts,
``ResolutionPipeline`` per-tier counts, ``Autoscaler.stats()`` — each with
its own definition and its own export path.  The registry gives every number
one home:

* :class:`Counter` — monotone event count (requests completed, cache hits);
* :class:`Gauge` — a timestamped sample series (queue depth, utilization) —
  samples carry the *virtual* instant they were taken at, so windowed
  consumers (the autoscaler) and whole-run consumers (summaries) read the
  same data;
* :class:`Histogram` — a value distribution with shared :func:`percentile`
  semantics (latencies, job durations).

:class:`MetricsRegistry` is the get-or-create namespace over all three.
A process-wide default (:func:`default_registry`) exists for drivers that
want one export path; components default to a private registry so parallel
fleets/tests never cross-contaminate.  :class:`CounterGroup` is the
dict-compatibility facade legacy ``stats()`` dicts migrate through: it reads
and writes registry counters but supports ``group["name"] += 1`` and
``dict(group)`` unchanged.
"""
from __future__ import annotations

import threading

import numpy as np


def percentile(xs: "list[float]", q: float) -> float:
    """q-th percentile (0..100, linear interpolation); 0.0 when empty.

    The one shared definition — fleet metrics, benchmarks, and trace
    reports all quote percentiles through this function, so a p95 printed
    by any of them is comparable with any other.  Edge cases are pinned
    (SLO burn-rate math and ledger ratios divide by these): an empty
    series is 0.0 for every q, and a single sample is that sample for
    every q — returned directly, bypassing numpy, so the value round-trips
    bit-exactly rather than through interpolation arithmetic.
    """
    if len(xs) == 0:
        return 0.0
    if len(xs) == 1:
        return float(xs[0])
    return float(np.percentile(xs, q))


class Counter:
    """Monotone event count.  ``+=`` works through :class:`CounterGroup`."""

    kind = "counter"

    def __init__(self, name: str):
        self.name = name
        self.value: float = 0

    def inc(self, n: float = 1) -> None:
        self.value += n

    def set(self, v: float) -> None:
        self.value = v

    def to_json(self):
        return self.value


class Gauge:
    """Timestamped sample series: ``sample(value, t)`` appends ``(t, value)``.

    ``t`` is required — a gauge sample without its instant cannot be
    windowed, and silently defaulting it misfiles the sample into the first
    window (the bug this type exists to prevent).
    """

    kind = "gauge"

    def __init__(self, name: str):
        self.name = name
        self.samples: list[tuple[float, float]] = []

    def sample(self, value: float, t: float) -> None:
        if t is None:
            raise TypeError(f"gauge {self.name!r}: sample timestamp required")
        self.samples.append((float(t), value))

    @property
    def value(self) -> float:
        """Latest sampled value (0.0 when never sampled)."""
        return self.samples[-1][1] if self.samples else 0.0

    def values(self, t0: float = float("-inf"),
               t1: float = float("inf")) -> list[float]:
        """Sample values taken in ``[t0, t1)``."""
        return [v for t, v in self.samples if t0 <= t < t1]

    def to_json(self):
        return {"last": self.value, "samples": len(self.samples)}


class Histogram:
    """Value distribution with :func:`percentile` queries.

    Raw observations are kept (these runs observe thousands of values, not
    millions), so any quantile is exact and :meth:`percentile` agrees with
    every other consumer of the shared definition.
    """

    kind = "histogram"

    def __init__(self, name: str):
        self.name = name
        self.values: list[float] = []
        self.sum = 0.0

    def observe(self, v: float) -> None:
        self.values.append(float(v))
        self.sum += v

    @property
    def count(self) -> int:
        return len(self.values)

    @property
    def mean(self) -> float:
        return self.sum / len(self.values) if self.values else 0.0

    def percentile(self, q: float) -> float:
        return percentile(self.values, q)

    def to_json(self):
        return {"count": self.count, "sum": self.sum, "mean": self.mean,
                "p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99)}


class MetricsRegistry:
    """Get-or-create namespace of named metrics with one export path."""

    _TYPES = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: dict[str, object] = {}

    def _get(self, name: str, kind: str):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = self._TYPES[kind](name)
            elif m.kind != kind:
                raise TypeError(
                    f"metric {name!r} is a {m.kind}, requested {kind}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, "counter")

    def gauge(self, name: str) -> Gauge:
        return self._get(name, "gauge")

    def histogram(self, name: str) -> Histogram:
        return self._get(name, "histogram")

    def group(self, prefix: str, names: "list[str]") -> "CounterGroup":
        return CounterGroup(self, prefix, names)

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._metrics)

    def get(self, name: str):
        with self._lock:
            return self._metrics.get(name)

    def to_json(self) -> dict:
        """``name -> value`` for every metric (the ``--metrics-out`` shape)."""
        with self._lock:
            items = sorted(self._metrics.items())
        return {name: {"kind": m.kind, "value": m.to_json()}
                for name, m in items}


class CounterGroup:
    """Dict-compatible facade over a prefix of registry counters.

    Legacy ``stats()`` dicts migrate through this: ``group["lookups"] += 1``
    and ``dict(group)`` behave exactly like the plain-dict counters they
    replace, but every number is a registry :class:`Counter` — one
    definition, one export path.
    """

    def __init__(self, metrics: MetricsRegistry, prefix: str,
                 names: "list[str]"):
        self.metrics = metrics
        self.prefix = prefix
        self._counters = {n: metrics.counter(f"{prefix}.{n}") for n in names}

    def __getitem__(self, name: str) -> float:
        return self._counters[name].value

    def __setitem__(self, name: str, value: float) -> None:
        self._counters[name].set(value)

    def __contains__(self, name: str) -> bool:
        return name in self._counters

    def __iter__(self):
        return iter(self._counters)

    def keys(self):
        return self._counters.keys()

    def items(self):
        return ((n, c.value) for n, c in self._counters.items())

    def inc(self, name: str, n: float = 1) -> None:
        self._counters[name].inc(n)


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-wide registry (drivers wanting a single export path)."""
    return _DEFAULT
