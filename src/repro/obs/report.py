"""Offline trace analysis: request breakdowns, tier shares, tuning jobs.

Consumes the flat record form produced by :func:`repro.obs.export
.load_records` (either saved format) and answers the questions the paper
cares about — where does a request's latency go, which resolution tier
served the fleet over time, and what did each tuning job cost.  The
``launch/trace_report.py`` CLI is a thin formatter over these functions;
tests run them on a golden fixture.
"""
from __future__ import annotations

from .metrics import percentile


def request_table(records: "list[dict]") -> list[dict]:
    """Per-request lifecycle rows from the ``cat="request"`` async spans.

    Each served request contributes a ``request`` span (arrival→finish)
    with ``queue``/``prefill``/``decode`` phase spans under the same id;
    shed requests appear with ``shed`` set and no phases.
    """
    by_uid: dict[str, dict] = {}
    for r in records:
        if r["kind"] != "span" or r.get("cat") != "request":
            continue
        row = by_uid.setdefault(r["id"], {"uid": r["id"]})
        if r["name"] == "request":
            row.update(r["attrs"])  # span timestamps are authoritative
            row.update(arrival_s=r["t0"], finished_s=r["t1"],
                       latency_s=r["t1"] - r["t0"])
        else:
            row[f"{r['name']}_s"] = r["t1"] - r["t0"]
    for r in records:
        if r["kind"] == "event" and r["name"] == "shed":
            uid = str(r["attrs"].get("uid"))
            row = by_uid.setdefault(uid, {"uid": uid})
            row.update(shed=r["attrs"].get("reason"), shed_at_s=r["t"])
    out = list(by_uid.values())
    out.sort(key=lambda r: r.get("arrival_s", r.get("shed_at_s", 0.0)))
    return out


def latency_breakdown(records: "list[dict]") -> dict:
    """Fleet-level latency quantiles per phase (queue / TTFT / decode).

    TTFT here is time-to-first-token measured from arrival: queue wait
    plus prefill.  ``latency_s`` percentiles over the same arrival→finish
    intervals ``FleetMetrics`` records, via the same :func:`percentile`,
    so the two agree exactly.
    """
    rows = [r for r in request_table(records) if "finished_s" in r]
    shed = [r for r in request_table(records) if r.get("shed")]
    series = {
        "latency_s": [r["latency_s"] for r in rows],
        "queue_s": [r.get("queue_s", 0.0) for r in rows],
        "ttft_s": [r.get("queue_s", 0.0) + r.get("prefill_s", 0.0)
                   for r in rows],
        "decode_s": [r.get("decode_s", 0.0) for r in rows],
    }
    out = {"requests": len(rows), "shed": len(shed)}
    for name, xs in series.items():
        out[name] = {"mean": sum(xs) / len(xs) if xs else 0.0,
                     "p50": percentile(xs, 50), "p95": percentile(xs, 95),
                     "p99": percentile(xs, 99)}
    return out


def tier_shares(records: "list[dict]", windows: int = 8) -> list[dict]:
    """Resolution-tier mix over time, from the ``lookup`` events.

    Splits the trace's lookup activity into ``windows`` equal time slices
    and reports each tier's share per slice — the "exact share climbs as
    background tuning publishes" curve, extracted from any saved trace.
    """
    hits = [(r["t"], r["attrs"].get("tier", "?")) for r in records
            if r["kind"] == "event" and r["name"] == "lookup"]
    if not hits:
        return []
    t0 = min(t for t, _ in hits)
    t1 = max(t for t, _ in hits)
    width = (t1 - t0) / windows or 1.0
    out = []
    for w in range(windows):
        lo = t0 + w * width
        hi = t0 + (w + 1) * width
        sel = [tier for t, tier in hits
               if lo <= t < hi or (w == windows - 1 and t == t1)]
        counts: dict[str, int] = {}
        for tier in sel:
            counts[tier] = counts.get(tier, 0) + 1
        n = len(sel)
        out.append({"t0": lo, "t1": hi, "lookups": n,
                    "shares": {tier: c / n for tier, c in
                               sorted(counts.items())} if n else {}})
    return out


def tuning_jobs(records: "list[dict]") -> list[dict]:
    """Per-job rows from the ``cat="tune"`` async spans (claim→publish)."""
    out = []
    for r in records:
        if r["kind"] == "span" and r.get("cat") == "tune":
            out.append({"key": r["attrs"].get("key", r["id"]),
                        "t0": r["t0"], "duration_s": r["t1"] - r["t0"],
                        **{k: v for k, v in r["attrs"].items()
                           if k != "key"}})
    out.sort(key=lambda r: r["t0"])
    return out


def scale_timeline(records: "list[dict]") -> list[dict]:
    """Autoscaler decisions and replica lifecycle transitions, in order."""
    out = [{"t": r["t"], "name": r["name"], **r["attrs"]}
           for r in records if r["kind"] == "event"
           and r["track"] == "autoscaler"]
    out.sort(key=lambda r: r["t"])
    return out


def acceptance_timeline(records: "list[dict]", windows: int = 8) -> list[dict]:
    """Speculative acceptance rate over time, from ``spec_burst`` events.

    Splits the trace's burst activity into ``windows`` equal time slices;
    each row reports the slice's acceptance rate (accepted / proposed draft
    tokens), committed-token total, and per-class acceptance — the panel
    that shows a class's draftability drifting and the auto router reacting.
    """
    bursts = [(r["t"], r["attrs"]) for r in records
              if r["kind"] == "event" and r["name"] == "spec_burst"]
    if not bursts:
        return []
    t0 = min(t for t, _ in bursts)
    t1 = max(t for t, _ in bursts)
    width = (t1 - t0) / windows or 1.0
    out = []
    for w in range(windows):
        lo = t0 + w * width
        hi = t0 + (w + 1) * width
        sel = [a for t, a in bursts
               if lo <= t < hi or (w == windows - 1 and t == t1)]
        prop = sum(a.get("proposed", 0) for a in sel)
        acc = sum(a.get("accepted", 0) for a in sel)
        by_cls: dict[str, list[int]] = {}
        for a in sel:
            pa = by_cls.setdefault(str(a.get("request_class", "")), [0, 0])
            pa[0] += a.get("proposed", 0)
            pa[1] += a.get("accepted", 0)
        out.append({
            "t0": lo, "t1": hi, "bursts": len(sel),
            "proposed": prop, "accepted": acc,
            "committed": sum(a.get("committed", 0) for a in sel),
            "acceptance": acc / prop if prop else 0.0,
            "by_class": {cls: (pa[1] / pa[0] if pa[0] else 0.0)
                         for cls, pa in sorted(by_cls.items())}})
    return out


def slo_timeline(records: "list[dict]") -> list[dict]:
    """SLO alert transitions from the ``slo`` track, in order.

    Each row is an ``slo_alert`` or ``slo_clear`` event with the window's
    fast/slow burn rates — the audit trail of when each objective's error
    budget started and stopped burning.
    """
    out = [{"t": r["t"], "name": r["name"], **r["attrs"]}
           for r in records if r["kind"] == "event"
           and r["track"] == "slo"
           and r["name"] in ("slo_alert", "slo_clear")]
    out.sort(key=lambda r: (r["t"], r.get("slo", "")))
    return out


def ledger_timeline(records: "list[dict]") -> list[dict]:
    """Speedup-ledger snapshots (``ledger`` events) over time.

    The realized-vs-attainable speedup curve: each row shows how much of
    the registry's best-known speedup the fleet was actually serving at
    that instant — the live form of the paper's headline metric.
    """
    out = [{"t": r["t"], **r["attrs"]}
           for r in records if r["kind"] == "event"
           and r["name"] == "ledger"]
    out.sort(key=lambda r: r["t"])
    return out


def summarize(records: "list[dict]", windows: int = 8) -> dict:
    """Everything the CLI prints, as one JSON-ready object."""
    # Imported lazily: profiler builds on request_table above, so a
    # module-level import would be circular.
    from . import profiler
    return {"latency": latency_breakdown(records),
            "tier_shares": tier_shares(records, windows),
            "tuning_jobs": tuning_jobs(records),
            "scale_timeline": scale_timeline(records),
            "acceptance": acceptance_timeline(records, windows),
            "slo": slo_timeline(records),
            "speedup_ledger": ledger_timeline(records),
            "critical_path": profiler.critical_path(records)}
