"""Virtual-clock tracer: nested spans and point events on named tracks.

The fleet runs on a discrete-event *virtual* clock (step durations are
cost-model kernel seconds), so spans are stamped with whatever clock the
owner binds via :meth:`Tracer.set_clock` — the fleet binds its ``_now``;
standalone engines fall back to wall clock for real jitted steps.  Time is
seconds in both cases; the exporter scales to microseconds.

Tracks are the horizontal lanes of the timeline: one per replica
(``replica-0`` …), plus ``router``, ``autoscaler``, ``tuning/<target>``,
and ``resolution``.  Three record shapes cover everything the fleet does:

* **sync span** (:meth:`add_span` / :meth:`span`) — a ``[t0, t1)`` interval
  that nests properly within its track (an engine step and the chunk/decode
  work inside it);
* **async span** (:meth:`add_async_span`) — an interval that *overlaps*
  others on its track, keyed by ``(cat, id)`` (concurrent request
  lifetimes on one replica, tuning jobs in the shared pool);
* **event** (:meth:`event`) — a zero-width instant (a shed, a publish,
  a scale decision).

Every record carries structured ``attrs`` (workload key, target, tier,
generation, replica id, scale reason, …) — the exporters pass them through
untouched so offline analysis never has to parse span names.

Instrumented code holds a tracer reference unconditionally and gates on
``tracer.enabled`` — the disabled default (:data:`NULL_TRACER`) makes the
hot path pay exactly one attribute check.
"""
from __future__ import annotations

import threading
import time as _time
from dataclasses import dataclass, field


@dataclass
class Span:
    """A recorded interval on a track.  ``parent`` indexes ``Tracer.spans``."""

    name: str
    track: str
    t0: float
    t1: float
    attrs: dict = field(default_factory=dict)
    parent: int | None = None
    # Async spans overlap on their track and are matched by (cat, id);
    # sync spans leave both None and must nest.
    cat: str | None = None
    id: str | None = None


@dataclass
class Event:
    """A recorded instant on a track."""

    name: str
    track: str
    t: float
    attrs: dict = field(default_factory=dict)


class Tracer:
    """Collects :class:`Span`/:class:`Event` records on a bound clock.

    Thread-safe: the tuning pool's worker threads record tune-job spans
    concurrently with the serve loop.
    """

    enabled = True

    def __init__(self, clock=None):
        self._clock = clock if clock is not None else _time.perf_counter
        self._lock = threading.Lock()
        self._tracks: dict[str, int] = {}
        self.spans: list[Span] = []
        self.events: list[Event] = []
        self._stack = threading.local()

    # -- clock ----------------------------------------------------------
    def set_clock(self, clock) -> None:
        """Bind the time source (fleet virtual clock, or wall clock)."""
        self._clock = clock

    def now(self) -> float:
        return float(self._clock())

    # -- tracks ---------------------------------------------------------
    def track(self, name: str) -> str:
        """Register ``name`` (idempotent); registration order fixes the
        exported track order."""
        with self._lock:
            self._tracks.setdefault(name, len(self._tracks))
        return name

    def tracks(self) -> list[str]:
        with self._lock:
            return sorted(self._tracks, key=self._tracks.__getitem__)

    # -- recording ------------------------------------------------------
    def add_span(self, name: str, track: str, t0: float, t1: float,
                 parent: int | None = None, **attrs) -> int:
        """Record a completed sync span; returns its index (a valid
        ``parent`` for children)."""
        if t1 < t0:
            raise ValueError(f"span {name!r}: t1 {t1} < t0 {t0}")
        s = Span(name, self.track(track), float(t0), float(t1), attrs, parent)
        with self._lock:
            self.spans.append(s)
            return len(self.spans) - 1

    def add_async_span(self, name: str, track: str, t0: float, t1: float,
                       cat: str, id: str, **attrs) -> int:
        """Record a completed async span — may overlap others on its track."""
        if t1 < t0:
            raise ValueError(f"span {name!r}: t1 {t1} < t0 {t0}")
        s = Span(name, self.track(track), float(t0), float(t1), attrs,
                 None, cat, str(id))
        with self._lock:
            self.spans.append(s)
            return len(self.spans) - 1

    def event(self, name: str, track: str, t: float | None = None,
              **attrs) -> None:
        e = Event(name, self.track(track), self.now() if t is None else
                  float(t), attrs)
        with self._lock:
            self.events.append(e)

    def span(self, name: str, track: str, **attrs):
        """Context manager timing a live region on the bound clock; nested
        uses (same thread) record parent links automatically."""
        return _LiveSpan(self, name, track, attrs)

    def counts(self) -> dict:
        with self._lock:
            return {"spans": len(self.spans), "events": len(self.events)}


class _LiveSpan:
    def __init__(self, tracer: Tracer, name: str, track: str, attrs: dict):
        self.tracer = tracer
        self.name = name
        self.track = track
        self.attrs = attrs
        self.index: int | None = None

    def __enter__(self):
        self._t0 = self.tracer.now()
        stack = getattr(self.tracer._stack, "open", None)
        if stack is None:
            stack = self.tracer._stack.open = []
        self._parent = stack[-1] if stack else None
        # Reserve the record now so children born inside the region can
        # point at it; t1 is patched on exit.
        self.index = self.tracer.add_span(self.name, self.track, self._t0,
                                          self._t0, self._parent,
                                          **self.attrs)
        stack.append(self.index)
        return self

    def __exit__(self, *exc):
        self.tracer._stack.open.pop()
        with self.tracer._lock:
            self.tracer.spans[self.index].t1 = self.tracer.now()
        return False


class NullTracer(Tracer):
    """Disabled tracer: every recording call is a no-op.

    Instrumentation sites check ``tracer.enabled`` before building attrs,
    so with this default the instrumented hot path costs one attribute
    read per site.
    """

    enabled = False

    def __init__(self):
        super().__init__(clock=lambda: 0.0)

    def add_span(self, *a, **k) -> int:  # noqa: D102
        return -1

    def add_async_span(self, *a, **k) -> int:  # noqa: D102
        return -1

    def event(self, *a, **k) -> None:  # noqa: D102
        pass

    def span(self, name, track, **attrs):  # noqa: D102
        return _NULL_LIVE

    def track(self, name: str) -> str:  # noqa: D102
        return name


class _NullLive:
    index = -1

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_LIVE = _NullLive()

NULL_TRACER = NullTracer()
