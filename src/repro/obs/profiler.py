"""Critical-path profiler: request latency -> segments -> kernel workloads.

Two consumers, one attribution model:

* **Offline** (:func:`critical_path`, :func:`request_breakdown`) — walk a
  folded trace (:func:`repro.obs.export.load_records`): each request's
  ``cat="request"`` async spans slice its arrival→finish latency into
  queue / prefill / decode segments, and the replica tracks' sync cell
  spans (slot ``prefill`` / ``decode_step``; paged step children ``chunk``
  / ``decode`` / ``verify`` / ``draft_burst`` / ``draft_sync``) carry the
  busy time each cell spent.  ``cell_workloads`` events — emitted by each
  replica once per (cell, plan generation) — map a cell to its kernel
  workloads with per-execution seconds under that plan, so cell busy time
  attributes down to individual workload keys.  The per-request latency
  totals are the *same floats* ``FleetMetrics`` aggregated (the async spans
  carry its exact intervals, and the exporters round-trip seconds
  losslessly), so :func:`critical_path`'s p50/p95 reproduce
  ``FleetMetrics.summary()`` exactly — pinned by ``bench_slo``.

* **Live** (:func:`live_workload_seconds`) — the same per-workload
  critical-path seconds computed directly from the replicas' cell
  execution counters and plan-derived costs, without a tracer.  This is
  the signal the :class:`~repro.fleet.advisor.TuningAdvisor` multiplies by
  remaining speedup headroom to rank tuning work; with tracing enabled the
  two paths agree because the spans are laid out from the very same costs.
"""
from __future__ import annotations

from .metrics import percentile
from .report import request_table

#: Sync span names that are cell executions (everything else on a replica
#: track — e.g. the paged ``step`` parent — is a container, not a cell).
_CELL_SPANS = ("prefill", "decode_step", "chunk", "decode", "verify",
               "draft_burst", "draft_sync")


def span_cell(rec: dict) -> tuple[str, float] | None:
    """Map one sync span record to ``(cell id, executions)``.

    Cell ids match the replicas' counters: ``prefill:<bucket>`` (slot
    prefill and paged chunk both — a chunk *is* the paged prefill cell for
    that length), ``decode``, ``verify``, ``draft_decode``,
    ``draft_sync:<len>``.  Returns None for non-cell spans.
    """
    name = rec["name"]
    if rec.get("cat") is not None or name not in _CELL_SPANS:
        return None
    attrs = rec.get("attrs", {})
    if name == "prefill":
        return f"prefill:{attrs.get('bucket')}", 1.0
    if name == "chunk":
        return f"prefill:{attrs.get('len')}", 1.0
    if name in ("decode_step", "decode"):
        return "decode", 1.0
    if name == "verify":
        return "verify", 1.0
    if name == "draft_burst":
        return "draft_decode", float(attrs.get("steps", 1))
    return f"draft_sync:{attrs.get('len')}", 1.0


def request_breakdown(records: "list[dict]") -> list[dict]:
    """Per-request segment rows for every *finished* request.

    Each row carries the request's ``latency_s`` (the request span's
    ``t1 - t0`` — bit-identical to ``FleetRequest.latency_s``) and its
    ``queue_s`` / ``prefill_s`` / ``decode_s`` segments, which partition
    the latency by construction (the phase spans share endpoints).
    """
    return [r for r in request_table(records) if "finished_s" in r]


def critical_path(records: "list[dict]") -> dict:
    """Fleet-wide critical-path breakdown of a folded trace.

    Returns::

        {"requests", "latency_s": {p50, p95, p99},   # == FleetMetrics'
         "segments": {queue, prefill, decode},       # summed request-seconds
         "by_cell": {cell: {"seconds", "executions"}},
         "by_workload": {workload_key: seconds},     # via cell_workloads
         "attributed_frac"}                          # covered cell seconds

    ``segments`` answers "where do requests wait"; ``by_cell`` /
    ``by_workload`` answer "which compute is that time spent in" — the
    quantity tuning priority should follow.
    """
    rows = request_breakdown(records)
    lats = [r["latency_s"] for r in rows]
    segments = {"queue": 0.0, "prefill": 0.0, "decode": 0.0}
    for r in rows:
        for seg in segments:
            segments[seg] += r.get(f"{seg}_s", 0.0)

    # cell_workloads events: (track, cell) -> [(t, [[key, s], ...])], sorted.
    maps: dict[tuple, list] = {}
    for r in records:
        if r["kind"] == "event" and r["name"] == "cell_workloads":
            a = r["attrs"]
            maps.setdefault((r["track"], a.get("cell")), []).append(
                (r["t"], a.get("workloads", [])))
    for v in maps.values():
        v.sort(key=lambda p: p[0])

    by_cell: dict[str, dict] = {}
    by_workload: dict[str, float] = {}
    attributed = total_cell_s = 0.0
    for r in records:
        if r["kind"] != "span":
            continue
        cell = span_cell(r)
        if cell is None:
            continue
        cell_id, execs = cell
        dur = r["t1"] - r["t0"]
        c = by_cell.setdefault(cell_id, {"seconds": 0.0, "executions": 0.0})
        c["seconds"] += dur
        c["executions"] += execs
        total_cell_s += dur
        # The mapping active when the span ran: latest event at or before
        # its start (plans only change at step boundaries, so the emission
        # preceding a span is the generation that priced it).
        series = maps.get((r["track"], cell_id))
        if not series:
            continue
        active = series[0][1]
        for t, wl in series:
            if t > r["t0"] + 1e-12:
                break
            active = wl
        for key, sec in active:
            by_workload[key] = by_workload.get(key, 0.0) + execs * sec
        attributed += dur
    return {
        "requests": len(rows),
        "latency_s": {"p50": percentile(lats, 50),
                      "p95": percentile(lats, 95),
                      "p99": percentile(lats, 99)},
        "segments": segments,
        "by_cell": dict(sorted(by_cell.items())),
        "by_workload": dict(sorted(by_workload.items(),
                                   key=lambda kv: -kv[1])),
        "attributed_frac": attributed / total_cell_s if total_cell_s else 0.0,
    }


def live_workload_seconds(replicas) -> dict:
    """Per-workload critical-path seconds from live replica state.

    ``{(workload_key, target): {"seconds", "instance"}}`` — each replica's
    cell execution counters times the cell's per-execution workload seconds
    under its *current* plan.  No tracer required: this is the advisor's
    input on a production fleet where tracing may be off.
    """
    out: dict = {}
    for r in replicas:
        for cell, n in getattr(r, "cell_counts", {}).items():
            for use, sec in r.cell_workload_seconds(cell):
                k = (use.instance.workload_key(), r.target)
                row = out.get(k)
                if row is None:
                    row = out[k] = {"seconds": 0.0, "instance": use.instance}
                row["seconds"] += n * sec
    return out
