"""Trace exporters: Chrome trace-event JSON (Perfetto) and JSON-lines.

The Chrome format (``chrome_trace`` / ``write_chrome_trace``) is the
interactive path — load the file in https://ui.perfetto.dev or
``chrome://tracing``.  Tracks map to threads of one process: each track
becomes a ``tid`` (named via ``M``/``thread_name`` metadata) in tracer
registration order, sync spans become complete ``X`` events, async spans
become ``b``/``e`` pairs keyed by ``(cat, id)`` so overlapping request
lifetimes render as parallel slices, and point events become instants
(``i``).  Timestamps are microseconds (the virtual clock's seconds x 1e6).

The JSONL format (``write_jsonl`` / ``read_jsonl``) is the offline path —
one self-describing record per line (``{"kind": "span"|"event", ...}``
with seconds-unit times and verbatim attrs), which is what
``launch/trace_report.py`` and the golden-fixture tests consume.
``load_records`` reads either file shape back into that record form.
"""
from __future__ import annotations

import json

from .tracer import Event, Span, Tracer

_US = 1e6  # seconds -> Chrome trace microseconds


def _records(tracer: Tracer) -> list[dict]:
    out = []
    for s in tracer.spans:
        out.append({"kind": "span", "name": s.name, "track": s.track,
                    "t0": s.t0, "t1": s.t1, "cat": s.cat, "id": s.id,
                    "attrs": s.attrs})
    for e in tracer.events:
        out.append({"kind": "event", "name": e.name, "track": e.track,
                    "t": e.t, "attrs": e.attrs})
    return out


def chrome_trace(tracer: Tracer) -> dict:
    """Tracer contents as a Chrome trace-event object (Perfetto-loadable)."""
    tids = {name: i + 1 for i, name in enumerate(tracer.tracks())}
    ev: list[dict] = []
    ev.append({"ph": "M", "pid": 1, "tid": 0, "name": "process_name",
               "args": {"name": "repro-fleet"}})
    for name, tid in tids.items():
        ev.append({"ph": "M", "pid": 1, "tid": tid, "name": "thread_name",
                   "args": {"name": name}})
        # sort_index pins the display order to track registration order.
        ev.append({"ph": "M", "pid": 1, "tid": tid,
                   "name": "thread_sort_index", "args": {"sort_index": tid}})
    # Alongside the standard microsecond ``ts``/``dur``, every record
    # carries exact-seconds sidecar keys (``ts_s``, and ``t1_s`` for
    # complete events).  Perfetto ignores unknown keys; ``load_records``
    # prefers them so a Chrome round-trip folds back to the *same floats*
    # the JSONL path preserves — the profiler's exact-percentile guarantee
    # rides on this (seconds x 1e6 / 1e6 is lossy in float64).
    for s in tracer.spans:
        tid = tids.get(s.track, 0)
        if s.cat is not None:
            common = {"pid": 1, "tid": tid, "name": s.name, "cat": s.cat,
                      "id": s.id}
            ev.append({"ph": "b", "ts": s.t0 * _US, "ts_s": s.t0,
                       "args": s.attrs, **common})
            ev.append({"ph": "e", "ts": s.t1 * _US, "ts_s": s.t1, **common})
        else:
            ev.append({"ph": "X", "pid": 1, "tid": tid, "name": s.name,
                       "ts": s.t0 * _US, "dur": (s.t1 - s.t0) * _US,
                       "ts_s": s.t0, "t1_s": s.t1, "args": s.attrs})
    for e in tracer.events:
        ev.append({"ph": "i", "pid": 1, "tid": tids.get(e.track, 0),
                   "name": e.name, "ts": e.t * _US, "ts_s": e.t, "s": "t",
                   "args": e.attrs})
    # Stable sort: metadata (no ts) first, then by timestamp, preserving
    # record order at equal instants so nesting survives zero-width steps.
    ev.sort(key=lambda r: r.get("ts", -1.0))
    return {"traceEvents": ev, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, tracer: Tracer) -> None:
    with open(path, "w") as f:
        json.dump(chrome_trace(tracer), f)


def write_jsonl(path: str, tracer: Tracer) -> None:
    with open(path, "w") as f:
        for rec in _records(tracer):
            f.write(json.dumps(rec) + "\n")


def read_jsonl(path: str) -> list[dict]:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def load_records(path: str) -> list[dict]:
    """Read a saved trace (either format) back as flat JSONL-shape records.

    Chrome files are folded back: ``X`` -> span, ``b``/``e`` pairs matched
    by ``(cat, id, name)`` -> async span, ``i`` -> event, metadata dropped.
    Files written by :func:`chrome_trace` carry exact-seconds sidecar keys
    (``ts_s``/``t1_s``) which are preferred over dividing the microsecond
    ``ts`` back down, so both formats fold to identical records; foreign
    Chrome traces without the sidecars still load (lossily) fine.
    """
    with open(path) as f:
        text = f.read()
    try:
        doc = json.loads(text)
    except json.JSONDecodeError:
        # many one-object lines -> "Extra data": the JSONL shape
        return read_jsonl(path)
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return read_jsonl(path)  # including a single-record JSONL file
    tracks: dict[int, str] = {}
    for r in doc.get("traceEvents", []):
        if r.get("ph") == "M" and r.get("name") == "thread_name":
            tracks[r["tid"]] = r["args"]["name"]
    out: list[dict] = []
    open_async: dict[tuple, dict] = {}
    for r in doc.get("traceEvents", []):
        ph = r.get("ph")
        track = tracks.get(r.get("tid"), "")
        if ph == "X":
            t0 = r.get("ts_s", r["ts"] / _US)
            t1 = r.get("t1_s", t0 + r.get("dur", 0.0) / _US)
            out.append({"kind": "span", "name": r["name"], "track": track,
                        "t0": t0, "t1": t1, "cat": None, "id": None,
                        "attrs": r.get("args", {})})
        elif ph == "b":
            key = (r.get("cat"), r.get("id"), r["name"])
            t0 = r.get("ts_s", r["ts"] / _US)
            open_async[key] = {"kind": "span", "name": r["name"],
                               "track": track, "t0": t0,
                               "t1": t0, "cat": r.get("cat"),
                               "id": r.get("id"),
                               "attrs": r.get("args", {})}
            out.append(open_async[key])
        elif ph == "e":
            key = (r.get("cat"), r.get("id"), r["name"])
            rec = open_async.pop(key, None)
            if rec is not None:
                rec["t1"] = r.get("ts_s", r["ts"] / _US)
        elif ph == "i":
            out.append({"kind": "event", "name": r["name"], "track": track,
                        "t": r.get("ts_s", r["ts"] / _US),
                        "attrs": r.get("args", {})})
    return out
