"""Declarative SLOs with multi-window burn-rate alerting.

PR 8 made the fleet observable; this module makes the observations
*actionable*.  An :class:`SLO` declares a compliance objective over the
served traffic — "95% of requests finish within 40 ticks", "at most 2% of
requests are shed" — and an :class:`SLOMonitor` evaluates every declared
objective at a fixed cadence over the same :class:`~repro.fleet.metrics.\
FleetMetrics` windows the autoscaler consumes.

Alerting follows the SRE multi-window burn-rate recipe: the error *budget*
of an objective is ``1 - objective`` (the fraction of requests allowed to be
bad), and the *burn rate* of a window is ``bad_fraction / budget`` — burn 1.0
means the budget is being spent exactly as fast as it accrues.  An alert
fires only when **both** a fast window (recent, catches regressions quickly)
and a slow window (longer, rejects one-sample blips) burn above
``burn_alert``; it clears when either stops burning.  Every state transition
is recorded as a trace event on the ``slo`` track, and the current burn
rates / alert state are sampled into registry gauges
(``slo.<name>.burn_fast`` / ``.burn_slow`` / ``.alerting``), so both the
live autoscaler and offline ``trace_report`` read the same signal.

The monitor is deliberately pull-based and windowed — it re-derives
good/bad counts from the request outcomes inside each window rather than
keeping its own counters — so replaying a trace through
:class:`~repro.fleet.ServingFleet` reproduces the alert timeline exactly.
"""
from __future__ import annotations

import dataclasses

from .metrics import MetricsRegistry
from .tracer import NULL_TRACER

#: SLO kinds and the request outcome that counts against the budget.
KINDS = ("latency", "ttft", "shed", "deadline")


@dataclasses.dataclass(frozen=True)
class SLO:
    """One declarative service-level objective.

    ``objective`` is the compliance target (0..1): the fraction of seen
    requests that must be *good*.  What "good" means depends on ``kind``:

    * ``latency`` — finished, with arrival→finish latency <= ``threshold_s``;
    * ``ttft`` — finished, with arrival→first-token time <= ``threshold_s``;
    * ``shed`` — not shed (``threshold_s`` unused);
    * ``deadline`` — finished before its deadline (requests without a
      deadline count good; ``threshold_s`` unused).

    Shed requests count *bad* for every kind — a request the fleet dropped
    never met any latency objective.  ``fast_windows`` / ``slow_windows``
    size the two burn-rate windows in multiples of the monitor's base
    window; ``burn_alert`` is the burn-rate threshold both must exceed.
    """

    name: str
    kind: str
    objective: float = 0.95
    threshold_s: float | None = None
    fast_windows: int = 1
    slow_windows: int = 4
    burn_alert: float = 1.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown SLO kind {self.kind!r}: one of {KINDS}")
        if not 0.0 < self.objective < 1.0:
            raise ValueError("objective must be in (0, 1)")
        if self.kind in ("latency", "ttft") and self.threshold_s is None:
            raise ValueError(f"SLO kind {self.kind!r} needs threshold_s")
        if self.fast_windows < 1 or self.slow_windows < self.fast_windows:
            raise ValueError("need 1 <= fast_windows <= slow_windows")

    @property
    def budget(self) -> float:
        """Error budget: the allowed bad fraction."""
        return 1.0 - self.objective

    def is_bad(self, req) -> bool:
        """Whether one seen request spends error budget."""
        if req.shed:
            return True
        if self.kind == "latency":
            return (req.latency_s or 0.0) > self.threshold_s
        if self.kind == "ttft":
            first = (req.prefill_done_s if req.prefill_done_s is not None
                     else req.finished_s)
            return first is not None and \
                first - req.arrival_s > self.threshold_s
        if self.kind == "deadline":
            return (req.deadline_s is not None
                    and req.finished_s is not None
                    and req.finished_s > req.deadline_s)
        return False  # kind == "shed": completions are good by definition


@dataclasses.dataclass(frozen=True)
class SLOStatus:
    """One monitor evaluation of one SLO."""

    t: float
    name: str
    burn_fast: float
    burn_slow: float
    seen_fast: int        # requests inside the fast window (0 -> no signal)
    alerting: bool
    changed: bool         # did this evaluation flip the alert state?


class SLOMonitor:
    """Evaluates a set of :class:`SLO` objectives over fleet windows.

    ``fleet_metrics`` supplies the request outcomes (its ``completed`` /
    ``shed`` lists, binned by finish / shed instant — the same binning
    :meth:`~repro.fleet.metrics.FleetMetrics.window` uses); ``window_s`` is
    the base evaluation cadence.  :meth:`evaluate` is called at window
    boundaries by the fleet's serve loop (or by hand over a finished run)
    and returns one :class:`SLOStatus` per objective, recording alert
    transitions as ``slo_alert`` / ``slo_clear`` trace events and sampling
    the burn gauges.
    """

    TRACK = "slo"

    def __init__(self, slos, fleet_metrics, *, window_s: float,
                 metrics: MetricsRegistry | None = None, tracer=None):
        if window_s <= 0:
            raise ValueError("window_s must be positive")
        names = [s.name for s in slos]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate SLO names: {names}")
        self.slos = list(slos)
        self.fleet_metrics = fleet_metrics
        self.window_s = window_s
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._alerting: dict[str, bool] = {s.name: False for s in self.slos}
        #: Evaluation log: one ``{"t", "slos": {name: status}}`` per call.
        self.history: list[dict] = []
        self._gauges = {
            s.name: (self.metrics.gauge(f"slo.{s.name}.burn_fast"),
                     self.metrics.gauge(f"slo.{s.name}.burn_slow"),
                     self.metrics.gauge(f"slo.{s.name}.alerting"))
            for s in self.slos}
        self._alerts_c = self.metrics.counter("slo.alerts")
        self._clears_c = self.metrics.counter("slo.clears")

    # -- window math -----------------------------------------------------------
    def _seen(self, t0: float, t1: float) -> list:
        """Requests whose outcome landed in ``[t0, t1)`` — completions by
        finish instant, sheds by shed instant (FleetMetrics' binning)."""
        fm = self.fleet_metrics
        done = [r for r in fm.completed
                if r.finished_s is not None and t0 <= r.finished_s < t1]
        shed = [r for r in fm.shed
                if r.shed_s is not None and t0 <= r.shed_s < t1]
        return done + shed

    def burn_rate(self, slo: SLO, t0: float, t1: float) -> tuple[float, int]:
        """(burn rate, requests seen) of ``slo`` over ``[t0, t1)``.

        An empty window burns 0 — no traffic spends no budget, so a quiet
        fleet never alerts.
        """
        seen = self._seen(t0, t1)
        if not seen:
            return 0.0, 0
        bad = sum(1 for r in seen if slo.is_bad(r))
        return (bad / len(seen)) / slo.budget, len(seen)

    # -- evaluation ------------------------------------------------------------
    def evaluate(self, now: float) -> list[SLOStatus]:
        """Evaluate every SLO at instant ``now`` (a window boundary)."""
        out = []
        row: dict = {"t": now, "slos": {}}
        for slo in self.slos:
            fast, seen_fast = self.burn_rate(
                slo, now - slo.fast_windows * self.window_s, now)
            slow, _ = self.burn_rate(
                slo, now - slo.slow_windows * self.window_s, now)
            alerting = fast >= slo.burn_alert and slow >= slo.burn_alert
            changed = alerting != self._alerting[slo.name]
            self._alerting[slo.name] = alerting
            gf, gs, ga = self._gauges[slo.name]
            gf.sample(fast, now)
            gs.sample(slow, now)
            ga.sample(1.0 if alerting else 0.0, now)
            if changed:
                (self._alerts_c if alerting else self._clears_c).inc()
                if self.tracer.enabled:
                    self.tracer.event(
                        "slo_alert" if alerting else "slo_clear", self.TRACK,
                        t=now, slo=slo.name, kind=slo.kind,
                        objective=slo.objective, burn_fast=fast,
                        burn_slow=slow)
            st = SLOStatus(now, slo.name, fast, slow, seen_fast, alerting,
                           changed)
            out.append(st)
            row["slos"][slo.name] = {
                "burn_fast": fast, "burn_slow": slow, "alerting": alerting}
        self.history.append(row)
        return out

    def alerting(self) -> list[str]:
        """Names of SLOs currently in the alerting state."""
        return [n for n, a in self._alerting.items() if a]

    def last_alert_end(self, name: str | None = None) -> float:
        """Latest evaluation instant at which any (or the named) SLO was
        still alerting — 0.0 when it never alerted.  The "time to reach SLO
        compliance" a benchmark reads off a finished run: after this
        instant the monitor never alerted again."""
        t = 0.0
        for row in self.history:
            for n, st in row["slos"].items():
                if st["alerting"] and (name is None or n == name):
                    t = max(t, row["t"])
        return t

    def summary(self) -> dict:
        """Per-SLO rollup for the fleet summary."""
        out = {}
        for slo in self.slos:
            evals = [r["slos"][slo.name] for r in self.history]
            n_alerting = sum(1 for e in evals if e["alerting"])
            out[slo.name] = {
                "kind": slo.kind,
                "objective": slo.objective,
                "threshold_s": slo.threshold_s,
                "evaluations": len(evals),
                "alerting_windows": n_alerting,
                "alert_share": n_alerting / len(evals) if evals else 0.0,
                "alerting_now": self._alerting[slo.name],
                "last_alert_end_s": self.last_alert_end(slo.name),
            }
        return out


def default_slos(tick_s: float) -> list[SLO]:
    """A reasonable default SLO set, sized in ticks (one untuned decode
    step) so it transfers across archs — what ``serve_fleet --slo default``
    installs."""
    return [
        SLO("p95_latency", "latency", objective=0.95,
            threshold_s=40.0 * tick_s),
        SLO("ttft", "ttft", objective=0.90, threshold_s=20.0 * tick_s),
        SLO("shed", "shed", objective=0.98),
        SLO("deadline", "deadline", objective=0.95),
    ]
