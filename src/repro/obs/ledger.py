"""Speedup ledger: realized vs attainable speedup, per workload, live.

The paper's headline metric is the fraction of the auto-scheduler's maximum
speedup that transfer-tuning realizes.  Offline, ``transfer_arch`` computes
it once per run; this ledger computes it *continuously* over a serving
fleet.  For every (workload, target) pair the fleet actually executes it
tracks three per-execution costs under the shared cost model:

* ``untuned_s`` — the default schedule (the denominator of every speedup);
* ``served_s``  — what the replicas' *current* plans actually charge,
  tagged with the resolution tier and donor that produced it;
* ``best_s``    — the best published registry record re-priced under the
  serving mode (None while the workload has no exact-tier record).

Weighted by observed critical-path executions (the replicas' cell counters
times each kernel's use count), the aggregates answer the closed-loop
question directly::

    realized_speedup   = sum(w * untuned) / sum(w * served)
    attainable_speedup = sum(w * untuned) / sum(w * best-or-served)
    realized_fraction  = sum(w * best-or-served) / sum(w * served)

``realized_fraction`` is the paper's metric: 1.0 means every served kernel
already runs its best known schedule — a fully-drained fleet must land
exactly there, and ``bench_slo`` gates that the ledger's numbers for a
drained fleet match an offline :func:`~repro.core.transfer.transfer_tune`
run against the same donor registry.  All costs are the cost model's
*virtual* seconds — the same seconds the virtual clock charges and the
tuner optimizes, so ledger speedups and serving latency move together by
construction (DESIGN.md §12 discusses why).
"""
from __future__ import annotations

import dataclasses

from .metrics import MetricsRegistry
from .tracer import NULL_TRACER


@dataclasses.dataclass
class LedgerEntry:
    """One (workload, target) row of the ledger."""

    key: str
    target: str
    class_id: str
    tier: str                  # resolution tier currently serving it
    source_model: str          # donor provenance of the served schedule
    untuned_s: float           # per single kernel execution
    served_s: float
    best_s: float | None       # None -> no exact-tier record published yet
    weight: float = 0.0        # observed executions x use_count

    @property
    def realized_speedup(self) -> float:
        return self.untuned_s / self.served_s if self.served_s else 1.0

    @property
    def attainable_speedup(self) -> float:
        best = self.best_s if self.best_s is not None else self.served_s
        return self.untuned_s / best if best else 1.0

    @property
    def headroom_s(self) -> float:
        """Per-execution seconds still on the table vs the best record."""
        best = self.best_s if self.best_s is not None else self.served_s
        return max(0.0, self.served_s - best)


class SpeedupLedger:
    """Tracks realized vs attainable speedup per (workload, target).

    :meth:`update` rebuilds the ledger from the live replicas — every cell
    the fleet has executed (plus the decode cell every request exercises),
    priced under the replicas' current plans and the registry's current
    best records — then samples the aggregate gauges
    (``ledger.realized_speedup`` / ``.attainable_speedup`` /
    ``.realized_fraction`` / ``.workloads`` / ``.tuned_workloads``) and,
    when tracing, emits one ``ledger`` event on the ``ledger`` track.  The
    fleet calls it on the same cadence as its tuning-drain bursts, so the
    gauges move the instant a publish lands.
    """

    TRACK = "ledger"

    def __init__(self, *, metrics: MetricsRegistry | None = None,
                 tracer=None):
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.entries: dict[tuple[str, str], LedgerEntry] = {}
        self._gauges = {
            name: self.metrics.gauge(f"ledger.{name}")
            for name in ("realized_speedup", "attainable_speedup",
                         "realized_fraction", "workloads", "tuned_workloads")}

    # -- building --------------------------------------------------------------
    def update(self, replicas, now: float = 0.0) -> dict:
        """Rebuild from live replica state; returns :meth:`aggregates`."""
        entries: dict[tuple[str, str], LedgerEntry] = {}
        snaps: dict = {}
        for r in replicas:
            svc = r.service
            db = None
            if svc is not None:
                db = snaps.get(r.target)
                if db is None:
                    db = snaps[r.target] = svc.registry.snapshot().db(None)
            cells = set(getattr(r, "cell_counts", ())) | {"decode"}
            for cell in cells:
                execs = r.cell_counts.get(cell, 0)
                for u in r.cell_uses(cell):
                    key = (u.instance.workload_key(), r.target)
                    e = entries.get(key)
                    if e is None:
                        res = r.use_resolution(u.instance)
                        served = r.use_seconds(u.instance, res.schedule)
                        untuned = r.use_seconds(u.instance, None)
                        best_rec = (db.exact(u.instance, target=r.target)
                                    if db is not None else None)
                        best = (r.use_seconds(u.instance, best_rec.schedule)
                                if best_rec is not None else None)
                        e = entries[key] = LedgerEntry(
                            key=key[0], target=r.target,
                            class_id=u.instance.class_id, tier=res.tier,
                            source_model=res.source_model, untuned_s=untuned,
                            served_s=served, best_s=best)
                    e.weight += execs * u.use_count
        self.entries = entries
        agg = self.aggregates()
        for name, g in self._gauges.items():
            g.sample(float(agg[name]), now)
        if self.tracer.enabled:
            self.tracer.event("ledger", self.TRACK, t=now, **agg)
        return agg

    # -- aggregates ------------------------------------------------------------
    def aggregates(self) -> dict:
        """Fleet-wide weighted rollup (weights fall back to 1 per workload
        before any traffic has executed)."""
        rows = list(self.entries.values())
        total_w = sum(e.weight for e in rows)
        w_of = (lambda e: e.weight) if total_w > 0 else (lambda e: 1.0)
        un = sum(w_of(e) * e.untuned_s for e in rows)
        sv = sum(w_of(e) * e.served_s for e in rows)
        bt = sum(w_of(e) * (e.best_s if e.best_s is not None else e.served_s)
                 for e in rows)
        tiers: dict[str, int] = {}
        for e in rows:
            tiers[e.tier] = tiers.get(e.tier, 0) + 1
        return {
            "workloads": len(rows),
            "tuned_workloads": sum(1 for e in rows if e.best_s is not None),
            "realized_speedup": un / sv if sv else 1.0,
            "attainable_speedup": un / bt if bt else 1.0,
            "realized_fraction": bt / sv if sv else 1.0,
            "headroom_s": sum(w_of(e) * e.headroom_s for e in rows),
            "tiers": tiers,
        }

    def speedup_for(self, uses, target: str) -> dict:
        """Ledger-side speedup over an explicit workload set, weighted by
        ``use_count`` — the exact aggregation :func:`~repro.core.transfer.\
transfer_tune` reports, so a drained fleet's number is directly comparable
        to the offline ``TransferResult.speedup`` for the same uses and
        registry (``bench_slo`` gate c)."""
        un = sv = bt = 0.0
        missing = []
        for u in uses:
            e = self.entries.get((u.instance.workload_key(), target))
            if e is None:
                missing.append(u.instance.workload_key())
                continue
            w = u.use_count
            un += w * e.untuned_s
            sv += w * e.served_s
            bt += w * (e.best_s if e.best_s is not None else e.served_s)
        return {
            "untuned_s": un, "served_s": sv, "best_s": bt,
            "realized_speedup": un / sv if sv else 1.0,
            "attainable_speedup": un / bt if bt else 1.0,
            "realized_fraction": bt / sv if sv else 1.0,
            "missing": missing,
        }

    def top_headroom(self, n: int = 5) -> list[LedgerEntry]:
        """Entries with the most weighted seconds left on the table."""
        return sorted(self.entries.values(),
                      key=lambda e: -e.weight * e.headroom_s)[:n]

    def summary(self) -> dict:
        out = self.aggregates()
        out["top_headroom"] = [
            {"key": e.key, "target": e.target, "tier": e.tier,
             "weight": e.weight, "headroom_s": e.weight * e.headroom_s}
            for e in self.top_headroom()]
        return out
