"""Unified observability: virtual-clock tracing, metrics, trace export.

See DESIGN.md §10.  Producers record through a :class:`Tracer` (default
:data:`NULL_TRACER`, a no-op costing one attribute check) and a
:class:`MetricsRegistry`; consumers export Chrome trace-event JSON for
Perfetto or JSON-lines for ``launch/trace_report.py``.
"""
from .metrics import (
    Counter,
    CounterGroup,
    Gauge,
    Histogram,
    MetricsRegistry,
    default_registry,
    percentile,
)
from .tracer import NULL_TRACER, Event, NullTracer, Span, Tracer
from .export import (
    chrome_trace,
    load_records,
    read_jsonl,
    write_chrome_trace,
    write_jsonl,
)
from .slo import KINDS, SLO, SLOMonitor, SLOStatus, default_slos
from .ledger import LedgerEntry, SpeedupLedger
from . import report
from . import profiler

__all__ = [
    "Counter", "CounterGroup", "Gauge", "Histogram", "MetricsRegistry",
    "default_registry", "percentile",
    "NULL_TRACER", "Event", "NullTracer", "Span", "Tracer",
    "chrome_trace", "load_records", "read_jsonl", "write_chrome_trace",
    "write_jsonl", "report", "profiler",
    "KINDS", "SLO", "SLOMonitor", "SLOStatus", "default_slos",
    "LedgerEntry", "SpeedupLedger",
]
