"""AdamW with f32 master weights, global-norm clipping, and LR schedules.

Production conventions: params may be stored bf16; the optimizer keeps f32
first/second moments and an f32 master copy, casting back to the param dtype
after each update (mixed-precision training).  All state is a pytree with
the same structure as params, so the distributed sharding rules apply to it
leaf-for-leaf (ZeRO-style: optimizer state inherits the param sharding).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to min_lr_ratio·peak."""
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init_state(params: Any) -> dict:
    f32 = lambda x: jnp.zeros(x.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(f32, params),
        "v": jax.tree_util.tree_map(f32, params),
        # copy=True: an f32 param would otherwise alias its master buffer,
        # and donating params+opt_state together would donate it twice.
        "master": jax.tree_util.tree_map(
            lambda x: jnp.array(x, dtype=jnp.float32, copy=True), params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def apply_updates(params: Any, grads: Any, state: dict, cfg: AdamWConfig
                  ) -> tuple[Any, dict, dict]:
    """One AdamW step. Returns (params, state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, master, p):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps)
        master_new = master - lr * (update + cfg.weight_decay * master)
        return m_new, v_new, master_new, master_new.astype(p.dtype)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_w = treedef.flatten_up_to(state["master"])
    out = [upd(g, m, v, w, p) for g, m, v, w, p in zip(flat_g, flat_m, flat_v, flat_w, flat_p)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_w = treedef.unflatten([o[2] for o in out])
    new_p = treedef.unflatten([o[3] for o in out])
    new_state = {"m": new_m, "v": new_v, "master": new_w, "step": step}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
