from repro.optim.adamw import AdamWConfig, apply_updates, global_norm, init_state, lr_at
from repro.optim.compression import (
    compress_with_feedback,
    compressed_gradients,
    compressed_psum,
    dequantize,
    init_residuals,
    quantize,
)

__all__ = [
    "AdamWConfig",
    "apply_updates",
    "compress_with_feedback",
    "compressed_gradients",
    "compressed_psum",
    "dequantize",
    "global_norm",
    "init_residuals",
    "init_state",
    "lr_at",
    "quantize",
]
