"""Gradient compression for cross-pod reduction: int8 quantized all-reduce
with error feedback.

At multi-pod scale the pod-axis all-reduce crosses the slowest links; int8
quantization cuts those bytes 2×(bf16)–4×(f32).  Error feedback (Seide et
al.) accumulates the quantization residual locally and re-injects it next
step, preserving convergence.  The quantizer is per-leaf symmetric with a
max-abs scale.

``compressed_psum`` composes with ``shard_map`` collectives; in pure-pjit
training the quantize/dequantize pair is applied around the gradient (XLA
still reduces in int8 domain when the pattern allows; the error-feedback
property holds either way and is what the tests verify).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric int8 quantization. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_with_feedback(grad: jax.Array, residual: jax.Array
                           ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Quantize (grad + residual); return (q, scale, new_residual)."""
    target = grad.astype(jnp.float32) + residual
    q, scale = quantize(target)
    recon = dequantize(q, scale)
    return q, scale, target - recon


def init_residuals(grads: Any) -> Any:
    return jax.tree_util.tree_map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_gradients(grads: Any, residuals: Any) -> tuple[Any, Any]:
    """Apply int8 round-trip with error feedback to every gradient leaf.

    Returns (dequantized grads to feed the reducer/optimizer, new residuals).
    """
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    outs = [compress_with_feedback(g, r) for g, r in zip(flat_g, flat_r)]
    deq = [dequantize(q, s, g.dtype) for (q, s, _), g in zip(outs, flat_g)]
    new_r = [o[2] for o in outs]
    return treedef.unflatten(deq), treedef.unflatten(new_r)


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """Compressed psum for use inside shard_map (cross-pod reductions).

    Each shard quantizes its contribution to int8 before the reduction —
    on the wire a real deployment moves int8 payloads + one f32 scale per
    leaf (the 2–4× collective-bytes saving the roofline counts); the math
    here is the per-shard quantization round-trip, whose error is exactly
    what :func:`compress_with_feedback` accumulates and re-injects.
    """
    q, scale = quantize(x)
    return jax.lax.psum(dequantize(q, scale), axis_name)
