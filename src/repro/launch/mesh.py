"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — jax locks the device count on first init,
and only the dry-run entrypoint forces 512 host devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_devices: int | None = None, model: int = 2):
    """Small mesh over however many (host) devices a test subprocess has."""
    n = n_devices or len(jax.devices())
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"))
