"""Serving driver: batched inference with continuous batching.

Loads a (reduced or full) arch, optionally a transfer-tuned schedule DB,
and runs a stream of requests through the slot-based engine, reporting
throughput and per-request latency.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs.base import get_arch, reduced
from repro.core.database import ScheduleDB
from repro.kernels.ops import ScheduleProvider
from repro.models.build import build_model
from repro.serving import ServingEngine


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description="serve an assigned architecture")
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--preset", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--tuning-db", default="")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.preset == "smoke":
        cfg = reduced(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    if args.tuning_db:
        db = ScheduleDB.load(args.tuning_db)
        ScheduleProvider({r.instance.workload_key(): r.schedule for r in db.records()})

    extras = {}
    if cfg.family == "audio":
        extras["frames"] = np.zeros((cfg.encoder_seq, cfg.d_model), np.float32)
    if cfg.vision_tokens:
        extras["patch_embeds"] = np.zeros((cfg.vision_tokens, cfg.d_model), np.float32)

    engine = ServingEngine(model, params, slots=args.slots, max_len=args.max_len,
                           extras=extras)
    rng = np.random.default_rng(0)
    pending = [list(rng.integers(1, cfg.vocab_size, size=int(rng.integers(3, 9))))
               for _ in range(args.requests)]
    done, t0, steps = [], time.monotonic(), 0
    while pending or engine.active:
        while pending:
            req = engine.add_request([int(t) for t in pending[0]],
                                     max_new_tokens=args.new_tokens)
            if req is None:
                break
            pending.pop(0)
        done.extend(engine.step())
        steps += 1
        if steps > 10_000:
            raise RuntimeError("serving did not converge")
    dt = time.monotonic() - t0
    toks = sum(len(r.generated) for r in done)
    result = {"requests": len(done), "decode_steps": steps,
              "tokens": toks, "tok_per_s": round(toks / dt, 1)}
    print(json.dumps(result))
    return result


if __name__ == "__main__":
    main()
