"""Serving driver: batched inference with continuous batching.

Loads a (reduced or full) arch and runs a stream of requests through the
slot-based engine, reporting throughput and per-request latency.

Schedule resolution is pluggable:

* ``--tuning-db db.json`` — frozen offline store: a ScheduleDB snapshot is
  loaded once and installed as a static provider (the pre-registry path).
* ``--tuning-registry DIR`` — online path: kernels resolve through a
  :class:`~repro.service.TuningService` over a segmented
  :class:`~repro.service.ScheduleRegistry`.  Unseen workloads are served
  untuned *once*, background transfer-tuning jobs publish upgrades, and
  later requests pick them up — the service's ``stats()`` land in the
  result JSON.  ``--tuning-workers 0`` defers jobs (drained at exit);
  the provider only affects the ``pallas`` backend (``--backend``).

Either way resolution runs through the staged
:class:`~repro.core.resolution.ResolutionPipeline` and the engine holds a
pre-resolved :class:`~repro.core.resolution.ExecutionPlan` for its serving
shapes: steady-state kernel calls are plan/cache dict hits, and when a
background job publishes an upgrade the engine re-plans at a decode-step
boundary — the result JSON reports per-tier resolution counts, plan tier
composition, and re-plan count.

``--target`` selects the hardware namespace served (schedules tuned for one
chip never silently serve another); ``--tuning-donor-target`` optionally
draws transfer donors from a different chip's namespace (explicit
cross-target serving, re-validated under ``--target``'s spec).

``--trace-out trace.json`` records wall-clock spans around the real jitted
prefill/decode steps plus resolution/replan events (Perfetto-loadable;
DESIGN.md §10); ``--metrics-out`` dumps the resolution metrics registry.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs.base import get_arch, reduced
from repro.core.database import ScheduleDB
from repro.fleet.traffic import sample_prompts
from repro.kernels.ops import ScheduleProvider, set_default_provider, use_backend
from repro.targets import DEFAULT_TARGET, list_targets
from repro.models.build import build_model
from repro.serving import ServingEngine, SlotsFull


def make_provider(args) -> tuple[ScheduleProvider, object | None]:
    """Build the schedule provider (and the service, when online) from args."""
    service = None
    schedule_map = {}
    if args.tuning_db:
        db = ScheduleDB.load(args.tuning_db)
        # Only this target's namespace: a record tuned for another chip must
        # never serve here, even through the frozen offline path.
        schedule_map = {r.instance.workload_key(): r.schedule
                       for r in db.records() if r.target == args.target}
    if args.tuning_registry:
        from repro.service import ScheduleRegistry, TuningService

        registry = ScheduleRegistry(args.tuning_registry)
        service = TuningService(registry, model_id=f"serve/{args.arch}",
                                max_workers=args.tuning_workers,
                                budget_s=args.tuning_budget_s,
                                target=args.target,
                                donor_target=args.tuning_donor_target)
    return ScheduleProvider(schedule_map, service=service), service


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description="serve an assigned architecture")
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--preset", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--backend", choices=["ref", "pallas"], default="ref")
    ap.add_argument("--target", choices=list_targets(), default=DEFAULT_TARGET,
                    help="hardware target to serve schedules for; the tuning "
                         "service only reads/publishes this chip's namespace")
    ap.add_argument("--tuning-donor-target", choices=list_targets(), default=None,
                    help="draw transfer donors from another chip's namespace "
                         "(cross-target serving; default: --target)")
    ap.add_argument("--tuning-db", default="")
    ap.add_argument("--tuning-registry", default="",
                    help="schedule-registry dir: serve through TuningService")
    ap.add_argument("--tuning-workers", type=int, default=2)
    ap.add_argument("--tuning-budget-s", type=float, default=float("inf"),
                    help="virtual search seconds for background tuning jobs")
    ap.add_argument("--seed", type=int, default=0,
                    help="request-stream seed (shared sampler with the fleet "
                         "traffic generator): runs are reproducible per seed "
                         "but vary across seeds")
    ap.add_argument("--trace-out", default="",
                    help="write a Perfetto-loadable Chrome trace (wall-clock "
                         "spans around the real jitted prefill/decode steps)")
    ap.add_argument("--metrics-out", default="",
                    help="write the engine's resolution metrics as JSON")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.preset == "smoke":
        cfg = reduced(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    provider, service = make_provider(args)
    prev_provider = set_default_provider(provider)

    extras = {}
    if cfg.family == "audio":
        extras["frames"] = np.zeros((cfg.encoder_seq, cfg.d_model), np.float32)
    if cfg.vision_tokens:
        extras["patch_embeds"] = np.zeros((cfg.vision_tokens, cfg.d_model), np.float32)

    # The provider (and hence plan construction, which runs service lookups
    # and enqueues background tuning) is wired in only for the pallas
    # backend: ref-backend ops never consult schedules, and planning for
    # them would spend tuning budget on kernels that never execute.
    engine = ServingEngine(
        model, params, slots=args.slots, max_len=args.max_len, extras=extras,
        provider=provider if args.backend == "pallas" else None)
    tracer = None
    if args.trace_out:
        from repro.obs import Tracer

        # A standalone engine has no virtual clock: spans are wall-clock
        # around the real jitted steps (engine.trace_compute default).
        tracer = Tracer()
        engine.tracer = tracer
        provider.pipeline.tracer = tracer
    rng = np.random.default_rng(args.seed)
    pending = sample_prompts(rng, args.requests, cfg.vocab_size)
    done, t0, steps = [], time.monotonic(), 0
    try:
        with use_backend(args.backend):
            while pending or engine.active:
                while pending and engine.free_slots:
                    try:
                        req = engine.add_request(pending[0],
                                                 max_new_tokens=args.new_tokens)
                    except SlotsFull:
                        break
                    pending.pop(0)
                    if req.done:  # finished by the prefill itself
                        done.append(req)
                done.extend(engine.step())
                steps += 1
                if steps > 10_000:
                    raise RuntimeError("serving did not converge")
    finally:
        set_default_provider(prev_provider)
        if service is not None:
            # Also on error paths: a live worker pool with queued jobs would
            # otherwise keep the process alive after a serving failure.
            service.close()
    dt = time.monotonic() - t0
    toks = sum(len(r.generated) for r in done)
    result = {"requests": len(done), "decode_steps": steps,
              "tokens": toks, "tok_per_s": round(toks / dt, 1),
              "target": args.target,
              "schedule_hits": provider.hits, "schedule_misses": provider.misses,
              "resolution": provider.stats(),
              "replans": engine.replans,
              "prefill_traces": engine.prefill_trace_count}
    if engine.plan is not None:
        result["plan"] = {"entries": len(engine.plan),
                          "generation": engine.plan.generation,
                          "tiers": engine.plan.tier_counts()}
    if service is not None:
        result["tuning_service"] = service.stats()
    if tracer is not None:
        from repro.obs.export import write_chrome_trace

        write_chrome_trace(args.trace_out, tracer)
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(provider.pipeline.metrics.to_json(), f, indent=1,
                      sort_keys=True)
    print(json.dumps(result))
    return result


if __name__ == "__main__":
    main()
