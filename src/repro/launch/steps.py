"""Step builders shared by the trainer, the server, and the dry-run.

``make_train_step``  — loss → grads → (optional int8 grad compression with
error feedback) → AdamW; donates params/opt state; applies the residual-
stream sharding constraint so GSPMD materializes the intended SP layout.
``make_prefill_step`` / ``make_decode_step`` — serving entry points.

Every step works identically on the 1-device CPU runtime (tests, examples)
and under a production mesh (dry-run, real deployment): sharding enters
only through jit's in/out_shardings, provided by the caller.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.build import Model
from repro.optim import adamw, compression


def make_train_step(model: Model, opt_cfg: adamw.AdamWConfig, *,
                    grad_accum: int = 1, compress_grads: bool = False,
                    remat: bool = True) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    grad_accum > 1 splits the batch into microbatches and accumulates grads
    in f32 (sequential scan — constant memory in microbatch count).
    """
    cfg = model.cfg

    def loss(params, batch):
        val, metrics = model.loss_fn(params, batch, remat=remat)
        return val, metrics

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def single(params, batch):
        (val, metrics), grads = grad_fn(params, batch)
        return val, metrics, grads

    def accumulated(params, batch):
        def micro(carry, mb):
            acc, _ = carry
            (val, metrics), grads = grad_fn(params, mb)
            acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32) / grad_accum, acc, grads)
            return (acc, metrics), val

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        micro_batches = jax.tree_util.tree_map(
            lambda x: x.reshape(grad_accum, x.shape[0] // grad_accum, *x.shape[1:]),
            batch)
        (grads, metrics), vals = jax.lax.scan(
            micro, (zeros, {"ce": jnp.zeros((), jnp.float32),
                            "aux": jnp.zeros((), jnp.float32)}), micro_batches)
        return vals.mean(), metrics, grads

    def train_step(params, opt_state, batch):
        if grad_accum > 1:
            val, metrics, grads = accumulated(params, batch)
        else:
            val, metrics, grads = single(params, batch)
        if compress_grads:
            residuals = opt_state.get("residuals")
            grads, residuals = compression.compressed_gradients(grads, residuals)
            opt_inner = {k: v for k, v in opt_state.items() if k != "residuals"}
            params, opt_inner, om = adamw.apply_updates(params, grads, opt_inner, opt_cfg)
            opt_state = {**opt_inner, "residuals": residuals}
        else:
            params, opt_state, om = adamw.apply_updates(params, grads, opt_state, opt_cfg)
        metrics = {**metrics, **om, "loss": val}
        return params, opt_state, metrics

    return train_step


def init_opt_state(params: Any, *, compress_grads: bool = False) -> dict:
    state = adamw.init_state(params)
    if compress_grads:
        state["residuals"] = compression.init_residuals(params)
    return state


def make_prefill_step(model: Model, max_len: int) -> Callable:
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len=max_len)

    return prefill_step


def make_decode_step(model: Model) -> Callable:
    def decode_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    return decode_step
