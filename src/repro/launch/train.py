"""Training driver: config → mesh → sharded train loop with fault tolerance.

Production loop structure:
  * deterministic data pipeline (step number is the data cursor — restarts
    resume the exact stream),
  * jit'd train step with param/optimizer donation,
  * async checkpointing every ``--ckpt-every`` steps (atomic commit),
  * straggler monitor + preemption handler (SIGTERM → checkpoint → exit),
  * optional int8 gradient compression and gradient accumulation,
  * transfer-tuned schedule DB applied to the kernel ops (``--tuning-db``).

Runs identically on this CPU container with ``--preset smoke`` (reduced
config, 1-device mesh) and, via the dry-run, on the production meshes.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import get_arch, reduced
from repro.core.database import ScheduleDB
from repro.data import DataConfig, Pipeline
from repro.distributed import StragglerMonitor, PreemptionHandler
from repro.distributed import sharding as shd
from repro.distributed.context import activation_sharding, set_remat_policy
from repro.kernels.ops import ScheduleProvider
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_test_mesh
from repro.models.build import build_model
from repro.optim.adamw import AdamWConfig


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description="train an assigned architecture")
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--preset", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--tuning-db", default="", help="transfer-tuned ScheduleDB json")
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--strategy", choices=["auto", "dp", "fsdp_tp"], default="auto",
                    help="auto: pure-DP/ZeRO-3 for small models (EXPERIMENTS §Perf it-7)")
    ap.add_argument("--remat-policy", choices=["full", "dots"], default="full")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.preset == "smoke":
        cfg = reduced(cfg)
    model = build_model(cfg)

    provider = None
    if args.tuning_db:
        db = ScheduleDB.load(args.tuning_db)
        provider = ScheduleProvider({r.instance.workload_key(): r.schedule
                                     for r in db.records()})

    mesh = make_test_mesh(model=args.mesh_model) if len(jax.devices()) > 1 else None

    params = model.init(jax.random.PRNGKey(0))
    opt_state = steps_mod.init_opt_state(params, compress_grads=args.compress_grads)
    opt_cfg = AdamWConfig(peak_lr=args.lr, warmup_steps=max(args.steps // 10, 2),
                          total_steps=args.steps)
    step_fn = steps_mod.make_train_step(model, opt_cfg, grad_accum=args.grad_accum,
                                        compress_grads=args.compress_grads)

    if mesh is not None:
        if args.strategy == "dp":
            dp_only = True
        elif args.strategy == "fsdp_tp":
            dp_only = False
        else:
            dp_only = shd.dp_dominant(cfg, mesh, kind="train", global_batch=args.batch)
        p_shard = shd.param_shardings(jax.eval_shape(lambda: params), cfg, mesh, dp_only)
        o_shard = {**shd.opt_state_shardings(p_shard, mesh)}
        if args.compress_grads:
            o_shard["residuals"] = p_shard
        params = jax.device_put(params, p_shard)
        opt_state = jax.device_put(opt_state, o_shard)
        jitted = jax.jit(step_fn, in_shardings=(p_shard, o_shard, None),
                         out_shardings=(p_shard, o_shard, None), donate_argnums=(0, 1))
        act = shd.activation_sharding(mesh, cfg, dp_only)
    else:
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))
        act = None

    start_step = 0
    manager = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if manager and args.resume and manager.latest_step() is not None:
        bundle = {"params": params, "opt": opt_state}
        start_step, restored = manager.restore(bundle)
        params, opt_state = restored["params"], restored["opt"]
        print(f"resumed from step {start_step}")

    data = Pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                               global_batch=args.batch), start_step=start_step)
    monitor = StragglerMonitor()
    preempt = PreemptionHandler(install_signal=False)

    losses = []
    set_remat_policy(args.remat_policy)
    ctx = activation_sharding(act) if act is not None else _null_ctx()
    with ctx:
        for step, np_batch in data:
            if step >= args.steps or preempt.requested:
                break
            t0 = time.monotonic()
            batch = {"tokens": jax.numpy.asarray(np_batch["tokens"])}
            params, opt_state, metrics = jitted(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.monotonic() - t0
            if monitor.record(step, dt):
                print(f"[straggler] step {step} took {dt:.2f}s (ewma {monitor.ewma:.2f}s)")
            losses.append(loss)
            if args.log_every and step % args.log_every == 0:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms", flush=True)
            if manager and args.ckpt_every and step and step % args.ckpt_every == 0:
                manager.save(step, {"params": params, "opt": opt_state}, blocking=False)
    data.close()
    if manager:
        manager.save(len(losses) + start_step, {"params": params, "opt": opt_state})
        manager.wait()
    result = {"first_loss": losses[0] if losses else None,
              "last_loss": losses[-1] if losses else None,
              "steps": len(losses), "stragglers": len(monitor.flagged)}
    print(json.dumps(result))
    return result


class _null_ctx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
