"""Fleet serving driver: a request stream across N engine replicas.

Stands a :class:`~repro.fleet.ServingFleet` — router, admission queue,
demand-driven background tuning — in front of ``--replicas`` engine
replicas, drives a seeded synthetic trace through it, and prints the fleet
summary JSON (throughput, p50/p95/p99 latency, queue depth, shed rate,
per-replica tier composition, cross-replica schedule-mismatch count).

    PYTHONPATH=src python -m repro.launch.serve_fleet \
        --arch minitron-4b --replicas 3 --policy plan_aware --prefetch \
        --arrival-rate 0.8 --queue-cap 16 --requests 24 --seed 7

``--tuning-registry DIR`` shares one schedule registry across every replica
(omitted: a temporary registry, discarded at exit — still exercises the
full background-tuning path, just from a cold, donor-less store).
``--targets`` assigns per-replica hardware targets (comma-separated, cycled
over replicas) for heterogeneous fleets; ``--donor-target`` draws transfer
donors from another chip's namespace.

``--engine paged`` swaps every replica to the paged-KV continuous-batching
engine (``--decode-batch`` lanes over a ``--pool-pages`` x ``--page-size``
KV pool, ``--chunk``-token prefill slices); ``--engine slot`` (default)
keeps the fixed-slot engine.  See DESIGN.md §8.

``--autoscale`` makes the fleet elastic: a hysteresis controller over the
windowed telemetry warm-joins replicas (up to ``--max-replicas``) under
pressure and drain-retires them (down to ``--min-replicas``) when quiet;
``--scale-window`` / ``--cooldown`` are in ticks.  ``--traffic bursty``
(square-wave: ``--burst-rate`` / ``--burst-every`` / ``--burst-len``) and
``--traffic diurnal`` (sinusoid: ``--period`` / ``--amplitude``) produce
the load shapes the controller is built for; ``--save-trace`` records the
generated stream and ``--replay-trace`` replays a recorded one verbatim.
See DESIGN.md §9.

``--speculative all|auto`` turns on draft-then-verify decoding on paged
replicas (DESIGN.md §11): ``--draft-model self:K`` builds a truncated
self-draft from the target's first K layers (``--spec-damp`` scales the
deeper layers' residual contributions down, controlling the acceptance
rate), ``--spec-k`` sets the draft tokens per burst, and ``auto`` decides
spec-vs-plain per request from the measured per-class acceptance rate
(``--class-mix chat=0.7,bulk=0.3`` stamps seeded workload classes on the
generated traffic).

    PYTHONPATH=src python -m repro.launch.serve_fleet \
        --engine paged --speculative auto --draft-model self:1 --spec-k 4 \
        --class-mix chat=0.7,bulk=0.3 --requests 24

``--slo`` attaches burn-rate SLO monitors (bare flag: default objectives —
p95 latency, TTFT, shed rate, deadline hits; or a
``name:kind:objective[:threshold_ticks]`` spec list): each objective's
error-budget burn is evaluated every ``--slo-window`` ticks over fast and
slow windows, alert transitions land in the trace, and active alerts feed
the autoscaler as scale-up pressure.  ``--prefetch advisor`` replaces
demand-count prefetch ordering with the closed-loop ranking
(critical-path seconds x remaining speedup headroom); the summary then
carries ``slo`` and ``speedup_ledger`` blocks (realized vs attainable
speedup — the paper's metric, live).  See DESIGN.md §12.

``--trace-out trace.json`` records every span/event of the run — request
queue→prefill→decode lifecycles per replica track, engine iterations,
tuning jobs, router and autoscaler decisions — as a Chrome trace on the
fleet's virtual clock (open it at https://ui.perfetto.dev, or feed it to
``python -m repro.launch.trace_report``); ``--metrics-out`` dumps the
fleet-wide metrics registry.  See DESIGN.md §10.
"""
from __future__ import annotations

import argparse
import json
import shutil
import tempfile

import jax
import numpy as np

from repro.configs.base import get_arch, reduced
from repro.fleet import (
    POLICIES,
    Autoscaler,
    BurstyTraffic,
    DiurnalTraffic,
    ServingFleet,
    TrafficGenerator,
    load_trace,
    save_trace,
)
from repro.models.build import build_model
from repro.targets import DEFAULT_TARGET, list_targets


def _parse_slos(spec: str, tick_s: float):
    """``--slo`` value -> ``ServingFleet(slos=...)`` argument.

    ``"default"`` passes through; otherwise each comma-separated item is
    ``name:kind:objective[:threshold_ticks]`` (threshold in ticks, scaled
    by the fleet's ``tick_s`` so specs are portable across arch sizes).
    """
    from repro.obs import SLO
    if spec == "default":
        return "default"
    slos = []
    for item in spec.split(","):
        parts = item.strip().split(":")
        if len(parts) not in (3, 4):
            raise ValueError(
                f"bad --slo item {item!r}: name:kind:objective[:ticks]")
        name, kind, objective = parts[0], parts[1], float(parts[2])
        threshold = float(parts[3]) * tick_s if len(parts) == 4 else None
        slos.append(SLO(name=name, kind=kind, objective=objective,
                        threshold_s=threshold))
    return slos


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description="serve a request stream across "
                                             "a fleet of engine replicas")
    ap.add_argument("--arch", default="minitron-4b")
    ap.add_argument("--preset", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--policy", choices=sorted(POLICIES), default="plan_aware")
    ap.add_argument("--arrival-rate", type=float, default=0.5,
                    help="expected requests per tick (one tick = one untuned "
                         "decode step)")
    ap.add_argument("--queue-cap", type=int, default=16,
                    help="admission-queue bound; overflow sheds")
    ap.add_argument("--prefetch", nargs="?", const="hot", default="off",
                    choices=["off", "hot", "advisor"],
                    help="background tuning prefetch: 'hot' (bare flag) "
                         "orders by bucket demand, 'advisor' by "
                         "critical-path seconds x speedup headroom")
    ap.add_argument("--engine", choices=["slot", "paged"], default="slot",
                    help="replica engine: fixed decode slots, or paged-KV "
                         "continuous batching with chunked prefill")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--decode-batch", type=int, default=None,
                    help="paged: decode lanes per replica (default: --slots)")
    ap.add_argument("--page-size", type=int, default=8,
                    help="paged: tokens per KV page")
    ap.add_argument("--pool-pages", type=int, default=None,
                    help="paged: total KV pages per replica (default: every "
                         "lane at full context)")
    ap.add_argument("--chunk", type=int, default=8,
                    help="paged: prefill chunk length (tokens per step)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0,
                    help="traffic seed (same seed -> same trace)")
    ap.add_argument("--deadline-ticks", type=float, default=None,
                    help="shed queued requests older than this many ticks")
    ap.add_argument("--long-frac", type=float, default=0.25,
                    help="fraction of long-prompt requests in the mix")
    ap.add_argument("--targets", default=DEFAULT_TARGET,
                    help="comma-separated per-replica hardware targets "
                         f"(cycled; registered: {','.join(list_targets())})")
    ap.add_argument("--donor-target", choices=list_targets(), default=None,
                    help="draw transfer donors from another chip's namespace")
    ap.add_argument("--tuning-registry", default="",
                    help="shared schedule-registry dir (default: temporary)")
    ap.add_argument("--tuning-budget-s", type=float, default=float("inf"))
    ap.add_argument("--drain-jobs", type=int, default=2,
                    help="background tuning jobs drained per burst")
    ap.add_argument("--defrag-threshold", type=float, default=None,
                    help="paged: defragment a replica's KV pool when its "
                         "fragmentation exceeds this (0, 1) ratio")
    ap.add_argument("--autoscale", action="store_true",
                    help="elastic fleet: warm-join/drain-retire replicas "
                         "between --min-replicas and --max-replicas")
    ap.add_argument("--min-replicas", type=int, default=1)
    ap.add_argument("--max-replicas", type=int, default=4)
    ap.add_argument("--scale-window", type=float, default=4.0,
                    help="autoscaler telemetry window, in ticks")
    ap.add_argument("--cooldown", type=float, default=8.0,
                    help="refractory period after a scale action, in ticks")
    ap.add_argument("--traffic", choices=["poisson", "bursty", "diurnal"],
                    default="poisson", help="arrival-rate shape")
    ap.add_argument("--burst-rate", type=float, default=2.0,
                    help="bursty: requests per tick during a burst")
    ap.add_argument("--burst-every", type=float, default=48.0,
                    help="bursty: ticks between burst starts")
    ap.add_argument("--burst-len", type=float, default=10.0,
                    help="bursty: burst duration in ticks")
    ap.add_argument("--period", type=float, default=96.0,
                    help="diurnal: rate-curve period in ticks")
    ap.add_argument("--amplitude", type=float, default=None,
                    help="diurnal: rate swing (default 0.8x --arrival-rate)")
    ap.add_argument("--speculative", choices=["off", "all", "auto"],
                    default="off",
                    help="paged: draft-then-verify decoding — 'all' "
                         "speculates every request, 'auto' decides per "
                         "request from measured per-class acceptance")
    ap.add_argument("--draft-model", default="self:1",
                    help="draft spec: 'self:K' truncates the target to its "
                         "first K layers (shared embeddings/head)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens proposed per speculative burst")
    ap.add_argument("--spec-damp", type=float, default=0.02,
                    help="self-draft: residual damping of the target's "
                         "deeper layers (0 -> draft == target, alpha = 1)")
    ap.add_argument("--class-mix", default="",
                    help="workload-class mixture, e.g. chat=0.7,bulk=0.3 "
                         "(empty: unclassified traffic)")
    ap.add_argument("--save-trace", default="",
                    help="record the generated request trace to this file")
    ap.add_argument("--replay-trace", default="",
                    help="replay a recorded trace instead of generating one")
    ap.add_argument("--slo", nargs="?", const="default", default="",
                    help="attach SLO burn-rate monitors: bare flag uses the "
                         "default objectives (p95 latency, TTFT, shed, "
                         "deadline); or a spec like "
                         "'p95:latency:0.95:40,ttft:ttft:0.9:20' — "
                         "name:kind:objective[:threshold_ticks]")
    ap.add_argument("--slo-window", type=float, default=4.0,
                    help="SLO evaluation window, in ticks")
    ap.add_argument("--trace-out", default="",
                    help="write a Perfetto-loadable Chrome trace of the run "
                         "(virtual-clock spans; open at ui.perfetto.dev)")
    ap.add_argument("--metrics-out", default="",
                    help="write the fleet-wide metrics registry as JSON")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.preset == "smoke":
        cfg = reduced(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    extras = {}
    if cfg.family == "audio":
        extras["frames"] = np.zeros((cfg.encoder_seq, cfg.d_model), np.float32)
    if cfg.vision_tokens:
        extras["patch_embeds"] = np.zeros((cfg.vision_tokens, cfg.d_model),
                                          np.float32)

    from repro.service import ScheduleRegistry

    tmp_root = None
    root = args.tuning_registry
    if not root:
        tmp_root = tempfile.mkdtemp(prefix="fleet-registry-")
        root = tmp_root
    registry = ScheduleRegistry(root)

    names = [t.strip() for t in args.targets.split(",") if t.strip()]
    targets = [names[i % len(names)] for i in range(args.replicas)]

    engine_kw = {}
    if args.engine == "paged":
        engine_kw = {"decode_batch": args.decode_batch,
                     "page_size": args.page_size,
                     "pool_pages": args.pool_pages, "chunk": args.chunk,
                     "defrag_threshold": args.defrag_threshold}
    if args.speculative != "off":
        if args.engine != "paged":
            ap.error("--speculative requires --engine paged")
        from repro.serving import make_self_draft
        if not args.draft_model.startswith("self:"):
            ap.error("--draft-model must be 'self:K' (truncated self-draft)")
        keep = int(args.draft_model.split(":", 1)[1])
        dcfg, dparams, params = make_self_draft(
            cfg, params, keep_layers=keep, damp=args.spec_damp)
        engine_kw.update(
            speculative=("auto" if args.speculative == "auto" else True),
            draft_model=build_model(dcfg), draft_params=dparams,
            spec_k=args.spec_k)
    from repro.obs import Tracer
    from repro.obs.export import write_chrome_trace

    tracer = Tracer() if args.trace_out else None
    prefetch = {"off": False, "hot": True, "advisor": "advisor"}[args.prefetch]
    slos = None
    if args.slo:
        slos = ("default" if args.slo == "default"
                else (lambda tick_s: _parse_slos(args.slo, tick_s)))
    fleet = ServingFleet(
        cfg, model, params, replicas=args.replicas, slots=args.slots,
        max_len=args.max_len, engine=args.engine, registry=registry,
        policy=args.policy, queue_cap=args.queue_cap,
        prefetch=prefetch, targets=targets,
        donor_target=args.donor_target, tuning_budget_s=args.tuning_budget_s,
        drain_jobs=args.drain_jobs, seed=args.seed, extras=extras,
        tracer=tracer, slos=slos, **engine_kw)
    if slos is not None:
        fleet.set_slo_window(args.slo_window * fleet.tick_s)
    if args.autoscale:
        fleet.attach_autoscaler(Autoscaler(
            min_replicas=args.min_replicas, max_replicas=args.max_replicas,
            window_s=args.scale_window * fleet.tick_s,
            cooldown_s=args.cooldown * fleet.tick_s))

    class_mix = None
    if args.class_mix:
        class_mix = {}
        for part in args.class_mix.split(","):
            name, _, w = part.partition("=")
            class_mix[name.strip()] = float(w)
    gen_kw = dict(seed=args.seed, vocab_size=cfg.vocab_size,
                  arrival_rate=args.arrival_rate, tick_s=fleet.tick_s,
                  long_frac=args.long_frac,
                  deadline_ticks=args.deadline_ticks,
                  prompt_cap=max(args.max_len // 2, 1),
                  class_mix=class_mix)
    if args.replay_trace:
        trace = load_trace(args.replay_trace)
    else:
        if args.traffic == "bursty":
            gen = BurstyTraffic(burst_rate=args.burst_rate,
                                burst_every_ticks=args.burst_every,
                                burst_len_ticks=args.burst_len, **gen_kw)
        elif args.traffic == "diurnal":
            gen = DiurnalTraffic(period_ticks=args.period,
                                 amplitude=args.amplitude, **gen_kw)
        else:
            gen = TrafficGenerator(**gen_kw)
        trace = gen.trace(args.requests)
    if args.save_trace:
        save_trace(args.save_trace, trace)
    try:
        summary = fleet.serve(trace)
    finally:
        fleet.close()  # close first: pending-job cancel events land in trace
        if tracer is not None:
            write_chrome_trace(args.trace_out, tracer)
        if args.metrics_out:
            with open(args.metrics_out, "w") as f:
                json.dump(fleet.obs.to_json(), f, indent=1, sort_keys=True)
        if tmp_root is not None:
            shutil.rmtree(tmp_root, ignore_errors=True)
    print(json.dumps(summary))
    return summary


if __name__ == "__main__":
    main()
