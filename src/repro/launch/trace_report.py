"""Offline trace analysis CLI: latency breakdown, tier shares, tuning jobs.

Reads a trace written by ``--trace-out`` (Chrome trace JSON or the flat
JSONL form — :func:`repro.obs.export.load_records` detects which) and
prints the run's story:

* **latency breakdown** — p50/p95/p99 (and means) of end-to-end latency,
  queue wait, TTFT (queue + prefill), and decode time, over exactly the
  arrival→finish intervals the fleet's own metrics aggregate — the printed
  p95 reproduces ``FleetMetrics.summary()``'s;
* **tier shares over time** — the resolution-tier mix (exact / transfer /
  static / default) per time slice, extracted from the tuning-service
  lookup events: the "exact share climbs as background tuning publishes"
  curve of the paper, recovered from any saved trace;
* **tuning jobs** — per-job claim time and virtual search cost;
* **scale timeline** — autoscaler decisions and replica join/retire
  transitions, in order;
* **speculative acceptance** — draft-token acceptance rate per time slice
  (overall and per request class) with committed-token totals, from the
  engines' ``spec_burst`` events — the panel that shows whether
  draft-then-verify is paying off and for which traffic;
* **critical path** — request latency attributed segment by segment
  (queue / prefill / decode) and cell by cell down to the kernel
  workloads that ran, from the replicas' ``cell_workloads`` events;
* **SLO timeline** — burn-rate alert/clear transitions per objective;
* **speedup ledger** — realized vs attainable speedup over time: how much
  of the registry's best-known schedules the fleet actually served.

    PYTHONPATH=src python -m repro.launch.trace_report trace.json
    PYTHONPATH=src python -m repro.launch.trace_report trace.json --json

``--json`` emits the full :func:`repro.obs.report.summarize` object for
machine consumption; the default output is a compact human-readable text
report.  See DESIGN.md §10.
"""
from __future__ import annotations

import argparse
import json

from repro.obs import report
from repro.obs.export import load_records


def _fmt_quantiles(name: str, q: dict) -> str:
    return (f"  {name:<10} mean {q['mean']:.6f}  p50 {q['p50']:.6f}  "
            f"p95 {q['p95']:.6f}  p99 {q['p99']:.6f}")


def format_report(summary: dict) -> str:
    """Render :func:`repro.obs.report.summarize` output as text."""
    lines = []
    lat = summary["latency"]
    lines.append(f"requests: {lat['requests']} completed, {lat['shed']} shed")
    lines.append("latency breakdown (virtual seconds):")
    for name in ("latency_s", "queue_s", "ttft_s", "decode_s"):
        lines.append(_fmt_quantiles(name, lat[name]))
    shares = summary["tier_shares"]
    if shares:
        lines.append("resolution tier shares over time:")
        for w in shares:
            mix = "  ".join(f"{t}={s:.2f}" for t, s in w["shares"].items())
            lines.append(f"  [{w['t0']:.4f}, {w['t1']:.4f})  "
                         f"{w['lookups']:>4} lookups  {mix}")
    jobs = summary["tuning_jobs"]
    if jobs:
        total = sum(j["duration_s"] for j in jobs)
        lines.append(f"tuning jobs: {len(jobs)}  "
                     f"(total search {total:.3f}s)")
        for j in jobs:
            lines.append(f"  t={j['t0']:.4f}  {j['duration_s']:.4f}s  "
                         f"{j['key']}")
    timeline = summary["scale_timeline"]
    if timeline:
        lines.append("scale timeline:")
        for e in timeline:
            detail = "  ".join(f"{k}={v}" for k, v in sorted(e.items())
                               if k not in ("t", "name"))
            lines.append(f"  t={e['t']:.4f}  {e['name']:<14} {detail}")
    acceptance = summary.get("acceptance", [])
    if acceptance:
        lines.append("speculative acceptance over time:")
        for w in acceptance:
            cls = "  ".join(f"{c or '(none)'}={a:.2f}"
                            for c, a in w["by_class"].items())
            lines.append(f"  [{w['t0']:.4f}, {w['t1']:.4f})  "
                         f"{w['bursts']:>4} bursts  "
                         f"accept={w['acceptance']:.2f}  "
                         f"committed={w['committed']}  {cls}")
    cp = summary.get("critical_path")
    if cp and cp.get("requests"):
        seg = cp["segments"]
        lines.append("critical path (latency attribution):")
        lines.append(f"  segments: queue={seg['queue']:.6f}s  "
                     f"prefill={seg['prefill']:.6f}s  "
                     f"decode={seg['decode']:.6f}s  "
                     f"(workload-attributed {cp['attributed_frac']:.0%})")
        cells = sorted(cp["by_cell"].items(),
                       key=lambda kv: -kv[1]["seconds"])
        for cell, row in cells[:8]:
            lines.append(f"  {cell:<16} {row['seconds']:.6f}s  "
                         f"({row['executions']:.0f} execs)")
        hot = sorted(cp["by_workload"].items(), key=lambda kv: -kv[1])
        if hot:
            lines.append("  hottest workloads:")
            for key, s in hot[:5]:
                lines.append(f"    {key}  {s:.6f}s")
    slo = summary.get("slo", [])
    if slo:
        lines.append("slo timeline:")
        for e in slo:
            lines.append(f"  t={e['t']:.4f}  {e['name']:<10} "
                         f"{e.get('slo', '?')}  "
                         f"burn fast={e.get('burn_fast', 0.0):.2f} "
                         f"slow={e.get('burn_slow', 0.0):.2f}")
    ledger = summary.get("speedup_ledger", [])
    if ledger:
        last = ledger[-1]
        lines.append("speedup ledger:")
        for e in ledger:
            lines.append(
                f"  t={e['t']:.4f}  realized {e['realized_speedup']:.3f}x  "
                f"attainable {e['attainable_speedup']:.3f}x  "
                f"fraction {e['realized_fraction']:.2f}  "
                f"tuned {e['tuned_workloads']}/{e['workloads']}")
        lines.append(
            f"  final: serving {last['realized_fraction']:.0%} of "
            f"best-known speedup "
            f"({last['realized_speedup']:.3f}x of "
            f"{last['attainable_speedup']:.3f}x attainable)")
    return "\n".join(lines)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(
        description="summarize a --trace-out trace: latency breakdown, "
                    "tier shares over time, tuning jobs, scale timeline")
    ap.add_argument("trace", help="Chrome trace JSON or JSONL record file")
    ap.add_argument("--windows", type=int, default=8,
                    help="time slices for the tier-share series")
    ap.add_argument("--json", action="store_true",
                    help="emit the full summary object as JSON")
    args = ap.parse_args(argv)

    records = load_records(args.trace)
    summary = report.summarize(records, windows=args.windows)
    if args.json:
        print(json.dumps(summary, indent=1, sort_keys=True))
    else:
        print(format_report(summary))
    return summary


if __name__ == "__main__":
    main()
