import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse        # noqa: E402
import json            # noqa: E402
import math            # noqa: E402
import re              # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402

import jax             # noqa: E402

from repro.configs.base import all_cells, get_arch, get_shape, shape_applicable  # noqa: E402
from repro.distributed import sharding as shd                                    # noqa: E402
from repro.distributed.context import activation_sharding, set_remat_policy, set_sharding_rules  # noqa: E402
from repro.hw.specs import TPU_V5E                                               # noqa: E402
from repro.launch import steps as steps_mod                                      # noqa: E402
from repro.launch.mesh import make_production_mesh                               # noqa: E402
from repro.models.build import build_model                                       # noqa: E402
from repro.optim.adamw import AdamWConfig                                        # noqa: E402

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware:
  * ``jax.jit(step).lower(**ShapeDtypeStructs).compile()`` must succeed on
    the 16×16 single-pod mesh AND the 2×16×16 multi-pod mesh;
  * ``compiled.memory_analysis()`` proves the per-device footprint fits;
  * ``compiled.cost_analysis()`` + the post-SPMD HLO collective scan feed
    the roofline table (EXPERIMENTS.md §Roofline).

Artifacts are cached as JSON under benchmarks/results/dryrun/ so the sweep
is resumable and the roofline benchmark is a pure read.
"""

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\w+\[[^\]]*\]\S*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo: str) -> dict:
    """Per-device collective operand bytes from post-SPMD HLO text."""
    stats: dict[str, dict] = {}
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        result_bytes = _shape_bytes(m.group(1))
        op = m.group(2)
        gl = _GROUPS_LIST_RE.search(line)
        gi = _GROUPS_IOTA_RE.search(line)
        if gl:
            gsize = len(gl.group(1).split(","))
        elif gi:
            gsize = int(gi.group(2))
        else:
            gsize = 1
        if op == "all-gather":
            operand = result_bytes // max(gsize, 1)
        elif op == "reduce-scatter":
            operand = result_bytes * max(gsize, 1)
        else:
            operand = result_bytes
        s = stats.setdefault(op, {"count": 0, "operand_bytes": 0, "result_bytes": 0})
        s["count"] += 1
        s["operand_bytes"] += operand
        s["result_bytes"] += result_bytes
    stats["total_operand_bytes"] = sum(
        v["operand_bytes"] for k, v in stats.items() if isinstance(v, dict)
    )
    return stats


def _sharded_bytes(abstract_tree, shardings_tree, mesh) -> int:
    """Analytic per-device bytes of a sharded pytree."""
    total = 0
    flat = jax.tree_util.tree_leaves(abstract_tree)
    shards = jax.tree_util.tree_leaves(
        shardings_tree, is_leaf=lambda x: hasattr(x, "spec"))
    for leaf, sh in zip(flat, shards):
        n_shards = 1
        for axes in sh.spec:
            if axes is None:
                continue
            for a in (axes if isinstance(axes, tuple) else (axes,)):
                n_shards *= mesh.shape[a]
        total += math.ceil(leaf.size / n_shards) * leaf.dtype.itemsize
    return total


def run_cell(arch_name: str, shape_name: str, multi_pod: bool, *,
             remat_policy_name: str = "full", grad_accum: int = 1,
             seq_parallel: bool = False) -> dict:
    cfg = get_arch(arch_name)
    shape = get_shape(shape_name)
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = math.prod(mesh.devices.shape)
    model = build_model(cfg)
    abstract_params = model.abstract_params()
    dp_only = shd.dp_dominant(cfg, mesh, kind=shape.kind,
                              global_batch=shape.global_batch)
    p_shard = shd.param_shardings(abstract_params, cfg, mesh, dp_only)
    specs = model.input_specs(shape)
    b_shard = shd.batch_shardings(specs, cfg, mesh, dp_only)
    act_shard = shd.activation_sharding(mesh, cfg, dp_only,
                                        seq_parallel and shape.kind == "prefill")

    t0 = time.monotonic()
    set_sharding_rules(shd.internal_sharding_rules(mesh, cfg))
    set_remat_policy(remat_policy_name)
    with activation_sharding(act_shard):
        if shape.kind == "train":
            opt = jax.eval_shape(steps_mod.init_opt_state, abstract_params)
            o_shard = shd.opt_state_shardings(p_shard, mesh)
            step = steps_mod.make_train_step(model, AdamWConfig(), grad_accum=grad_accum)
            jitted = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                             out_shardings=(p_shard, o_shard, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(abstract_params, opt, specs)
        elif shape.kind == "prefill":
            step = steps_mod.make_prefill_step(model, max_len=shape.seq_len)
            jitted = jax.jit(step, in_shardings=(p_shard, b_shard))
            lowered = jitted.lower(abstract_params, specs)
        else:  # decode
            step = steps_mod.make_decode_step(model)
            jitted = jax.jit(step,
                             in_shardings=(p_shard, b_shard["cache"], b_shard["tokens"]),
                             out_shardings=(None, b_shard["cache"]),
                             donate_argnums=(1,))
            lowered = jitted.lower(abstract_params, specs["cache"], specs["tokens"])
        t_lower = time.monotonic() - t0
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0 - t_lower
    set_sharding_rules(None)
    set_remat_policy(None)

    cost = dict(compiled.cost_analysis() or {})
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # CPU client may not implement it
        mem_d = {"error": str(e)}
    hlo = compiled.as_text()
    coll = collective_stats(hlo)

    param_bytes = _sharded_bytes(abstract_params, p_shard, mesh)
    flops = float(cost.get("flops", 0.0))
    hbm_bytes = float(cost.get("bytes accessed", 0.0))

    # roofline terms (per assignment formulas; cost_analysis is per-device
    # post-SPMD, so the chips factor is already applied)
    compute_s = flops / TPU_V5E.peak_flops_bf16
    memory_s = hbm_bytes / TPU_V5E.hbm_bandwidth
    collective_s = coll["total_operand_bytes"] / TPU_V5E.ici_bandwidth

    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    n_active = cfg.active_param_count()
    model_flops = (6 if shape.kind == "train" else 2) * n_active * tokens
    # XLA-CPU lowers dots to oneDNN custom-calls whose flops cost_analysis
    # does not count; the analytic term (8·N·D train with full remat
    # recompute, 2·N·D inference) is the TPU-faithful compute bound.
    train_factor = 6 if remat_policy_name == "dots" else 8  # dots: no fwd recompute
    analytic_flops = (train_factor if shape.kind == "train" else 2) * n_active * tokens
    compute_analytic_s = analytic_flops / (chips * TPU_V5E.peak_flops_bf16)

    return {
        "status": "ok",
        "arch": arch_name,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "strategy": "dp_only" if dp_only else "fsdp+tp",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "cost_analysis": {k: cost[k] for k in sorted(cost) if isinstance(cost[k], (int, float))},
        "memory_analysis": mem_d,
        "collectives": coll,
        "param_bytes_per_device": param_bytes,
        "roofline": {
            "compute_s": max(compute_s, compute_analytic_s),
            "compute_hlo_s": compute_s,
            "compute_analytic_s": compute_analytic_s,
            "memory_s": memory_s,
            "collective_s": collective_s,
            "dominant": max(
                [("compute", max(compute_s, compute_analytic_s)),
                 ("memory", memory_s), ("collective", collective_s)],
                key=lambda kv: kv[1],
            )[0],
            "model_flops_total": model_flops,
            "hlo_flops_per_device": flops,
            "useful_flops_ratio": model_flops / max(
                max(flops, analytic_flops / chips) * chips, 1.0),
        },
    }


def cell_path(arch: str, shape: str, mesh: str) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mesh}.json")


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    ap.add_argument("--remat-policy", choices=["full", "dots"], default="full")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--seq-parallel", action="store_true",
                    help="prefill context parallelism experiment (§Perf it-8)")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    cells = [(a, s) for a, s, _ok, _w in all_cells()
             if (args.arch is None or a == args.arch)
             and (args.shape is None or s == args.shape)]

    n_ok = n_skip = n_fail = 0
    for arch, shape in cells:
        for multi in meshes:
            mesh_name = "2x16x16" if multi else "16x16"
            path = cell_path(arch, shape, mesh_name)
            if os.path.exists(path) and not args.force:
                with open(path) as f:
                    prev = json.load(f)
                print(f"[cached] {arch} {shape} {mesh_name}: {prev['status']}")
                n_ok += prev["status"] == "ok"
                n_skip += prev["status"] == "skipped"
                n_fail += prev["status"] == "failed"
                continue
            print(f"[run] {arch} {shape} {mesh_name} ...", flush=True)
            try:
                res = run_cell(arch, shape, multi,
                               remat_policy_name=args.remat_policy,
                               grad_accum=args.grad_accum,
                               seq_parallel=args.seq_parallel)
            except Exception as e:
                res = {"status": "failed", "arch": arch, "shape": shape,
                       "mesh": mesh_name, "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]}
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            if res["status"] == "ok":
                n_ok += 1
                r = res["roofline"]
                print(f"  ok: compile={res['compile_s']}s "
                      f"compute={r['compute_s']*1e3:.2f}ms memory={r['memory_s']*1e3:.2f}ms "
                      f"coll={r['collective_s']*1e3:.2f}ms dom={r['dominant']} "
                      f"params/dev={res['param_bytes_per_device']/2**30:.2f}GiB", flush=True)
            elif res["status"] == "skipped":
                n_skip += 1
                print(f"  skipped: {res['reason']}")
            else:
                n_fail += 1
                print(f"  FAILED: {res['error']}")
    print(f"\ndry-run summary: ok={n_ok} skipped={n_skip} failed={n_fail}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
