from repro.hw.specs import (
    TPU_V5E,
    TPU_V5E_LITE,
    TPU_V5P,
    ChipSpec,
    collective_time_s,
    compute_time_s,
    dim_efficiency,
    memory_time_s,
)

__all__ = [
    "TPU_V5E",
    "TPU_V5E_LITE",
    "TPU_V5P",
    "ChipSpec",
    "collective_time_s",
    "compute_time_s",
    "dim_efficiency",
    "memory_time_s",
]
