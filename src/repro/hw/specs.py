"""Hardware constants for the target platform (TPU v5e) and roofline helpers.

This container is CPU-only; v5e is the *target*. Every performance number in
the framework (cost model, roofline terms) is derived from these constants,
so they live in exactly one place.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops_bf16: float        # FLOP/s per chip
    hbm_bandwidth: float          # bytes/s per chip
    hbm_capacity: int             # bytes per chip
    vmem_capacity: int            # bytes per core (usable budget for kernels)
    ici_bandwidth: float          # bytes/s per link
    ici_links: int                # links per chip (2D torus: 4)
    mxu_dim: int = 128            # systolic array native dim
    vreg_sublanes: int = 8        # native sublane count
    vreg_lanes: int = 128         # native lane count
    kernel_launch_overhead_s: float = 2e-6


TPU_V5E = ChipSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,       # 197 TFLOP/s bf16 (assignment constant)
    hbm_bandwidth=819e9,          # 819 GB/s (assignment constant)
    hbm_capacity=16 * 1024**3,    # 16 GiB
    vmem_capacity=96 * 1024**2,   # 96 MiB usable of 128 MiB (pipeline margin)
    ici_bandwidth=50e9,           # ~50 GB/s per link (assignment constant)
    ici_links=4,
)


def compute_time_s(flops: float, chips: int = 1, spec: ChipSpec = TPU_V5E) -> float:
    return flops / (chips * spec.peak_flops_bf16)


def memory_time_s(bytes_: float, chips: int = 1, spec: ChipSpec = TPU_V5E) -> float:
    return bytes_ / (chips * spec.hbm_bandwidth)


def collective_time_s(bytes_: float, chips: int = 1, spec: ChipSpec = TPU_V5E) -> float:
    # Per the assignment: collective_bytes / (chips * link_bw).
    return bytes_ / (chips * spec.ici_bandwidth)


def dim_efficiency(block: int, native: int) -> float:
    """Fraction of a hardware-native tile that a block of size `block` fills.

    A block of 96 on a native-128 unit wastes 25% of the lanes: eff = 96/128.
    Blocks larger than native are penalized only by their remainder tile.
    """
    if block <= 0:
        return 0.0
    import math

    padded = math.ceil(block / native) * native
    return block / padded
