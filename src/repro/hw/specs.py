"""Hardware constants for the supported target platforms and roofline helpers.

This container is CPU-only; the TPU chips are *targets*.  Every performance
number in the framework (cost model, roofline terms) is derived from these
constants, so they live in exactly one place.  Named specs are registered as
:class:`repro.targets.Target` entries — resolve them by name through
``repro.targets.get_target`` rather than importing constants directly.

``TPU_V5E`` is the paper-analogue server-class chip every seed experiment
used.  ``TPU_V5E_LITE`` is a constrained edge analogue (the paper's A7x-class
platform): one MXU worth of FLOPs, a narrow LPDDR-like memory system, and a
small VMEM budget that makes many server-tuned schedules structurally
invalid.  ``TPU_V5P`` is the larger pod-scale chip.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops_bf16: float        # FLOP/s per chip
    hbm_bandwidth: float          # bytes/s per chip
    hbm_capacity: int             # bytes per chip
    vmem_capacity: int            # bytes per core (usable budget for kernels)
    ici_bandwidth: float          # bytes/s per link
    ici_links: int                # links per chip (2D torus: 4)
    mxu_dim: int = 128            # systolic array native dim
    vreg_sublanes: int = 8        # native sublane count
    vreg_lanes: int = 128         # native lane count
    kernel_launch_overhead_s: float = 2e-6


TPU_V5E = ChipSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,       # 197 TFLOP/s bf16 (assignment constant)
    hbm_bandwidth=819e9,          # 819 GB/s (assignment constant)
    hbm_capacity=16 * 1024**3,    # 16 GiB
    vmem_capacity=96 * 1024**2,   # 96 MiB usable of 128 MiB (pipeline margin)
    ici_bandwidth=50e9,           # ~50 GB/s per link (assignment constant)
    ici_links=4,
)

TPU_V5E_LITE = ChipSpec(
    name="tpu-v5e-lite",
    peak_flops_bf16=25e12,        # single-MXU edge part
    hbm_bandwidth=102e9,          # LPDDR-class memory system
    hbm_capacity=4 * 1024**3,     # 4 GiB
    vmem_capacity=8 * 1024**2,    # 8 MiB usable — large server tiles overflow
    ici_bandwidth=10e9,           # single narrow link
    ici_links=1,
    kernel_launch_overhead_s=8e-6,
)

TPU_V5P = ChipSpec(
    name="tpu-v5p",
    peak_flops_bf16=459e12,       # 459 TFLOP/s bf16
    hbm_bandwidth=2765e9,         # 2.77 TB/s HBM2e
    hbm_capacity=95 * 1024**3,    # 95 GiB
    vmem_capacity=112 * 1024**2,  # 112 MiB usable of 128 MiB
    ici_bandwidth=100e9,          # 3D-torus links
    ici_links=6,
)


def compute_time_s(flops: float, chips: int = 1, spec: ChipSpec = TPU_V5E) -> float:
    return flops / (chips * spec.peak_flops_bf16)


def memory_time_s(bytes_: float, chips: int = 1, spec: ChipSpec = TPU_V5E) -> float:
    return bytes_ / (chips * spec.hbm_bandwidth)


def collective_time_s(bytes_: float, chips: int = 1, spec: ChipSpec = TPU_V5E) -> float:
    # Per the assignment: collective_bytes / (chips * link_bw).
    return bytes_ / (chips * spec.ici_bandwidth)


def dim_efficiency(block: int, native: int) -> float:
    """Fraction of a hardware-native tile that a block of size `block` fills.

    A block of 96 on a native-128 unit wastes 25% of the lanes: eff = 96/128.
    Blocks larger than native are penalized only by their remainder tile.
    """
    if block <= 0:
        return 0.0
    padded = math.ceil(block / native) * native
    return block / padded
