"""Resolution pipeline: staged, generation-aware schedule resolution.

The paper's payoff is *cheap reuse*: auto-schedules are found once and then
served many times.  Before this module, the serving hot path re-paid
resolution on every kernel call — a service lookup (lock + counters +
optional transfer probe) followed by a fresh ``concretize``.  This module
makes resolution a first-class, explicitly staged pipeline with a memoized
result cache:

* :class:`ResolutionPipeline` walks an ordered list of stages —
  **service** (online :class:`~repro.service.TuningService`) → **static map**
  (frozen offline schedules) → **default** (untuned fallback) — and caches
  the winning :class:`Resolution` keyed by
  ``(workload_key, mode, target, generation)``.  ``generation`` is the
  schedule registry's publish counter, so a background upgrade naturally
  invalidates exactly the stale keys: steady-state resolution is a single
  dict hit with no service lock and no re-``concretize``.
* When the service can attribute every generation bump to its own publishes
  (:meth:`TuningService.changed_since`), the cache *migrates* unchanged
  workloads to the new generation instead of clearing — an upgrade to one
  kernel does not re-resolve the other hundred.
* :class:`ExecutionPlan` freezes the resolutions for every kernel instance a
  model emits (via :mod:`repro.core.extract`), with provenance tier and a
  generation stamp.  :func:`plan_model` builds one for an (arch × shape)
  cell; :func:`plan_serving` builds one for a serving engine's decode batch
  and prefill buckets.  Ops consult the active plan before falling back to
  the pipeline; a plan lookup is a dict hit — no service lock, no stage
  walk, no re-``concretize`` (only a cheap local counter bump remains).

Per-tier accounting (``exact`` / ``transfer`` / ``static`` / ``default``) is
kept here under a lock, replacing the lossy (and racy) hit/miss pair the old
provider kept: a service answer of the *untuned default* tier falls through
the stage and is never counted as a hit.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Iterable, Mapping, Sequence

from repro.core.schedule import (
    ConcreteSchedule,
    Schedule,
    ScheduleInvalid,
    concretize,
    default_schedule,
)
from repro.core.workload import KernelInstance, KernelUse, dedup_uses
from repro.obs import NULL_TRACER, MetricsRegistry
from repro.targets import DEFAULT_TARGET, target_name

#: Resolution tiers, strongest first.  ``exact``/``transfer`` come from the
#: online service, ``static`` from a frozen offline schedule map, ``default``
#: is the untuned fallback.
TIERS = ("exact", "transfer", "static", "default")


@dataclasses.dataclass(frozen=True)
class Resolution:
    """One resolved schedule: the concrete binding plus its provenance."""

    concrete: ConcreteSchedule
    tier: str                 # one of TIERS
    stage: str = ""           # name of the pipeline stage that answered
    source_model: str = ""    # model the winning schedule was tuned on
    generation: int = 0       # pipeline generation the resolution is valid at

    @property
    def schedule(self) -> Schedule:
        return self.concrete.schedule

    @property
    def instance(self) -> KernelInstance:
        return self.concrete.instance


# ---------------------------------------------------------------------------
# Stages
# ---------------------------------------------------------------------------


class ResolutionStage:
    """One rung of the pipeline: answer or pass (return ``None``)."""

    name = "stage"

    def resolve(self, instance: KernelInstance, mode: str) -> Resolution | None:
        raise NotImplementedError

    def generation(self) -> int:
        """Monotone counter bumped whenever this stage's answers may change."""
        return 0

    def changed_since(self, generation: int) -> set[str] | None:
        """Workload keys whose answer may differ since ``generation``.

        ``None`` means "unknown — assume everything changed".  Static stages
        never change, so the base returns the empty set.
        """
        return set()


class ServiceStage(ResolutionStage):
    """Tiered online lookup through a :class:`~repro.service.TuningService`.

    Only ``exact``/``transfer`` answers count; a ``default``-tier lookup
    falls through to the next stage (the untuned default is not a hit — the
    accounting bug the old provider had).  Answers are re-validated under
    the *requested* mode, which may differ from the service's own.
    """

    name = "service"

    def __init__(self, service):
        self.service = service

    def resolve(self, instance: KernelInstance, mode: str) -> Resolution | None:
        lr = self.service.lookup(instance)
        if lr.schedule is None or lr.tier == "default":
            return None
        try:
            cs = concretize(lr.schedule, instance, mode=mode)
        except ScheduleInvalid:
            return None
        return Resolution(cs, lr.tier, self.name, lr.source_model, lr.generation)

    def generation(self) -> int:
        gen = getattr(self.service, "generation", None)
        if callable(gen):
            return gen()
        return getattr(self.service.registry, "generation", 0)

    def changed_since(self, generation: int) -> set[str] | None:
        fn = getattr(self.service, "changed_since", None)
        if fn is None:
            return None
        return fn(generation)


class StaticMapStage(ResolutionStage):
    """Frozen ``workload_key -> Schedule`` mapping (offline tuning output)."""

    name = "static"

    def __init__(self, schedule_map: Mapping[str, Schedule] | None = None):
        self.schedule_map = dict(schedule_map or {})

    def resolve(self, instance: KernelInstance, mode: str) -> Resolution | None:
        sched = self.schedule_map.get(instance.workload_key())
        if sched is None:
            return None
        try:
            cs = concretize(sched, instance, mode=mode)
        except ScheduleInvalid:
            return None
        return Resolution(cs, "static", self.name, sched.source)


class DefaultStage(ResolutionStage):
    """Terminal stage: the untuned default schedule, always valid."""

    name = "default"

    def resolve(self, instance: KernelInstance, mode: str) -> Resolution | None:
        cs = concretize(default_schedule(instance), instance)
        return Resolution(cs, "default", self.name, "")


# ---------------------------------------------------------------------------
# Pipeline
# ---------------------------------------------------------------------------


class ResolutionPipeline:
    """Staged resolution with a generation-keyed memo cache.

    ``resolve()`` walks the stages on a miss and caches the winner under
    ``(workload_key, mode, target, generation)``.  The generation is the sum
    of the stages' counters (in practice: the schedule registry's publish
    counter), so background upgrades invalidate exactly the stale entries.
    Counter updates are lock-protected; the steady-state read is a dict hit.
    """

    def __init__(self, stages: Sequence[ResolutionStage], *,
                 mode: str = "strict", target=None,
                 metrics: MetricsRegistry | None = None, tracer=None):
        if not stages:
            stages = [DefaultStage()]
        self.stages = list(stages)
        self.mode = mode
        self.target = target_name(target) if target is not None else DEFAULT_TARGET
        self._lock = threading.Lock()
        self._cache: dict[tuple[str, str, str, int], Resolution] = {}
        # Per-stage generation vector: each stage's changed_since must be
        # asked against its OWN last generation (summing first would
        # misattribute bumps when several stages carry counters).
        self._stage_gens = tuple(st.generation() for st in self.stages)
        self._cache_gen = sum(self._stage_gens)
        # Counters live in a metrics registry (private by default: one
        # pipeline per replica, and same-named counters must not merge
        # across replicas).  Owners rebind ``tracer`` post-construction.
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._counters = self.metrics.group("resolution", [
            "resolves", "cache_hits", "cache_misses", "stage_calls",
            "migrated", "invalidations", *(f"served_{t}" for t in TIERS)])

    @staticmethod
    def build(schedule_map: Mapping[str, Schedule] | None = None,
              service=None, mode: str = "strict", target=None
              ) -> "ResolutionPipeline":
        """The canonical stage order: service → static map → default."""
        stages: list[ResolutionStage] = []
        if service is not None:
            stages.append(ServiceStage(service))
            if target is None:
                target = getattr(service, "target", None)
        stages.append(StaticMapStage(schedule_map))
        stages.append(DefaultStage())
        return ResolutionPipeline(stages, mode=mode, target=target)

    # -- convenience accessors ------------------------------------------------
    @property
    def service(self):
        for st in self.stages:
            if isinstance(st, ServiceStage):
                return st.service
        return None

    @property
    def schedule_map(self) -> dict[str, Schedule]:
        for st in self.stages:
            if isinstance(st, StaticMapStage):
                return st.schedule_map
        return {}

    # -- resolution -----------------------------------------------------------
    def generation(self) -> int:
        return sum(st.generation() for st in self.stages)

    def resolve(self, instance: KernelInstance, mode: str | None = None
                ) -> Resolution:
        mode = mode or self.mode
        gen = self.generation()
        if gen != self._cache_gen:
            with self._lock:
                self._sync_generation_locked()
            gen = self._cache_gen
        key = (instance.workload_key(), mode, self.target, gen)
        res = self._cache.get(key)
        if res is not None:
            with self._lock:
                self._counters["resolves"] += 1
                self._counters["cache_hits"] += 1
                self._counters[f"served_{res.tier}"] += 1
            return res

        res = None
        walked = 0
        for stage in self.stages:
            walked += 1
            res = stage.resolve(instance, mode)
            if res is not None:
                break
        if res is None:  # no terminal stage configured: untuned fallback
            res = Resolution(concretize(default_schedule(instance), instance),
                             "default", "fallback", "")
        res = dataclasses.replace(res, generation=gen)
        with self._lock:
            self._counters["resolves"] += 1
            self._counters["cache_misses"] += 1
            self._counters["stage_calls"] += walked
            self._counters[f"served_{res.tier}"] += 1
            self._cache[key] = res
        # Only stage walks are traced: memoized hits are the hot path and
        # would swamp the trace with identical records.
        if self.tracer.enabled:
            self.tracer.event("resolve", "resolution",
                              key=instance.workload_key(), tier=res.tier,
                              stage=res.stage, target=self.target,
                              generation=gen)
        return res

    def get(self, instance: KernelInstance) -> ConcreteSchedule:
        """Ops-facing API: the concrete schedule to run ``instance`` with."""
        return self.resolve(instance).concrete

    def _sync_generation_locked(self) -> None:
        stage_gens = tuple(st.generation() for st in self.stages)
        new_gen = sum(stage_gens)
        if new_gen == self._cache_gen:
            return  # another thread synced while we waited on the lock
        changed: set[str] | None = set()
        for st, old_g in zip(self.stages, self._stage_gens):
            c = st.changed_since(old_g)
            if c is None:
                changed = None
                break
            changed |= c
        if changed is None:
            # Unattributable bump (e.g. another process published): assume
            # anything may have changed.
            self._cache.clear()
            self._counters["invalidations"] += 1
        else:
            moved: dict[tuple[str, str, str, int], Resolution] = {}
            for (wk, mode, tgt, g), res in self._cache.items():
                # Only entries at the synced generation migrate: a slow
                # resolver may have inserted under an older generation after
                # a previous sync, and rekeying it here could shadow the
                # fresher answer.
                if g == self._cache_gen and wk not in changed:
                    moved[(wk, mode, tgt, new_gen)] = dataclasses.replace(
                        res, generation=new_gen)
            self._counters["migrated"] += len(moved)
            self._cache = moved
        self._cache_gen = new_gen
        self._stage_gens = stage_gens

    def invalidate(self) -> None:
        """Drop every memoized resolution (stages are re-walked on demand)."""
        with self._lock:
            self._cache.clear()
            self._counters["invalidations"] += 1

    # -- telemetry ------------------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            out = dict(self._counters)
            out["cache_size"] = len(self._cache)
        out["generation"] = self.generation()
        out["mode"] = self.mode
        out["target"] = self.target
        out["stages"] = [st.name for st in self.stages]
        return out


# ---------------------------------------------------------------------------
# Execution plans
# ---------------------------------------------------------------------------


class ExecutionPlan:
    """Frozen pre-resolved schedules for every kernel a model emits.

    Built once per (model, shapes, generation); lookups are a plain dict hit
    with zero locks — the serving hot path's steady state.  A plan is
    immutable: upgrades produce a *new* plan via :meth:`refresh` (the engine
    swaps plans only between decode steps, never mid-step).
    """

    def __init__(self, uses: Sequence[KernelUse],
                 resolutions: Sequence[Resolution], *, generation: int,
                 mode: str, target: str, label: str = ""):
        if len(uses) != len(resolutions):
            raise ValueError("one resolution per kernel use required")
        self.uses = tuple(uses)
        self.generation = generation
        self.mode = mode
        self.target = target
        self.label = label
        self._by_key: dict[str, Resolution] = {
            u.instance.workload_key(): r for u, r in zip(uses, resolutions)
        }

    def __len__(self) -> int:
        return len(self._by_key)

    def lookup(self, instance: KernelInstance) -> Resolution | None:
        return self._by_key.get(instance.workload_key())

    def get(self, workload_key: str) -> Resolution | None:
        return self._by_key.get(workload_key)

    def items(self) -> Iterable[tuple[KernelUse, Resolution]]:
        for u in self.uses:
            yield u, self._by_key[u.instance.workload_key()]

    def tier_counts(self) -> dict[str, int]:
        counts = {t: 0 for t in TIERS}
        for r in self._by_key.values():
            counts[r.tier] += 1
        return counts

    def schedules(self) -> dict[str, Schedule]:
        """workload_key -> chosen Schedule (for equivalence checks)."""
        return {k: r.schedule for k, r in self._by_key.items()}

    def refresh(self, pipeline: ResolutionPipeline) -> "ExecutionPlan":
        """Re-resolve every entry at the pipeline's current generation."""
        return plan_uses(self.uses, pipeline, label=self.label)


def plan_uses(uses: Sequence[KernelUse], pipeline: ResolutionPipeline,
              label: str = "") -> ExecutionPlan:
    """Freeze resolutions for an explicit kernel-use list."""
    merged = dedup_uses(list(uses))
    generation = pipeline.generation()
    resolutions = [pipeline.resolve(u.instance) for u in merged]
    return ExecutionPlan(merged, resolutions, generation=generation,
                         mode=pipeline.mode, target=pipeline.target,
                         label=label)


def plan_model(model_cfg, pipeline: ResolutionPipeline, shape="train_4k", *,
               dp: int = 1, tp: int = 1, label: str | None = None
               ) -> ExecutionPlan:
    """Pre-resolve every kernel instance an (arch × shape) cell emits.

    ``model_cfg`` is an :class:`~repro.configs.base.ArchConfig` or arch id;
    ``shape`` a :class:`~repro.configs.base.ShapeConfig` or shape name.
    """
    from repro.configs.base import get_arch, get_shape  # lazy: layering
    from repro.core.extract import extract_kernels

    cfg = get_arch(model_cfg) if isinstance(model_cfg, str) else model_cfg
    sh = get_shape(shape) if isinstance(shape, str) else shape
    uses = extract_kernels(cfg, sh, dp=dp, tp=tp)
    return plan_uses(uses, pipeline,
                     label=label if label is not None else f"{cfg.name}/{sh.name}")


def plan_serving(model_cfg, pipeline: ResolutionPipeline, *, slots: int,
                 max_len: int, prefill_lengths: Sequence[int] = (),
                 label: str = "serving") -> ExecutionPlan:
    """Pre-resolve a serving engine's kernel set.

    Covers the batched decode step (batch = ``slots``) plus a batch-1
    prefill cell per expected prompt-length bucket.  Instances the engine
    emits outside this set (e.g. unbucketed prompt lengths) fall back to the
    pipeline at run time.
    """
    from repro.configs.base import ShapeConfig  # lazy: layering
    from repro.core.extract import extract_kernels

    uses = list(extract_kernels(
        model_cfg, ShapeConfig("serve_decode", max_len, slots, "decode"),
        dp=1, tp=1))
    for n in sorted(set(int(n) for n in prefill_lengths)):
        uses.extend(extract_kernels(
            model_cfg, ShapeConfig(f"serve_prefill_{n}", n, 1, "prefill"),
            dp=1, tp=1))
    return plan_uses(uses, pipeline, label=label)


def plan_serving_paged(model_cfg, pipeline: ResolutionPipeline, *,
                       decode_batch: int, page_size: int, pages_per_seq: int,
                       chunk_lens: Sequence[int] = (), spec_k: int = 0,
                       draft_cfg=None, label: str | None = None
                       ) -> ExecutionPlan:
    """Pre-resolve a *paged* serving engine's kernel set.

    The paged engine's workload classes key on (decode-batch-size,
    page-size): the batched decode step runs at ``decode_batch`` lanes over
    a per-lane context of ``page_size * pages_per_seq`` gathered pages, and
    prefill is batch-1 ``chunk_prefill`` cells — one per chunk length —
    attending into that same context.  The registry/TuningService stack
    learns these shapes exactly like any other cell.

    ``spec_k > 0`` adds the speculative cells: the batched ``verify`` step
    (k+1 positions per lane, all ``decode_batch`` lanes) for the target
    model, and — when ``draft_cfg`` is given — the draft model's decode and
    chunk-prefill cells.  The verify cell shares the chunk-prefill kernel
    classes, so transfer-tuning seeds it from the chunk donors.
    """
    from repro.configs.base import ShapeConfig  # lazy: layering
    from repro.core.extract import extract_kernels

    max_ctx = page_size * pages_per_seq
    if label is None:
        label = f"paged/b{decode_batch}/p{page_size}"
    uses = list(extract_kernels(
        model_cfg, ShapeConfig("paged_decode", max_ctx, decode_batch,
                               "decode"), dp=1, tp=1))
    for c in sorted(set(int(c) for c in chunk_lens)):
        uses.extend(extract_kernels(
            model_cfg, ShapeConfig(f"paged_chunk_{c}", c, 1, "chunk_prefill",
                                   ctx_len=max_ctx), dp=1, tp=1))
    if spec_k > 0:
        uses.extend(spec_verify_uses(model_cfg, decode_batch=decode_batch,
                                     max_ctx=max_ctx, spec_k=spec_k))
        if draft_cfg is not None:
            uses.extend(extract_kernels(
                draft_cfg, ShapeConfig("paged_decode", max_ctx, decode_batch,
                                       "decode"), dp=1, tp=1))
            for c in sorted(set(int(c) for c in chunk_lens)):
                uses.extend(extract_kernels(
                    draft_cfg, ShapeConfig(f"paged_chunk_{c}", c, 1,
                                           "chunk_prefill", ctx_len=max_ctx),
                    dp=1, tp=1))
    return plan_uses(uses, pipeline, label=label)


def spec_verify_uses(model_cfg, *, decode_batch: int, max_ctx: int,
                     spec_k: int) -> list[KernelUse]:
    """Kernel uses of one batched speculative ``verify`` step: k+1 positions
    per lane across all ``decode_batch`` lanes, attending into ``max_ctx``
    cached context.  Exposed standalone so benchmarks and the tuning service
    can tune / transfer-seed the verify workload without building a plan."""
    from repro.configs.base import ShapeConfig  # lazy: layering
    from repro.core.extract import extract_kernels

    return list(extract_kernels(
        model_cfg, ShapeConfig(f"spec_verify_{spec_k + 1}", spec_k + 1,
                               decode_batch, "verify", ctx_len=max_ctx),
        dp=1, tp=1))
