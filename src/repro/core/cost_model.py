"""Analytical TPU-v5e kernel cost model + measurement simulator.

This plays the role of Ansor's *measurement* step (build + run on hardware).
The container is a single CPU core and the target is TPU v5e, so wall-clock
measurement of interpreted Pallas kernels would rank schedules by Python
overhead rather than TPU behaviour.  Instead we model, per kernel family:

* a compute term — FLOPs over MXU/VPU peak, derated by tile alignment
  against the native (8, 128) VREG / 128×128 MXU geometry;
* a memory term — HBM traffic **derived from the tiling and grid order**,
  using Pallas' consecutive-revisit semantics (a block is re-fetched unless
  its index map is unchanged between consecutive grid steps);
* VMEM capacity validity (double-buffered operand blocks + accumulators);
* pipeline fill/launch overheads and an unroll instruction-overhead knob.

Time = max(compute, memory) + overheads, then a seeded log-normal noise
factor emulates Ansor's stochastic measurements.  Every second produced here
is a *cost-model second* (documented in DESIGN.md / EXPERIMENTS.md).

The model is intentionally sensitive to the same schedule features the paper
manipulates (Split/Reorder/Unroll/Vectorize/cache staging), so the transfer-
tuning phenomena (invalid transfers, near-native transferred performance,
mixed-pool regressions) emerge rather than being hard-coded.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
import struct
from typing import Mapping, Sequence

from repro.core.schedule import ConcreteSchedule, Schedule, ScheduleInvalid, concretize, default_schedule
from repro.core.workload import KernelInstance, KernelUse, class_family
from repro.hw.specs import TPU_V5E, ChipSpec, dim_efficiency

DTYPE_BYTES = {"bfloat16": 2, "float32": 4, "float16": 2, "int8": 1}

# Virtual measurement-harness costs (Ansor's search time is dominated by
# candidate build+run; these mirror its scale: ~seconds per candidate).
COMPILE_S = 1.2          # per-candidate build time
FAILED_COMPILE_S = 0.7   # invalid candidates are caught at build time
RUN_REPEATS = 3
RUN_OVERHEAD_S = 0.05
MIN_RUN_S = 1e-3


@dataclasses.dataclass(frozen=True)
class CostBreakdown:
    compute_s: float
    memory_s: float
    overhead_s: float
    flops: float
    hbm_bytes: float
    vmem_bytes: int

    @property
    def seconds(self) -> float:
        return max(self.compute_s, self.memory_s) + self.overhead_s

    @property
    def bottleneck(self) -> str:
        return "compute" if self.compute_s >= self.memory_s else "memory"


@dataclasses.dataclass(frozen=True)
class Measurement:
    """Result of one simulated hardware measurement of (instance, schedule)."""

    seconds: float | None        # None => invalid schedule for this instance
    measure_cost_s: float        # virtual harness time spent (compile + runs)
    breakdown: CostBreakdown | None = None
    adapted: bool = False
    cached: bool = False         # served from a CachedRunner without re-measuring
    pruned: bool = False         # dropped by a PruningRunner draft, never built

    @property
    def valid(self) -> bool:
        return self.seconds is not None


def _esize(dtype: str) -> int:
    return DTYPE_BYTES[dtype]


def _operand_fetches(order: Sequence[str], trips: Mapping[str, int], dep: set[str]) -> int:
    """Number of HBM fetch events for an operand whose block index depends on
    axes `dep`, under Pallas consecutive-revisit caching.

    The block stays VMEM-resident across the innermost contiguous run of grid
    axes it does NOT depend on; every other step boundary re-fetches it.
    """
    run = 1
    for axis in reversed(order):
        if axis in dep:
            break
        run *= trips[axis]
    total = math.prod(trips[a] for a in order)
    return max(1, total // run)


# ---------------------------------------------------------------------------
# Matmul family
# ---------------------------------------------------------------------------


def _epilogue_flops_per_elem(class_id: str) -> float:
    return {
        "matmul": 0.0,
        "matmul_bias": 1.0,
        "matmul_bias_gelu": 9.0,
        "matmul_silu_glu": 4.0,      # silu(x1)*x2 over N/2 outputs ≈ 4/elem of N
        "matmul_gelu_glu": 5.5,
        "matmul_residual": 1.0,
        "matmul_lmhead": 0.0,
        "matmul_lmhead_softcap": 12.0,  # tanh softcap
        "moe_gemm_silu_glu": 4.0,
        "moe_router": 6.0,           # softmax over experts
    }.get(class_id, 1.0)


def _matmul_cost(cs: ConcreteSchedule, spec: ChipSpec) -> CostBreakdown:
    inst, sched = cs.instance, cs.schedule
    p = inst.p
    M, N, K = p["M"], p["N"], p["K"]
    E = p.get("E", 1)
    bm, bn, bk = cs.t["M"], cs.t["N"], cs.t["K"]
    es = _esize(inst.dtype)

    # MoE grouped GEMM: E independent (M/E, N, K) problems (average routing),
    # plus dispatch/combine gather-scatter traffic over the token dim.
    m_eff = max(1, M // E)
    order = [a for a in cs.order if a != "E"]
    trips = {"M": max(1, math.ceil(m_eff / bm)), "N": math.ceil(N / bn), "K": math.ceil(K / bk)}

    # --- compute term ---
    flops = 2.0 * m_eff * N * K * E
    epi = _epilogue_flops_per_elem(inst.class_id) * m_eff * N * E
    mxu_eff = (
        dim_efficiency(bk, spec.mxu_dim)
        * dim_efficiency(bn, spec.mxu_dim)
        * dim_efficiency(min(bm, m_eff), spec.vreg_sublanes)
    )
    if bn % sched.vec != 0:
        mxu_eff *= 0.85  # vectorized innermost tile misaligned with lane tile
    vpu_flops = spec.peak_flops_bf16 / 16.0
    compute_s = flops / (spec.peak_flops_bf16 * max(mxu_eff, 1e-3)) + epi / vpu_flops

    # --- memory term (order-dependent HBM traffic) ---
    fetches_a = _operand_fetches(order, trips, {"M", "K"})
    fetches_b = _operand_fetches(order, trips, {"K", "N"})
    bytes_a = fetches_a * bm * bk * es
    bytes_b = fetches_b * bk * bn * es
    out_tiles = trips["M"] * trips["N"]
    if _acc_resident(order):
        bytes_c = out_tiles * bm * bn * es  # written once
    else:
        # accumulator revisited non-consecutively: spill+reload per K segment
        fetches_c = _operand_fetches(order, trips, {"M", "N"})
        bytes_c = 2 * fetches_c * bm * bn * es
    hbm = (bytes_a + bytes_b + bytes_c) * E
    if E > 1:
        hbm += 2.0 * M * K * es  # token dispatch + combine
    memory_s = hbm / spec.hbm_bandwidth

    # --- VMEM validity ---
    acc_bytes = bm * bn * (4 if sched.cache_write else es)
    vmem = 2 * (bm * bk + bk * bn) * es + acc_bytes + bm * bn * es
    if vmem > spec.vmem_capacity:
        raise ScheduleInvalid(f"VMEM overflow: {vmem} > {spec.vmem_capacity}")

    # --- overheads ---
    steps = math.prod(trips.values()) * E
    step_overhead = 60e-9 / (1.0 + sched.unroll / 8.0)
    icache_penalty = 1.05 if (sched.unroll >= 256 and bm * bn >= 128 * 128) else 1.0
    fill = 2.0 / max(steps, 2)
    overhead = spec.kernel_launch_overhead_s + steps * step_overhead
    base = max(compute_s * icache_penalty, memory_s) * (1.0 + fill)
    return CostBreakdown(
        compute_s=compute_s * icache_penalty,
        memory_s=memory_s,
        overhead_s=overhead + (base - max(compute_s * icache_penalty, memory_s)),
        flops=flops + epi,
        hbm_bytes=hbm,
        vmem_bytes=vmem,
    )


def _acc_resident(order: Sequence[str]) -> bool:
    """Output accumulator stays VMEM-resident iff K is the innermost axis."""
    return order[-1] == "K"


# ---------------------------------------------------------------------------
# Attention family (flash attention with q/kv tiling)
# ---------------------------------------------------------------------------


def _attention_cost(cs: ConcreteSchedule, spec: ChipSpec) -> CostBreakdown:
    inst, sched = cs.instance, cs.schedule
    p = inst.p
    Q, KV = p["Q"], p["KV"]
    H = p.get("H", 1)
    D = p.get("D", 128)
    B = p.get("B", 1)
    window = p.get("window", 0)
    bq, bkv = cs.t["Q"], cs.t["KV"]
    es = _esize(inst.dtype)

    causal = inst.class_id in ("flash_attention_causal", "flash_attention_swa",
                               "flash_attention_local", "flash_attention_softcap")
    if window > 0:
        frac = min(1.0, (window + bq) / KV)
    elif causal and Q == KV:
        frac = 0.5 + bkv / (2.0 * KV)
    else:
        frac = 1.0

    flops = 4.0 * B * H * Q * KV * D * frac            # QK^T + PV
    vpu = 10.0 * B * H * Q * KV * frac                 # softmax, scaling, softcap
    if "softcap" in inst.class_id:
        vpu *= 1.6
    mxu_eff = (
        dim_efficiency(bkv, spec.mxu_dim)
        * dim_efficiency(D, spec.mxu_dim)
        * dim_efficiency(min(bq, Q), spec.vreg_sublanes)
    )
    compute_s = flops / (spec.peak_flops_bf16 * max(mxu_eff, 1e-3)) + vpu / (spec.peak_flops_bf16 / 16.0)

    trips_q = max(1, math.ceil(Q / bq))
    trips_kv = max(1, math.ceil(KV / bkv))
    q_outer = cs.order[0] == "Q"
    if q_outer:
        # stream K/V per q block (classic flash): K/V re-read per q tile
        bytes_ = B * H * (Q * D * es + 2 * KV * D * es * trips_q * frac + Q * D * es)
    else:
        # kv outer: q re-read per kv tile + softmax stats/acc spill per kv tile
        bytes_ = B * H * (Q * D * es * trips_kv + 2 * KV * D * es * frac
                          + 2 * Q * D * 4 * trips_kv + Q * D * es)
    memory_s = bytes_ / spec.hbm_bandwidth

    acc_bytes = bq * D * (4 if sched.cache_write else es) + bq * 8  # acc + m/l stats
    vmem = 2 * (bq * D + 2 * bkv * D) * es + bq * bkv * es + acc_bytes
    if vmem > spec.vmem_capacity:
        raise ScheduleInvalid(f"VMEM overflow: {vmem} > {spec.vmem_capacity}")

    steps = B * H * trips_q * trips_kv
    step_overhead = 80e-9 / (1.0 + sched.unroll / 8.0)
    fill = 2.0 / max(steps, 2)
    overhead = spec.kernel_launch_overhead_s + steps * step_overhead
    base = max(compute_s, memory_s)
    return CostBreakdown(
        compute_s=compute_s,
        memory_s=memory_s,
        overhead_s=overhead + base * fill,
        flops=flops + vpu,
        hbm_bytes=bytes_,
        vmem_bytes=vmem,
    )


# ---------------------------------------------------------------------------
# Recurrent-scan family (rwkv6 wkv, RG-LRU)
# ---------------------------------------------------------------------------


def _scan_cost(cs: ConcreteSchedule, spec: ChipSpec) -> CostBreakdown:
    inst, sched = cs.instance, cs.schedule
    p = inst.p
    T, C = p["T"], p["C"]
    B = p.get("B", 1)
    D = p.get("D", 64)  # head dim (state is DxD per head for rwkv6)
    ct, bc = cs.t["T"], cs.t["C"]
    es = _esize(inst.dtype)

    if inst.class_id == "rwkv6_scan":
        flops = 4.0 * B * T * C * D     # decay/update/readout of DxD states
        state_bytes = B * C * D * 4
        intensity_unit = spec.peak_flops_bf16 / 8.0   # outer products: VPU+MXU mix
    else:  # rglru_scan
        flops = 10.0 * B * T * C
        state_bytes = B * C * 4
        intensity_unit = spec.peak_flops_bf16 / 16.0  # pure VPU elementwise

    lane_eff = dim_efficiency(bc, spec.vreg_lanes) * dim_efficiency(min(ct, T), spec.vreg_sublanes)
    compute_s = flops / (intensity_unit * max(lane_eff, 1e-3))

    io_streams = 4 if inst.class_id == "rwkv6_scan" else 3  # x,(r,k,v,w..) approximated
    bytes_ = B * T * C * es * io_streams + B * T * C * es + 2 * state_bytes
    memory_s = bytes_ / spec.hbm_bandwidth

    vmem = 2 * ct * bc * es * io_streams + bc * D * 4 + ct * bc * es
    if vmem > spec.vmem_capacity:
        raise ScheduleInvalid(f"VMEM overflow: {vmem} > {spec.vmem_capacity}")

    chunks = max(1, math.ceil(T / ct)) * max(1, math.ceil(C / bc)) * B
    step_overhead = 120e-9 / (1.0 + sched.unroll / 8.0)
    fill = 2.0 / max(chunks, 2)
    overhead = spec.kernel_launch_overhead_s + chunks * step_overhead
    base = max(compute_s, memory_s)
    return CostBreakdown(
        compute_s=compute_s,
        memory_s=memory_s,
        overhead_s=overhead + base * fill,
        flops=flops,
        hbm_bytes=bytes_,
        vmem_bytes=vmem,
    )


_FAMILY_COST = {"matmul": _matmul_cost, "attention": _attention_cost, "scan": _scan_cost}


def evaluate(cs: ConcreteSchedule, spec: ChipSpec = TPU_V5E) -> CostBreakdown:
    """Deterministic cost of a concrete (instance, schedule) binding.

    Raises ScheduleInvalid on structural violations (VMEM overflow,
    parallelized reduction axis).
    """
    sched = cs.schedule
    reduction = {"matmul": "K", "attention": "KV", "scan": "T"}[cs.instance.family]
    if reduction in sched.order[: sched.parallel]:
        raise ScheduleInvalid(f"reduction axis {reduction} marked parallel")
    return _FAMILY_COST[cs.instance.family](cs, spec)


# ---------------------------------------------------------------------------
# Measurement simulator (the "hardware" the auto-scheduler talks to)
# ---------------------------------------------------------------------------


def _noise_factor(instance: KernelInstance, schedule: Schedule, seed: int, sigma: float) -> float:
    blob = f"{instance.workload_key()}|{schedule.to_json()}|{seed}".encode()
    h = hashlib.sha256(blob).digest()
    u1 = struct.unpack("<I", h[:4])[0] / 2**32
    u2 = struct.unpack("<I", h[4:8])[0] / 2**32
    u1 = min(max(u1, 1e-12), 1 - 1e-12)
    z = math.sqrt(-2.0 * math.log(u1)) * math.cos(2 * math.pi * u2)
    return math.exp(sigma * z)


def measure(
    instance: KernelInstance,
    schedule: Schedule,
    *,
    mode: str = "strict",
    seed: int = 0,
    noise_sigma: float = 0.05,
    spec: ChipSpec = TPU_V5E,
) -> Measurement:
    """Simulate one build+measure of `schedule` applied to `instance`."""
    try:
        cs = concretize(schedule, instance, mode=mode)
        bd = evaluate(cs, spec)
    except ScheduleInvalid:
        return Measurement(seconds=None, measure_cost_s=FAILED_COMPILE_S)
    secs = bd.seconds * _noise_factor(instance, schedule, seed, noise_sigma)
    cost = COMPILE_S + RUN_REPEATS * max(secs, MIN_RUN_S) + RUN_OVERHEAD_S
    return Measurement(seconds=secs, measure_cost_s=cost, breakdown=bd, adapted=cs.adapted)


def kernel_seconds(instance: KernelInstance, schedule: Schedule | None = None,
                   mode: str = "strict", spec: ChipSpec = TPU_V5E) -> float:
    """Noise-free cost (used for ground-truth model totals and P_c shares)."""
    schedule = schedule or default_schedule(instance)
    cs = concretize(schedule, instance, mode=mode)
    return evaluate(cs, spec).seconds


def model_seconds(uses: Sequence[KernelUse], schedule_map: Mapping[str, Schedule] | None = None,
                  mode: str = "strict", spec: ChipSpec = TPU_V5E) -> float:
    """End-to-end model cost = Σ use_count × kernel cost under chosen schedules.

    ``schedule_map`` maps workload_key -> Schedule; missing entries fall back
    to the untuned default (exactly the paper's partially-tuned setting).
    """
    total = 0.0
    for u in uses:
        sched = None
        if schedule_map is not None:
            sched = schedule_map.get(u.instance.workload_key())
        total += u.use_count * kernel_seconds(u.instance, sched, mode=mode, spec=spec)
    return total


def contextual_model_seconds(uses: Sequence[KernelUse],
                             schedule_map: Mapping[str, Schedule] | None = None,
                             mode: str = "strict", coupling: float = 0.08,
                             spec: ChipSpec = TPU_V5E) -> float:
    """Model cost including inter-kernel cache-residency coupling (§5.5).

    Standalone kernel latency ignores that kernel A's output tiling dictates
    the VMEM/cache residency kernel B reads it back with.  We model the
    coupling as a memory-term penalty proportional to the (log) mismatch
    between the producer's output tile width (bn) and the consumer's
    reduction streaming tile (bk): perfectly matched tiles re-use resident
    blocks; mismatched tiles re-fetch.  This is what makes "fastest
    standalone" an imperfect proxy — the paper's mixed-pool regression.
    """
    total = 0.0
    prev_cs = None
    for u in uses:
        sched = None
        if schedule_map is not None:
            sched = schedule_map.get(u.instance.workload_key())
        sched = sched or default_schedule(u.instance)
        cs = concretize(sched, u.instance, mode=mode)
        bd = evaluate(cs, spec)
        sec = bd.seconds
        if (prev_cs is not None and u.instance.family == "matmul"
                and prev_cs.instance.family == "matmul"):
            bn_p = prev_cs.t.get("N")
            bk_c = cs.t.get("K")
            if bn_p and bk_c:
                mismatch = min(abs(math.log2(bn_p / bk_c)) / 4.0, 1.0)
                mem_frac = bd.memory_s / max(bd.seconds, 1e-30)
                sec *= 1.0 + coupling * mismatch * mem_frac
        total += u.use_count * sec
        prev_cs = cs
    return total


def class_proportions(uses: Sequence[KernelUse], spec: ChipSpec = TPU_V5E,
                      seconds_fn=None) -> dict[str, float]:
    """P_c: share of *untuned* model time per kernel class (paper Table 2).

    ``seconds_fn(instance) -> float`` overrides the untuned-seconds source
    (e.g. a memoizing MeasureRunner's ``seconds`` query).
    """
    fn = seconds_fn or (lambda inst: kernel_seconds(inst, None, spec=spec))
    per_class: dict[str, float] = {}
    for u in uses:
        sec = u.use_count * fn(u.instance)
        per_class[u.instance.class_id] = per_class.get(u.instance.class_id, 0.0) + sec
    total = sum(per_class.values()) or 1.0
    return {c: s / total for c, s in per_class.items()}
