"""Donor-model selection heuristic (paper §4.4, Eq. 1).

For a target model M with kernel classes C, choose the donor T maximizing

    score(T) = Σ_{c ∈ C}  P_c² · sqrt(|W_Tc|)

where P_c is class c's share of M's *untuned* inference time and W_Tc the set
of tuned schedules of class c available from T.  Squaring P_c boosts the
influence of expensive classes; the square root damps donors with very many
schedules (paper's stated rationale).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence

from repro.core.cost_model import class_proportions
from repro.core.database import ScheduleDB
from repro.core.runner import MeasureRunner
from repro.core.workload import KernelUse
from repro.targets import target_name


@dataclasses.dataclass(frozen=True)
class DonorScore:
    model_id: str
    score: float
    per_class: tuple[tuple[str, float], ...]  # class -> contribution


def _proportions(uses: Sequence[KernelUse], runner: MeasureRunner | None) -> dict[str, float]:
    """P_c via the injected runner (sharing its noise-free seconds cache),
    falling back to the bare cost model when no runner is given."""
    if runner is None:
        return class_proportions(uses)
    return class_proportions(uses, seconds_fn=lambda inst: runner.seconds(inst, None))


def donor_scores(
    uses: Sequence[KernelUse],
    db: ScheduleDB,
    exclude: Sequence[str] = (),
    proportions: Mapping[str, float] | None = None,
    runner: MeasureRunner | None = None,
    donor_target=None,
) -> list[DonorScore]:
    """Rank all donor models in the DB for this target model (descending).

    ``donor_target`` names the hardware namespace the candidate pool is
    drawn from (default: the runner's target, i.e. same-target transfer);
    |W_Tc| only counts schedules tuned on that chip.  P_c shares come from
    the runner's own target — the model will *run* there.
    """
    p = dict(proportions) if proportions is not None else _proportions(uses, runner)
    dt = target_name(donor_target if donor_target is not None
                     else (runner.target if runner is not None else None))
    scores: list[DonorScore] = []
    for model_id in db.models(target=dt):
        if model_id in exclude:
            continue
        counts = db.class_counts(model_id, target=dt)
        contrib = []
        total = 0.0
        for c, pc in p.items():
            n = counts.get(c, 0)
            s = (pc ** 2) * math.sqrt(n)
            if s > 0:
                contrib.append((c, s))
            total += s
        scores.append(DonorScore(model_id=model_id, score=total, per_class=tuple(contrib)))
    scores.sort(key=lambda s: (-s.score, s.model_id))
    return scores


def select_donor(uses: Sequence[KernelUse], db: ScheduleDB,
                 exclude: Sequence[str] = (),
                 runner: MeasureRunner | None = None,
                 donor_target=None) -> str | None:
    ranked = donor_scores(uses, db, exclude=exclude, runner=runner,
                          donor_target=donor_target)
    if not ranked or ranked[0].score <= 0.0:
        return None
    return ranked[0].model_id


def top_donors(uses: Sequence[KernelUse], db: ScheduleDB, k: int = 3,
               exclude: Sequence[str] = (),
               runner: MeasureRunner | None = None,
               donor_target=None) -> list[DonorScore]:
    """Top-k choices (paper Table 3)."""
    return donor_scores(uses, db, exclude=exclude, runner=runner,
                        donor_target=donor_target)[:k]


# ---------------------------------------------------------------------------
# Beyond-paper: compatibility-aware donor selection (the paper's §4.4.2
# future-work direction — "a better predictive model of which schedules may
# perform well").  Eq. 1 counts schedules but ignores whether their tiles
# can legally bind to the target's extents; divisibility is *static*
# (zero measurement cost), so we weight each class contribution by the
# fraction of the donor's schedules that strictly concretize on the
# target's kernels of that class.
# ---------------------------------------------------------------------------


def donor_scores_v2(
    uses: Sequence[KernelUse],
    db: ScheduleDB,
    exclude: Sequence[str] = (),
    proportions: Mapping[str, float] | None = None,
    runner: MeasureRunner | None = None,
    donor_target=None,
) -> list[DonorScore]:
    from repro.core.schedule import is_valid

    p = dict(proportions) if proportions is not None else _proportions(uses, runner)
    dt = target_name(donor_target if donor_target is not None
                     else (runner.target if runner is not None else None))
    targets_by_class: dict[str, list] = {}
    for u in uses:
        targets_by_class.setdefault(u.instance.class_id, []).append(u.instance)

    scores: list[DonorScore] = []
    for model_id in db.models(target=dt):
        if model_id in exclude:
            continue
        counts = db.class_counts(model_id, target=dt)
        contrib = []
        total = 0.0
        for c, pc in p.items():
            n = counts.get(c, 0)
            if n == 0:
                continue
            recs = db.by_class(c, [model_id], target=dt)
            pairs = [(r, t) for r in recs for t in targets_by_class.get(c, [])]
            compat = (sum(is_valid(r.schedule, t) for r, t in pairs) / len(pairs)
                      if pairs else 0.0)
            s = (pc ** 2) * math.sqrt(n) * compat
            if s > 0:
                contrib.append((c, s))
            total += s
        scores.append(DonorScore(model_id=model_id, score=total, per_class=tuple(contrib)))
    scores.sort(key=lambda s: (-s.score, s.model_id))
    return scores


def select_donor_v2(uses: Sequence[KernelUse], db: ScheduleDB,
                    exclude: Sequence[str] = (),
                    runner: MeasureRunner | None = None,
                    donor_target=None) -> str | None:
    ranked = donor_scores_v2(uses, db, exclude=exclude, runner=runner,
                             donor_target=donor_target)
    if not ranked or ranked[0].score <= 0.0:
        return None
    return ranked[0].model_id
