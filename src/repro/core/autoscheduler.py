"""Auto-scheduler: the Ansor analogue this framework tunes kernels with.

Structure mirrors Ansor (Zheng et al., OSDI'20) at the granularity the paper
relies on:

* per-kernel *tasks*, each searching the schedule space of one workload;
* evolutionary search: a population of schedules, mutation + crossover,
  ranked by a learned surrogate (ridge regression on schedule features),
  with only the top candidates sent to "hardware" measurement through a
  pluggable :class:`repro.core.runner.MeasureRunner` (default: memoized
  analytical model with seeded noise);
* a task scheduler that allocates measurement trials across kernels
  proportionally to their share of remaining model time (Ansor §5);
* a search trace — (cumulative virtual search seconds, best model seconds) —
  which the benchmarks use for the paper's "same search time" and
  "time to match" comparisons (Figs. 1/5, Table 4).
"""
from __future__ import annotations

import dataclasses
import math
import random
import time
from typing import Callable, Sequence

import numpy as np

from repro.core.cost_model import Measurement
from repro.core.database import Record, ScheduleDB
from repro.core.runner import MeasureRunner, resolve_runner, telemetry_delta
from repro.targets import DEFAULT_TARGET
from repro.core.schedule import (
    UNROLL_CHOICES,
    VEC_CHOICES,
    Schedule,
    default_schedule,
)
from repro.core.workload import KernelInstance, KernelUse, class_axes

#: Candidate tile sizes: powers of two plus the 3× and 5× series (384 = 3·128
#: etc.) — TPU-friendly multiples of the (8, 128) VREG tile that divide the
#: d_model/d_ff families of real architectures (2304 = 9·256, 5120 = 5·1024).
TILE_POOL = tuple(sorted(
    {1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048}
    | {3, 6, 12, 24, 48, 96, 192, 384, 768, 1536}
    | {5, 10, 20, 40, 80, 160, 320, 640, 1280, 2560}
))


def _divisor_tiles(extent: int) -> list[int]:
    """Candidate tile sizes for an extent: divisors near hardware-friendly sizes."""
    out = sorted({d for d in TILE_POOL if d <= extent and extent % d == 0})
    if not out:
        out = [1]
    if extent <= 2048 and extent not in out:
        out.append(extent)
    return out


def random_schedule(instance: KernelInstance, rng: random.Random) -> Schedule:
    axes = class_axes(instance.class_id)
    tiles = {a: rng.choice(_divisor_tiles(instance.extent(a))) for a in axes}
    reduction = {"matmul": "K", "attention": "KV", "scan": "T"}[instance.family]
    non_reduction = [a for a in axes if a != reduction]
    rng.shuffle(non_reduction)
    # Reduction axis position: anywhere but first (keeps ≥1 parallelizable axis).
    pos = rng.randrange(1, len(axes))
    order = non_reduction[:]
    order.insert(pos, reduction)
    parallel = rng.randint(1, max(1, order.index(reduction)))
    return Schedule.make(
        instance.class_id,
        tiles=tiles,
        order=order,
        parallel=parallel,
        unroll=rng.choice(UNROLL_CHOICES),
        vec=rng.choice(VEC_CHOICES),
        cache_write=rng.random() < 0.7,
        source=instance.workload_key(),
    )


def mutate(schedule: Schedule, instance: KernelInstance, rng: random.Random) -> Schedule:
    axes = class_axes(instance.class_id)
    kind = rng.choice(("tile", "tile", "tile", "order", "unroll", "vec", "cache"))
    tiles = schedule.t
    order = list(schedule.order)
    parallel, unroll, vec, cache = schedule.parallel, schedule.unroll, schedule.vec, schedule.cache_write
    if kind == "tile":
        a = rng.choice(axes)
        choices = _divisor_tiles(instance.extent(a))
        tiles[a] = rng.choice(choices)
    elif kind == "order":
        reduction = {"matmul": "K", "attention": "KV", "scan": "T"}[instance.family]
        i, j = rng.sample(range(len(order)), 2) if len(order) >= 2 else (0, 0)
        order[i], order[j] = order[j], order[i]
        if order[0] == reduction:  # keep one leading parallelizable axis
            order[0], order[1] = order[1], order[0]
        parallel = min(parallel, max(1, order.index(reduction)))
    elif kind == "unroll":
        unroll = rng.choice(UNROLL_CHOICES)
    elif kind == "vec":
        vec = rng.choice(VEC_CHOICES)
    else:
        cache = not cache
    return Schedule.make(
        schedule.class_id, tiles=tiles, order=order, parallel=parallel,
        unroll=unroll, vec=vec, cache_write=cache, source=instance.workload_key(),
    )


def crossover(a: Schedule, b: Schedule, rng: random.Random) -> Schedule:
    tiles = {ax: (a.t[ax] if rng.random() < 0.5 else b.t[ax]) for ax in a.t}
    donor = a if rng.random() < 0.5 else b
    return Schedule.make(
        a.class_id, tiles=tiles, order=donor.order, parallel=donor.parallel,
        unroll=(a if rng.random() < 0.5 else b).unroll,
        vec=(a if rng.random() < 0.5 else b).vec,
        cache_write=(a if rng.random() < 0.5 else b).cache_write,
        source=a.source,
    )


# ---------------------------------------------------------------------------
# Surrogate cost model (Ansor's learned model, here: ridge on features)
# ---------------------------------------------------------------------------


def featurize(schedule: Schedule, instance: KernelInstance) -> np.ndarray:
    axes = class_axes(instance.class_id)
    f: list[float] = []
    for a in axes:
        t, e = schedule.t[a], instance.extent(a)
        f += [math.log2(t), math.log2(max(e // t, 1)), float(t % 128 == 0), float(t % 8 == 0)]
    for a in axes:
        f.append(float(schedule.order.index(a)) / len(axes))
    f += [
        float(schedule.parallel),
        math.log2(schedule.unroll + 1),
        math.log2(schedule.vec),
        float(schedule.cache_write),
    ]
    return np.asarray(f, dtype=np.float64)


class Surrogate:
    def __init__(self, lam: float = 1e-2):
        self.lam = lam
        self._x: list[np.ndarray] = []
        self._y: list[float] = []
        self._w: np.ndarray | None = None

    def add(self, feat: np.ndarray, seconds: float) -> None:
        self._x.append(feat)
        self._y.append(math.log(max(seconds, 1e-12)))
        self._w = None

    def _fit(self) -> None:
        x = np.stack(self._x)
        x = np.concatenate([x, np.ones((len(x), 1))], axis=1)
        y = np.asarray(self._y)
        a = x.T @ x + self.lam * np.eye(x.shape[1])
        self._w = np.linalg.solve(a, x.T @ y)

    def predict(self, feats: Sequence[np.ndarray]) -> np.ndarray:
        if len(self._x) < 8:
            return np.zeros(len(feats))  # no signal yet: random ranking
        if self._w is None:
            self._fit()
        x = np.stack(list(feats))
        x = np.concatenate([x, np.ones((len(x), 1))], axis=1)
        return x @ self._w


# ---------------------------------------------------------------------------
# Per-kernel evolutionary search task
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TracePoint:
    search_time_s: float   # cumulative virtual search seconds
    best_seconds: float    # best (noise-free ranked by noisy measurement) kernel/model seconds
    trials: int


@dataclasses.dataclass
class TuneResult:
    best: Schedule
    best_seconds: float
    trials: int
    search_time_s: float
    trace: list[TracePoint]
    wall_time_s: float
    runner_telemetry: dict = dataclasses.field(default_factory=dict)
    target: str = DEFAULT_TARGET   # chip the search measured on


class KernelTask:
    """Evolutionary search state for one kernel workload.

    Measurement goes through the injected ``runner`` (one may be shared
    across tasks to pool caching); the default is a fresh memoizing
    analytical runner for ``target`` (the two must agree when both given —
    the task's records belong in that target's namespace).
    """

    def __init__(self, instance: KernelInstance, seed: int, noise_sigma: float = 0.05,
                 population: int = 32, measure_per_round: int = 8,
                 runner: MeasureRunner | None = None, target=None):
        self.instance = instance
        # int(hex_key) not hash(): str hash is salted per process and would
        # make tuning results non-reproducible across runs.
        self.rng = random.Random(seed ^ (int(instance.workload_key(), 16) & 0xFFFFFFFF))
        self.noise_sigma = noise_sigma
        self.population = population
        self.measure_per_round = measure_per_round
        self.runner, self.target = resolve_runner(runner, target)
        self.surrogate = Surrogate()
        self.seed = seed
        self.pool: list[tuple[Schedule, float]] = []  # measured (schedule, noisy seconds)
        self.trials = 0
        self.search_time_s = 0.0
        base = default_schedule(instance)
        m = self.runner.measure(instance, base, seed=seed, noise_sigma=0.0)
        assert m.valid, "default schedule must be valid"
        self.best_schedule: Schedule = base
        self.best_seconds: float = m.seconds
        self.untuned_seconds: float = m.seconds

    def _record(self, schedule: Schedule, m: Measurement) -> None:
        self.trials += 1
        self.search_time_s += m.measure_cost_s
        if m.pruned:
            return
        if m.valid:
            self.pool.append((schedule, m.seconds))
            self.surrogate.add(featurize(schedule, self.instance), m.seconds)
            if m.seconds < self.best_seconds:
                self.best_seconds = m.seconds
                self.best_schedule = schedule

    def _measure_batch(self, schedules: Sequence[Schedule]) -> None:
        ms = self.runner.measure_many(self.instance, schedules, seed=self.seed,
                                      noise_sigma=self.noise_sigma)
        for s, m in zip(schedules, ms):
            self._record(s, m)

    def step(self, budget_trials: int) -> None:
        """Run measurement rounds until `budget_trials` more trials are spent."""
        spent = 0
        while spent < budget_trials:
            candidates: list[Schedule] = []
            if len(self.pool) < 4:
                candidates = [random_schedule(self.instance, self.rng)
                              for _ in range(self.measure_per_round * 4)]
            else:
                elite = sorted(self.pool, key=lambda p: p[1])[: self.population // 2]
                for _ in range(self.measure_per_round * 6):
                    r = self.rng.random()
                    if r < 0.5:
                        parent = self.rng.choice(elite)[0]
                        candidates.append(mutate(parent, self.instance, self.rng))
                    elif r < 0.75 and len(elite) >= 2:
                        a, b = self.rng.sample(elite, 2)
                        candidates.append(crossover(a[0], b[0], self.rng))
                    else:
                        candidates.append(random_schedule(self.instance, self.rng))
            feats = [featurize(c, self.instance) for c in candidates]
            pred = self.surrogate.predict(feats)
            ranked = [c for _, c in sorted(zip(pred, candidates), key=lambda t: t[0])]
            n = min(self.measure_per_round, budget_trials - spent)
            self._measure_batch(ranked[:n])
            spent += n


def tune_kernel(instance: KernelInstance, trials: int = 128, seed: int = 0,
                noise_sigma: float = 0.05,
                runner: MeasureRunner | None = None, target=None) -> TuneResult:
    t0 = time.monotonic()
    runner, tname = resolve_runner(runner, target)
    before = runner.telemetry()
    task = KernelTask(instance, seed=seed, noise_sigma=noise_sigma, runner=runner)
    trace: list[TracePoint] = []
    batch = max(8, trials // 16)
    while task.trials < trials:
        task.step(min(batch, trials - task.trials))
        trace.append(TracePoint(task.search_time_s, task.best_seconds, task.trials))
    return TuneResult(
        best=task.best_schedule, best_seconds=task.best_seconds, trials=task.trials,
        search_time_s=task.search_time_s, trace=trace, wall_time_s=time.monotonic() - t0,
        runner_telemetry=telemetry_delta(runner.telemetry(), before),
        target=tname,
    )


# ---------------------------------------------------------------------------
# Whole-model tuning with an Ansor-style task scheduler
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ModelTuneResult:
    model_id: str
    records: list[Record]
    total_trials: int
    search_time_s: float
    wall_time_s: float
    untuned_seconds: float
    tuned_seconds: float
    trace: list[TracePoint]   # (search time, best *model* seconds)
    runner_telemetry: dict = dataclasses.field(default_factory=dict)
    target: str = DEFAULT_TARGET   # chip the search measured on

    @property
    def speedup(self) -> float:
        return self.untuned_seconds / self.tuned_seconds


def tune_model(
    uses: Sequence[KernelUse],
    model_id: str,
    total_trials: int = 1024,
    seed: int = 0,
    noise_sigma: float = 0.05,
    round_trials: int = 16,
    stop_when: Callable[[float, float], bool] | None = None,
    runner: MeasureRunner | None = None,
    target=None,
) -> ModelTuneResult:
    """Tune every kernel of a model under a shared trial budget.

    Trials are allocated Ansor-style: each round goes to the task with the
    largest expected gain, estimated as (current share of model time) ×
    (recent relative improvement + exploration bonus).

    ``stop_when(search_time_s, model_seconds)`` allows the benchmarks to cut
    the search at a given virtual time or speedup (paper's same-time /
    time-to-match comparisons).  One ``runner`` is shared across all kernel
    tasks, so a memoizing runner dedups measurements model-wide.  ``target``
    selects the chip to tune for; the emitted records land in its namespace.
    """
    t0 = time.monotonic()
    runner, tname = resolve_runner(runner, target)
    tele_before = runner.telemetry()
    tasks = [KernelTask(u.instance, seed=seed, noise_sigma=noise_sigma, runner=runner)
             for u in uses]
    weights = [u.use_count for u in uses]
    improv = [1.0] * len(tasks)  # optimistic init → round-robin warmup

    def model_now() -> float:
        return sum(w * t.best_seconds for w, t in zip(weights, tasks))

    untuned = model_now()
    trace: list[TracePoint] = []
    spent = 0
    while spent < total_trials:
        shares = [w * t.best_seconds for w, t in zip(weights, tasks)]
        total_share = sum(shares) or 1.0
        scores = [
            (shares[i] / total_share) * (improv[i] + 0.05 / (1 + tasks[i].trials / 64))
            for i in range(len(tasks))
        ]
        i = max(range(len(tasks)), key=lambda j: scores[j])
        before = tasks[i].best_seconds
        n = min(round_trials, total_trials - spent)
        tasks[i].step(n)
        spent += n
        after = tasks[i].best_seconds
        improv[i] = 0.7 * improv[i] + 0.3 * ((before - after) / before if before > 0 else 0.0)
        st = sum(t.search_time_s for t in tasks)
        now = model_now()
        trace.append(TracePoint(st, now, spent))
        if stop_when is not None and stop_when(st, now):
            break

    # Emit the top-k distinct schedules per kernel (Ansor's log retains every
    # measurement; transfer-tuning's candidate pool draws from them).
    records = []
    for t in tasks:
        seen: set = set()
        for sched, secs in sorted(t.pool, key=lambda p: p[1]):
            key = sched.to_json().__repr__()
            if key in seen:
                continue
            seen.add(key)
            records.append(Record(instance=t.instance, schedule=sched, seconds=secs,
                                  model_id=model_id, trials=t.trials, target=tname))
            if len(seen) >= 5:
                break
        if not seen:  # no valid measured schedule: record the default-based best
            records.append(Record(instance=t.instance, schedule=t.best_schedule,
                                  seconds=t.best_seconds, model_id=model_id,
                                  trials=t.trials, target=tname))
    return ModelTuneResult(
        model_id=model_id,
        records=records,
        total_trials=spent,
        search_time_s=sum(t.search_time_s for t in tasks),
        wall_time_s=time.monotonic() - t0,
        untuned_seconds=untuned,
        tuned_seconds=model_now(),
        trace=trace,
        runner_telemetry=telemetry_delta(runner.telemetry(), tele_before),
        target=tname,
    )


def tune_model_into_db(db: ScheduleDB, uses: Sequence[KernelUse], model_id: str,
                       **kw) -> ModelTuneResult:
    res = tune_model(uses, model_id, **kw)
    for r in res.records:
        db.add(r)
    return res
