"""Pluggable measurement runners: the seam between search and "hardware".

Every layer of the tuning stack (evolutionary search, transfer-tuning, the
donor heuristic, benchmarks) needs the answer to one question — "how fast is
schedule S on instance I?" — and historically each called
:func:`repro.core.cost_model.measure` directly, serially, uncached.  This
module extracts that call behind a small protocol so the *policy* of
measurement (caching, batching, draft-then-verify pruning, and eventually a
real interpreted-Pallas backend) is injectable without touching the search
code.

Three implementations ship today:

* :class:`AnalyticalRunner` — wraps the analytical cost model one-to-one;
  behaviour-identical to the old direct calls.
* :class:`CachedRunner` — memoizes on ``(workload, schedule, mode, seed,
  noise_sigma)``.  Repeated donor schedules across target kernels, matrix
  cells, and benchmark passes are measured once; hits are free (zero virtual
  ``measure_cost_s``) and counted in :class:`RunnerStats`.
* :class:`PruningRunner` — Pruner-style (arXiv:2402.02361) draft-then-verify:
  ranks a candidate batch with the zero-cost noise-free analytical
  breakdown, then charges full virtual build+run seconds only for the
  ``verify_top_k`` drafts it actually verifies.  Pruned candidates come back
  with ``seconds=None`` and ``pruned=True`` so callers can distinguish them
  from invalid schedules.

The composition ``CachedRunner(AnalyticalRunner())`` is the default
everywhere (see :func:`default_runner`); ``PruningRunner(CachedRunner(...))``
is the aggressive search configuration.  See DESIGN.md for the worked
example.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.cost_model import Measurement, kernel_seconds, measure
from repro.core.schedule import Schedule, ScheduleInvalid
from repro.core.workload import KernelInstance
from repro.hw.specs import ChipSpec
from repro.targets import DEFAULT_TARGET, Target, resolve_target


@dataclasses.dataclass
class RunnerStats:
    """Per-runner-layer counters (each layer of a composition keeps its own)."""

    requests: int = 0          # measure() questions answered at this layer
    measurements: int = 0      # full cost-model evaluations actually performed
    cache_hits: int = 0
    cache_misses: int = 0
    drafts: int = 0            # zero-cost draft rankings performed
    pruned: int = 0            # candidates dropped without full measurement
    measure_cost_s: float = 0.0  # virtual harness seconds charged by this layer


class MeasureRunner:
    """Protocol + shared machinery for measurement backends.

    Subclasses implement :meth:`measure`; :meth:`measure_many` defaults to a
    serial loop and is the batching seam (PruningRunner overrides it, a
    future real-hardware runner would build candidates concurrently).
    """

    def __init__(self) -> None:
        self.stats = RunnerStats()

    @property
    def target(self) -> str:
        """Name of the hardware target this runner measures for.

        Wrapper layers inherit it from their inner runner; the innermost
        backend (AnalyticalRunner, or a future real-hardware runner) owns it.
        A runner measures exactly one target — per-target namespacing of
        schedule stores relies on this identity.
        """
        inner = getattr(self, "inner", None)
        if inner is not None:
            return inner.target
        return DEFAULT_TARGET

    # -- core protocol -------------------------------------------------------
    def measure(self, instance: KernelInstance, schedule: Schedule, *,
                mode: str = "strict", seed: int = 0,
                noise_sigma: float = 0.05) -> Measurement:
        raise NotImplementedError

    def measure_many(self, instance: KernelInstance, schedules: Sequence[Schedule],
                     *, mode: str = "strict", seed: int = 0,
                     noise_sigma: float = 0.05) -> list[Measurement]:
        """Measure a candidate batch for one instance (order-preserving)."""
        return [
            self.measure(instance, s, mode=mode, seed=seed, noise_sigma=noise_sigma)
            for s in schedules
        ]

    def seconds(self, instance: KernelInstance, schedule: Schedule | None = None,
                mode: str = "strict") -> float:
        """Noise-free ground-truth seconds (no virtual harness cost).

        Raises ScheduleInvalid if the schedule cannot bind to the instance.
        """
        return kernel_seconds(instance, schedule, mode=mode)

    # -- telemetry -----------------------------------------------------------
    def telemetry(self) -> dict[str, float]:
        """Flat counter dict merged across the runner composition."""
        out = {
            "requests": self.stats.requests,
            "measurements": self.stats.measurements,
            "cache_hits": self.stats.cache_hits,
            "cache_misses": self.stats.cache_misses,
            "drafts": self.stats.drafts,
            "pruned": self.stats.pruned,
            "measure_cost_s": self.stats.measure_cost_s,
        }
        inner = getattr(self, "inner", None)
        if inner is not None:
            for k, v in inner.telemetry().items():
                if k == "requests":
                    pass  # outermost layer owns the question count
                else:
                    # Summing is exact: each counter is incremented by exactly
                    # one layer kind (measurements by the innermost backend,
                    # hits/misses by caches, drafts/pruned + draft charges by
                    # pruners), so the total measure_cost_s matches the sum
                    # of per-Measurement charges callers accumulate.
                    out[k] = out.get(k, 0) + v
        return out


def telemetry_delta(after: dict[str, float], before: dict[str, float]) -> dict[str, float]:
    """Counter difference between two :meth:`MeasureRunner.telemetry` snapshots."""
    return {k: after[k] - before.get(k, 0) for k in after}


class AnalyticalRunner(MeasureRunner):
    """Bare analytical cost model — behaviour-identical to direct measure().

    ``target`` names the chip to model: a registered target name, a
    :class:`~repro.targets.Target`, a bare :class:`ChipSpec`, or ``None``
    (the default ``tpu-v5e``).
    """

    def __init__(self, target: "str | Target | ChipSpec | None" = None):
        super().__init__()
        t = resolve_target(target)
        self.spec = t.spec
        self._target_name = t.name

    @property
    def target(self) -> str:
        return self._target_name

    def measure(self, instance: KernelInstance, schedule: Schedule, *,
                mode: str = "strict", seed: int = 0,
                noise_sigma: float = 0.05) -> Measurement:
        m = measure(instance, schedule, mode=mode, seed=seed,
                    noise_sigma=noise_sigma, spec=self.spec)
        self.stats.requests += 1
        self.stats.measurements += 1
        self.stats.measure_cost_s += m.measure_cost_s
        return m

    def seconds(self, instance: KernelInstance, schedule: Schedule | None = None,
                mode: str = "strict") -> float:
        return kernel_seconds(instance, schedule, mode=mode, spec=self.spec)


class CachedRunner(MeasureRunner):
    """Memoizing wrapper: one full measurement per unique question.

    The key is ``(workload_key, schedule json, mode, seed, noise_sigma)`` —
    everything the simulated measurement depends on, including the noise
    seed, so caching is bit-transparent: a hit returns the stored
    measurement with ``measure_cost_s`` zeroed (the harness already paid for
    it exactly once) and ``cached=True``.
    """

    def __init__(self, inner: MeasureRunner | None = None):
        super().__init__()
        self.inner = inner if inner is not None else AnalyticalRunner()
        self._cache: dict[tuple, Measurement] = {}
        self._seconds_cache: dict[tuple, float | ScheduleInvalid] = {}

    def _key(self, instance: KernelInstance, schedule: Schedule, mode: str,
             seed: int, noise_sigma: float) -> tuple:
        # The target is part of the key: the cached answer is a property of
        # the chip the inner runner models, and keeping it explicit means a
        # future cross-runner cache merge cannot alias across targets.
        return (self.target, instance.workload_key(), repr(schedule.to_json()),
                mode, seed, noise_sigma)

    def measure(self, instance: KernelInstance, schedule: Schedule, *,
                mode: str = "strict", seed: int = 0,
                noise_sigma: float = 0.05) -> Measurement:
        self.stats.requests += 1
        key = self._key(instance, schedule, mode, seed, noise_sigma)
        hit = self._cache.get(key)
        if hit is not None:
            self.stats.cache_hits += 1
            return dataclasses.replace(hit, measure_cost_s=0.0, cached=True)
        self.stats.cache_misses += 1
        m = self.inner.measure(instance, schedule, mode=mode, seed=seed,
                               noise_sigma=noise_sigma)
        self._cache[key] = m
        return m

    def seconds(self, instance: KernelInstance, schedule: Schedule | None = None,
                mode: str = "strict") -> float:
        skey = repr(schedule.to_json()) if schedule is not None else None
        key = (self.target, instance.workload_key(), skey, mode)
        if key in self._seconds_cache:
            val = self._seconds_cache[key]
            if isinstance(val, ScheduleInvalid):
                raise val
            return val
        try:
            val = self.inner.seconds(instance, schedule, mode=mode)
        except ScheduleInvalid as e:
            self._seconds_cache[key] = e
            raise
        self._seconds_cache[key] = val
        return val

    def cache_info(self) -> dict[str, int]:
        return {
            "entries": len(self._cache),
            "hits": self.stats.cache_hits,
            "misses": self.stats.cache_misses,
        }


class PruningRunner(MeasureRunner):
    """Draft-then-verify batch measurement (Pruner, arXiv:2402.02361).

    ``measure_many`` ranks the batch with the zero-cost noise-free analytical
    breakdown (the draft), then fully measures only the best
    ``verify_top_k`` drafts through ``inner``.  Invalid candidates are caught
    statically at draft time (free — no virtual failed-compile charge);
    pruned candidates return ``seconds=None, pruned=True`` and cost
    ``draft_cost_s`` virtual seconds each (default 0).

    With ``verify_top_k >= len(candidates)`` every valid candidate is
    verified and the winning schedule is identical to the unpruned path.
    Single ``measure`` calls bypass drafting entirely.
    """

    def __init__(self, inner: MeasureRunner | None = None, *,
                 verify_top_k: int = 8, draft_cost_s: float = 0.0):
        super().__init__()
        if verify_top_k < 1:
            raise ValueError("verify_top_k must be >= 1")
        self.inner = inner if inner is not None else CachedRunner()
        self.verify_top_k = verify_top_k
        self.draft_cost_s = draft_cost_s

    def measure(self, instance: KernelInstance, schedule: Schedule, *,
                mode: str = "strict", seed: int = 0,
                noise_sigma: float = 0.05) -> Measurement:
        self.stats.requests += 1
        return self.inner.measure(instance, schedule, mode=mode, seed=seed,
                                  noise_sigma=noise_sigma)

    def measure_many(self, instance: KernelInstance, schedules: Sequence[Schedule],
                     *, mode: str = "strict", seed: int = 0,
                     noise_sigma: float = 0.05) -> list[Measurement]:
        self.stats.requests += len(schedules)
        drafts: list[tuple[int, float]] = []   # (index, draft seconds)
        results: list[Measurement | None] = [None] * len(schedules)
        for i, s in enumerate(schedules):
            self.stats.drafts += 1
            try:
                drafts.append((i, self.inner.seconds(instance, s, mode=mode)))
            except ScheduleInvalid:
                # Static draft catches invalid bindings before any build.
                results[i] = Measurement(seconds=None, measure_cost_s=self.draft_cost_s)
                self.stats.measure_cost_s += self.draft_cost_s
        drafts.sort(key=lambda t: t[1])
        verify = {i for i, _ in drafts[: self.verify_top_k]}
        for i, _ in drafts:
            if i in verify:
                results[i] = self.inner.measure(
                    instance, schedules[i], mode=mode, seed=seed,
                    noise_sigma=noise_sigma)
            else:
                self.stats.pruned += 1
                self.stats.measure_cost_s += self.draft_cost_s
                results[i] = Measurement(seconds=None,
                                         measure_cost_s=self.draft_cost_s,
                                         pruned=True)
        # Callers zip() the result against `schedules`: positional alignment
        # is part of the contract, so every slot must be filled.
        assert all(m is not None for m in results)
        return results

    def seconds(self, instance: KernelInstance, schedule: Schedule | None = None,
                mode: str = "strict") -> float:
        return self.inner.seconds(instance, schedule, mode=mode)


def default_runner(target: "str | Target | ChipSpec | None" = None) -> MeasureRunner:
    """The stack-wide default: memoized analytical measurement of ``target``."""
    return CachedRunner(AnalyticalRunner(target))


def resolve_runner(runner: MeasureRunner | None,
                   target: "str | Target | ChipSpec | None" = None,
                   ) -> tuple[MeasureRunner, str]:
    """Resolve the (runner, target-name) pair every tuning entrypoint needs.

    * runner=None            → a fresh :func:`default_runner` for ``target``;
    * runner given, target=None → the runner's own target;
    * both given             → they must agree; a mismatch raises rather than
      silently measuring one chip while labelling records with another.
    """
    if runner is None:
        runner = default_runner(target)
        return runner, runner.target
    if target is not None:
        name = resolve_target(target).name
        if name != runner.target:
            raise ValueError(
                f"runner measures target {runner.target!r} but target={name!r} "
                "was requested — build the runner with default_runner(target)")
        return runner, name
    return runner, runner.target
