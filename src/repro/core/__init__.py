"""Transfer-tuning core: the paper's contribution as a composable library.

Public API:
    KernelInstance / KernelUse / kernel classes ............ workload.py
    Schedule / concretize / default_schedule ............... schedule.py
    measure / evaluate / model_seconds (v5e cost model) .... cost_model.py
    MeasureRunner / Analytical|Cached|PruningRunner ........ runner.py
    tune_kernel / tune_model (Ansor analogue) .............. autoscheduler.py
    ScheduleDB / Record (target-namespaced) ................ database.py
    transfer_tune / transfer_matrix / cross_target_transfer  transfer.py
    select_donor / top_donors (Eq. 1) ...................... heuristic.py
    extract_kernels (model config -> kernel workloads) ..... extract.py
    ResolutionPipeline / ExecutionPlan / plan_model ........ resolution.py
    Target / get_target / resolve_target ................... repro.targets
"""
from repro.core.autoscheduler import ModelTuneResult, TuneResult, tune_kernel, tune_model, tune_model_into_db
from repro.core.cost_model import (
    CostBreakdown,
    Measurement,
    class_proportions,
    evaluate,
    kernel_seconds,
    measure,
    model_seconds,
)
from repro.core.database import Record, ScheduleDB
from repro.core.heuristic import DonorScore, donor_scores, select_donor, top_donors
from repro.core.resolution import (
    DefaultStage,
    ExecutionPlan,
    Resolution,
    ResolutionPipeline,
    ResolutionStage,
    ServiceStage,
    StaticMapStage,
    plan_model,
    plan_serving,
    plan_uses,
)
from repro.core.runner import (
    AnalyticalRunner,
    CachedRunner,
    MeasureRunner,
    PruningRunner,
    RunnerStats,
    default_runner,
)
from repro.core.schedule import ConcreteSchedule, Schedule, ScheduleInvalid, concretize, default_schedule
from repro.core.transfer import (
    KernelTransfer,
    TransferResult,
    cross_target_transfer,
    transfer_matrix,
    transfer_tune,
)
from repro.core.workload import KERNEL_CLASSES, KernelInstance, KernelUse, classes_in, dedup_uses
from repro.targets import DEFAULT_TARGET, Target, get_target, list_targets, resolve_target

__all__ = [
    "DEFAULT_TARGET",
    "KERNEL_CLASSES",
    "AnalyticalRunner",
    "CachedRunner",
    "ConcreteSchedule",
    "CostBreakdown",
    "DefaultStage",
    "DonorScore",
    "ExecutionPlan",
    "Target",
    "MeasureRunner",
    "PruningRunner",
    "Resolution",
    "ResolutionPipeline",
    "ResolutionStage",
    "RunnerStats",
    "ServiceStage",
    "StaticMapStage",
    "KernelInstance",
    "KernelTransfer",
    "KernelUse",
    "Measurement",
    "ModelTuneResult",
    "Record",
    "Schedule",
    "ScheduleDB",
    "ScheduleInvalid",
    "TransferResult",
    "TuneResult",
    "class_proportions",
    "classes_in",
    "concretize",
    "cross_target_transfer",
    "dedup_uses",
    "default_runner",
    "default_schedule",
    "donor_scores",
    "evaluate",
    "extract_kernels",
    "get_target",
    "kernel_seconds",
    "list_targets",
    "measure",
    "model_seconds",
    "plan_model",
    "plan_serving",
    "plan_uses",
    "resolve_target",
    "select_donor",
    "top_donors",
    "transfer_matrix",
    "transfer_tune",
    "tune_kernel",
    "tune_model",
    "tune_model_into_db",
]


def extract_kernels(*args, **kwargs):  # lazy import: configs depend on models
    from repro.core.extract import extract_kernels as _ek

    return _ek(*args, **kwargs)
