"""Transfer-tuning engine (paper §4): reuse auto-schedules across kernels.

For each kernel of the target model, every compatible schedule (same kernel
class) from the donor pool is *applied and measured standalone*; the best
valid one wins.  The accumulated measurement cost is transfer-tuning's search
time — the quantity the paper compares against Ansor's (§4.3: "the time for
testing each kernel of the target model with each valid schedule").

Modes:
* ``strict``   — paper-faithful: non-dividing/oversized tiles are invalid
  (Fig. 4's -1 bars) and simply skipped.
* ``adaptive`` — beyond-paper: shape-agnostic tile reformulation
  (schedule.py) rescues otherwise-invalid transfers.  Reported separately.

Exact workload hits (same class *and* shapes) reuse the donor schedule with
zero extra measurements, matching Ansor's workload-ID reuse.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Mapping, Sequence

from repro.core.cost_model import kernel_seconds, measure
from repro.core.database import Record, ScheduleDB
from repro.core.schedule import Schedule, default_schedule
from repro.core.workload import KernelInstance, KernelUse


@dataclasses.dataclass(frozen=True)
class KernelTransfer:
    """Outcome of transfer-tuning one target kernel."""

    instance: KernelInstance
    chosen: Schedule | None          # None -> fall back to untuned default
    chosen_from: str                 # donor model id ("" if default)
    seconds: float                   # standalone (cost-model) seconds after choice
    untuned_seconds: float
    candidates: int                  # schedules evaluated
    invalid: int                     # candidates rejected as invalid
    exact_hit: bool                  # Ansor-style exact workload reuse

    @property
    def speedup(self) -> float:
        return self.untuned_seconds / self.seconds


@dataclasses.dataclass
class TransferResult:
    model_id: str
    kernels: list[KernelTransfer]
    uses: list[KernelUse]
    search_time_s: float             # virtual seconds (measurement harness)
    wall_time_s: float
    untuned_seconds: float
    tuned_seconds: float

    @property
    def speedup(self) -> float:
        return self.untuned_seconds / self.tuned_seconds

    def schedule_map(self) -> dict[str, Schedule]:
        """workload_key -> chosen schedule (for model execution / launch)."""
        out = {}
        for k in self.kernels:
            if k.chosen is not None:
                out[k.instance.workload_key()] = k.chosen
        return out

    def coverage(self) -> float:
        """Fraction of untuned model time whose kernels got a transferred
        schedule (paper §5.2 discusses uncovered classes, e.g. MobileNetV2)."""
        covered = sum(
            u.use_count * k.untuned_seconds
            for u, k in zip(self.uses, self.kernels)
            if k.chosen is not None
        )
        return covered / self.untuned_seconds if self.untuned_seconds else 0.0


def transfer_tune(
    uses: Sequence[KernelUse],
    db: ScheduleDB,
    *,
    model_id: str = "target",
    donors: Sequence[str] | None = None,
    mode: str = "strict",
    seed: int = 0,
    noise_sigma: float = 0.05,
    max_candidates_per_kernel: int | None = None,
) -> TransferResult:
    """Transfer-tune a target model from donor schedules in ``db``.

    ``donors=None`` uses the full pool (paper §5.5 "mixed"); a single-element
    list is the paper's default one-to-one setting.
    """
    t0 = time.monotonic()
    kernels: list[KernelTransfer] = []
    search_time = 0.0
    for u in uses:
        inst = u.instance
        untuned = kernel_seconds(inst, None)
        exact = db.exact(inst)
        if exact is not None and (donors is None or exact.model_id in donors):
            # Ansor workload-ID reuse: no measurement needed.
            m = measure(inst, exact.schedule, mode="strict", seed=seed, noise_sigma=0.0)
            kernels.append(KernelTransfer(
                instance=inst, chosen=exact.schedule, chosen_from=exact.model_id,
                seconds=m.seconds, untuned_seconds=untuned,
                candidates=0, invalid=0, exact_hit=True,
            ))
            continue
        candidates = db.by_class(inst.class_id, models=donors)
        if max_candidates_per_kernel is not None:
            candidates = candidates[:max_candidates_per_kernel]
        best_secs, best_sched, best_model, invalid = untuned, None, "", 0
        for rec in candidates:
            m = measure(inst, rec.schedule, mode=mode, seed=seed, noise_sigma=noise_sigma)
            search_time += m.measure_cost_s
            if not m.valid:
                invalid += 1
                continue
            if m.seconds < best_secs:
                best_secs, best_sched, best_model = m.seconds, rec.schedule, rec.model_id
        final_secs = (
            kernel_seconds(inst, best_sched, mode=mode) if best_sched is not None else untuned
        )
        kernels.append(KernelTransfer(
            instance=inst, chosen=best_sched, chosen_from=best_model,
            seconds=final_secs, untuned_seconds=untuned,
            candidates=len(candidates), invalid=invalid, exact_hit=False,
        ))
    untuned_total = sum(u.use_count * k.untuned_seconds for u, k in zip(uses, kernels))
    tuned_total = sum(u.use_count * k.seconds for u, k in zip(uses, kernels))
    return TransferResult(
        model_id=model_id,
        kernels=kernels,
        uses=list(uses),
        search_time_s=search_time,
        wall_time_s=time.monotonic() - t0,
        untuned_seconds=untuned_total,
        tuned_seconds=tuned_total,
    )


def transfer_matrix(
    uses: Sequence[KernelUse],
    db: ScheduleDB,
    donors: Sequence[str] | None = None,
    mode: str = "strict",
    seed: int = 0,
) -> dict[str, dict[str, float | None]]:
    """Paper Fig. 4: per-(target kernel × donor schedule) standalone seconds.

    Returns {target workload_key: {donor record key: seconds | None(invalid)}}.
    """
    out: dict[str, dict[str, float | None]] = {}
    for u in uses:
        row: dict[str, float | None] = {}
        for rec in db.by_class(u.instance.class_id, models=donors):
            key = f"{rec.model_id}/{rec.instance.workload_key()}"
            m = measure(u.instance, rec.schedule, mode=mode, seed=seed)
            row[key] = m.seconds
        out[u.instance.workload_key()] = row
    return out
