"""Transfer-tuning engine (paper §4): reuse auto-schedules across kernels.

For each kernel of the target model, every compatible schedule (same kernel
class) from the donor pool is *applied and measured standalone*; the best
valid one wins.  The accumulated measurement cost is transfer-tuning's search
time — the quantity the paper compares against Ansor's (§4.3: "the time for
testing each kernel of the target model with each valid schedule").

Modes:
* ``strict``   — paper-faithful: non-dividing/oversized tiles are invalid
  (Fig. 4's -1 bars) and simply skipped.
* ``adaptive`` — beyond-paper: shape-agnostic tile reformulation
  (schedule.py) rescues otherwise-invalid transfers.  Reported separately.

Exact workload hits (same class *and* shapes) reuse the donor schedule with
zero extra measurements, matching Ansor's workload-ID reuse.

Measurement goes through an injected :class:`repro.core.runner.MeasureRunner`
(default ``CachedRunner(AnalyticalRunner())``), so repeated donor schedules
across kernels, matrix cells, and passes are measured once; pass a shared
runner across calls to pool the cache.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

from repro.core.database import Record, ScheduleDB
from repro.core.runner import MeasureRunner, resolve_runner, telemetry_delta
from repro.core.schedule import Schedule
from repro.core.workload import KernelInstance, KernelUse
from repro.targets import DEFAULT_TARGET, target_name


@dataclasses.dataclass(frozen=True)
class KernelTransfer:
    """Outcome of transfer-tuning one target kernel."""

    instance: KernelInstance
    chosen: Schedule | None          # None -> fall back to untuned default
    chosen_from: str                 # donor model id ("" if default)
    seconds: float                   # standalone (cost-model) seconds after choice
    untuned_seconds: float
    candidates: int                  # schedules evaluated
    invalid: int                     # candidates rejected as invalid
    exact_hit: bool                  # Ansor-style exact workload reuse
    pruned: int = 0                  # candidates dropped by a PruningRunner draft

    @property
    def speedup(self) -> float:
        return self.untuned_seconds / self.seconds


@dataclasses.dataclass
class TransferResult:
    model_id: str
    kernels: list[KernelTransfer]
    uses: list[KernelUse]
    search_time_s: float             # virtual seconds (measurement harness)
    wall_time_s: float
    untuned_seconds: float
    tuned_seconds: float
    # Measurement telemetry (delta over the injected runner for this call):
    measurements: int = 0            # full cost-model evaluations performed
    cache_hits: int = 0
    cache_misses: int = 0
    pruned_candidates: int = 0
    runner_telemetry: dict = dataclasses.field(default_factory=dict)
    target: str = DEFAULT_TARGET     # chip the transfers were measured on
    donor_target: str = DEFAULT_TARGET  # chip the donor pool was tuned on

    @property
    def speedup(self) -> float:
        return self.untuned_seconds / self.tuned_seconds

    @property
    def invalid_transfers(self) -> int:
        """Candidates rejected as invalid across all kernels (Fig. 4 −1 bars;
        for cross-target runs these include donors infeasible on ``target``,
        e.g. server tiles overflowing the edge chip's VMEM)."""
        return sum(k.invalid for k in self.kernels)

    def schedule_map(self) -> dict[str, Schedule]:
        """workload_key -> chosen schedule (for model execution / launch)."""
        out = {}
        for k in self.kernels:
            if k.chosen is not None:
                out[k.instance.workload_key()] = k.chosen
        return out

    def coverage(self) -> float:
        """Fraction of untuned model time whose kernels got a transferred
        schedule (paper §5.2 discusses uncovered classes, e.g. MobileNetV2)."""
        covered = sum(
            u.use_count * k.untuned_seconds
            for u, k in zip(self.uses, self.kernels)
            if k.chosen is not None
        )
        return covered / self.untuned_seconds if self.untuned_seconds else 0.0


def _strongest_first(candidates: list[Record], limit: int,
                     runner: MeasureRunner) -> list[Record]:
    """Truncate the donor pool keeping the strongest donors — ``db.by_class``
    order is (model_id, seconds), so a naive ``[:limit]`` would keep
    whichever models sort first alphabetically.  Strength is the recorded
    seconds *relative to the donor workload's own untuned seconds* (its
    speedup at home): raw seconds are only comparable within one workload
    shape, and would bias a mixed pool toward small donors."""
    def strength(r: Record) -> float:
        return r.seconds / runner.seconds(r.instance, None)
    return sorted(candidates, key=strength)[:limit]


def transfer_tune(
    uses: Sequence[KernelUse],
    db: ScheduleDB,
    *,
    model_id: str = "target",
    donors: Sequence[str] | None = None,
    mode: str = "strict",
    seed: int = 0,
    noise_sigma: float = 0.05,
    max_candidates_per_kernel: int | None = None,
    runner: MeasureRunner | None = None,
    target=None,
    donor_target=None,
) -> TransferResult:
    """Transfer-tune a target model from donor schedules in ``db``.

    ``donors=None`` uses the full pool (paper §5.5 "mixed"); a single-element
    list is the paper's default one-to-one setting.  ``runner`` injects the
    measurement backend; the default is a fresh memoizing analytical runner.

    ``target`` names the chip transfers are measured and served on (it must
    match ``runner``'s target when both are given).  ``donor_target`` names
    the chip the donor pool was tuned on — it defaults to ``target``, and
    setting it to a different chip is cross-target transfer
    (:func:`cross_target_transfer`): donors are re-validated under
    ``target``'s spec, and infeasible ones count as invalid transfers.  Exact
    workload reuse only ever draws from ``target``'s own namespace — a
    same-shape record from another chip is a candidate to re-measure, not a
    zero-cost hit.
    """
    t0 = time.monotonic()
    runner, tname = resolve_runner(runner, target)
    donor_tname = target_name(donor_target) if donor_target is not None else tname
    before = runner.telemetry()
    kernels: list[KernelTransfer] = []
    search_time = 0.0
    for u in uses:
        inst = u.instance
        untuned = runner.seconds(inst, None)
        exact = db.exact(inst, target=tname) if donor_tname == tname else None
        if exact is not None and (donors is None or exact.model_id in donors):
            # Ansor workload-ID reuse: no measurement needed — the noise-free
            # seconds query charges nothing and counts as zero measurements.
            kernels.append(KernelTransfer(
                instance=inst, chosen=exact.schedule, chosen_from=exact.model_id,
                seconds=runner.seconds(inst, exact.schedule, mode="strict"),
                untuned_seconds=untuned,
                candidates=0, invalid=0, exact_hit=True,
            ))
            continue
        candidates = db.by_class(inst.class_id, models=donors, target=donor_tname)
        if max_candidates_per_kernel is not None:
            candidates = _strongest_first(candidates, max_candidates_per_kernel, runner)
        measured = runner.measure_many(
            inst, [rec.schedule for rec in candidates],
            mode=mode, seed=seed, noise_sigma=noise_sigma)
        best_secs, best_sched, best_model = untuned, None, ""
        invalid = pruned = 0
        for rec, m in zip(candidates, measured):
            search_time += m.measure_cost_s
            if m.pruned:
                pruned += 1
                continue
            if not m.valid:
                invalid += 1
                continue
            if m.seconds < best_secs:
                best_secs, best_sched, best_model = m.seconds, rec.schedule, rec.model_id
        final_secs = (
            runner.seconds(inst, best_sched, mode=mode) if best_sched is not None else untuned
        )
        kernels.append(KernelTransfer(
            instance=inst, chosen=best_sched, chosen_from=best_model,
            seconds=final_secs, untuned_seconds=untuned,
            candidates=len(candidates), invalid=invalid, exact_hit=False,
            pruned=pruned,
        ))
    untuned_total = sum(u.use_count * k.untuned_seconds for u, k in zip(uses, kernels))
    tuned_total = sum(u.use_count * k.seconds for u, k in zip(uses, kernels))
    delta = telemetry_delta(runner.telemetry(), before)
    return TransferResult(
        model_id=model_id,
        kernels=kernels,
        uses=list(uses),
        search_time_s=search_time,
        wall_time_s=time.monotonic() - t0,
        untuned_seconds=untuned_total,
        tuned_seconds=tuned_total,
        measurements=int(delta.get("measurements", 0)),
        cache_hits=int(delta.get("cache_hits", 0)),
        cache_misses=int(delta.get("cache_misses", 0)),
        pruned_candidates=int(delta.get("pruned", 0)),
        runner_telemetry=delta,
        target=tname,
        donor_target=donor_tname,
    )


def cross_target_transfer(
    uses: Sequence[KernelUse],
    db: ScheduleDB,
    *,
    source_target,
    target,
    runner: MeasureRunner | None = None,
    **kw,
) -> TransferResult:
    """Explicit cross-target transfer: schedules auto-tuned on
    ``source_target`` become the donor pool for ``target``.

    This is the only sanctioned way a schedule crosses a target namespace
    (Chen et al. 2018 argue schedule knowledge transfers across devices; the
    namespaced stores make the trade-off measurable instead of accidental).
    Every donor is re-validated and re-measured under ``target``'s spec:
    tiles that overflow the destination chip's VMEM or break its geometry
    surface as invalid transfers (the paper's −1 bars) rather than crashing,
    and survivors are ranked by their measured seconds *on the destination
    chip*.  The result's records belong in ``target``'s namespace.

    Accepts every :func:`transfer_tune` keyword except ``donor_target``
    (which is ``source_target`` by definition).
    """
    if target_name(source_target) == target_name(target):
        raise ValueError(
            f"source and destination target are both {target_name(target)!r} — "
            "use transfer_tune for same-target reuse")
    return transfer_tune(uses, db, runner=runner, target=target,
                         donor_target=source_target, **kw)


def transfer_matrix(
    uses: Sequence[KernelUse],
    db: ScheduleDB,
    donors: Sequence[str] | None = None,
    mode: str = "strict",
    seed: int = 0,
    runner: MeasureRunner | None = None,
    target=None,
    donor_target=None,
) -> dict[str, dict[str, float | None]]:
    """Paper Fig. 4: per-(target kernel × donor schedule) standalone seconds.

    Returns {target workload_key: {donor record key: seconds | None(invalid)}}.
    Cells a :class:`PruningRunner` drafts away are omitted entirely — they
    were never evaluated, so recording them as ``None`` would conflate them
    with the paper's invalid (-1) transfers.  Sharing ``runner`` with a
    subsequent :func:`transfer_tune` call makes the tune pass free — every
    cell is already cached.
    """
    runner, tname = resolve_runner(runner, target)
    donor_tname = target_name(donor_target) if donor_target is not None else tname
    out: dict[str, dict[str, float | None]] = {}
    for u in uses:
        row: dict[str, float | None] = {}
        recs = db.by_class(u.instance.class_id, models=donors, target=donor_tname)
        measured = runner.measure_many(
            u.instance, [rec.schedule for rec in recs], mode=mode, seed=seed)
        for rec, m in zip(recs, measured):
            if m.pruned:
                continue
            key = f"{rec.model_id}/{rec.instance.workload_key()}"
            row[key] = m.seconds
        out[u.instance.workload_key()] = row
    return out
