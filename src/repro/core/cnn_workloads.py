"""The paper's own CNN workloads as kernel lists (paper §4.3 / Table 1).

TPU adaptation: a convolution lowers onto the MXU as an implicit GEMM
(im2col), so each conv kernel becomes a matmul-family instance with
M = B·OH·OW, N = C_out, K = C_in·KH·KW — the schedule space (BlockSpec
tiles, order, staging) and the v5e cost model apply unchanged.  This lets
us reproduce the paper's ResNet18 ← ResNet50 experiment *literally* (same
kernel classes, same layer shapes) inside the same transfer-tuning core
the LM architectures use.

ResNet18's kernel table below is transcribed from paper Table 1 (18
kernels, 6 classes A–F); ResNet50/other models are built from their
published layer configurations.
"""
from __future__ import annotations

from repro.core.workload import KernelInstance, KernelUse, dedup_uses


def _conv(class_id: str, cin: int, cout: int, k: int, hw: int, stride: int = 1,
          count: int = 1, batch: int = 1, tag: str = "") -> KernelUse:
    ohw = hw // stride
    return KernelUse(
        KernelInstance.make(class_id, M=batch * ohw * ohw, N=cout, K=cin * k * k),
        use_count=count, tag=tag or f"{class_id}_{cin}x{cout}k{k}s{stride}",
    )


def _dense(cin: int, cout: int, batch: int = 1, count: int = 1) -> KernelUse:
    return KernelUse(KernelInstance.make("dense_add", M=batch, N=cout, K=cin),
                     use_count=count, tag=f"dense_{cin}x{cout}")


def _pool(class_id: str, c: int, hw: int, k: int, count: int = 1, batch: int = 1) -> KernelUse:
    return KernelUse(
        KernelInstance.make(class_id, M=batch * (hw // k) * (hw // k), N=c, K=k * k),
        use_count=count, tag=f"{class_id}_{c}",
    )


def resnet18(batch: int = 1) -> list[KernelUse]:
    """Paper Table 1, verbatim kernel census (classes A–F)."""
    b = batch
    return dedup_uses([
        # class A: conv2d_add (strided downsample shortcuts)
        _conv("conv2d_add", 256, 512, 1, 14, 2, 1, b),
        _conv("conv2d_add", 128, 256, 1, 28, 2, 1, b),
        _conv("conv2d_add", 64, 128, 1, 56, 2, 1, b),
        # class E: conv2d_bias_relu
        _conv("conv2d_bias_relu", 3, 64, 7, 224, 2, 1, b),
        _conv("conv2d_bias_relu", 64, 64, 3, 56, 1, 2, b),
        _conv("conv2d_bias_relu", 64, 128, 3, 56, 2, 1, b),
        _conv("conv2d_bias_relu", 128, 128, 3, 28, 1, 1, b),
        _conv("conv2d_bias_relu", 128, 256, 3, 28, 2, 1, b),
        _conv("conv2d_bias_relu", 256, 256, 3, 14, 1, 1, b),
        _conv("conv2d_bias_relu", 256, 512, 3, 14, 2, 1, b),
        _conv("conv2d_bias_relu", 512, 512, 3, 7, 1, 1, b),
        # class F: conv2d_bias_add_relu (residual-add fused)
        _conv("conv2d_bias_add_relu", 64, 64, 3, 56, 1, 2, b),
        _conv("conv2d_bias_add_relu", 128, 128, 3, 28, 1, 2, b),
        _conv("conv2d_bias_add_relu", 256, 256, 3, 14, 1, 2, b),
        _conv("conv2d_bias_add_relu", 512, 512, 3, 7, 1, 2, b),
        # classes B/C: pooling; class D: classifier
        _pool("max_pool2d", 64, 112, 2, 1, b),
        _pool("global_avg_pool2d", 512, 7, 7, 1, b),
        _dense(512, 1000, b),
    ])


def resnet50(batch: int = 1) -> list[KernelUse]:
    """Bottleneck-block census (1x1-reduce / 3x3 / 1x1-expand per block)."""
    b = batch
    uses: list[KernelUse] = [
        _conv("conv2d_bias_relu", 3, 64, 7, 224, 2, 1, b),
        _pool("max_pool2d", 64, 112, 2, 1, b),
    ]
    stages = [  # (cin, cmid, cout, hw, blocks)
        (64, 64, 256, 56, 3),
        (256, 128, 512, 28, 4),
        (512, 256, 1024, 14, 6),
        (1024, 512, 2048, 7, 3),
    ]
    for cin, cmid, cout, hw, blocks in stages:
        stride = 1 if cin == 64 else 2
        in_hw = hw * stride
        uses += [
            _conv("conv2d_add", cin, cout, 1, in_hw, stride, 1, b),        # shortcut
            _conv("conv2d_bias_relu", cin, cmid, 1, in_hw, stride, 1, b),  # first reduce
            _conv("conv2d_bias_relu", cout, cmid, 1, hw, 1, blocks - 1, b),
            _conv("conv2d_bias_relu", cmid, cmid, 3, hw, 1, blocks, b),
            _conv("conv2d_bias_add_relu", cmid, cout, 1, hw, 1, blocks, b),
        ]
    uses += [_pool("global_avg_pool2d", 2048, 7, 7, 1, b), _dense(2048, 1000, b)]
    return dedup_uses(uses)


def alexnet(batch: int = 1) -> list[KernelUse]:
    b = batch
    return dedup_uses([
        _conv("conv2d_bias_relu", 3, 64, 11, 224, 4, 1, b),
        _conv("conv2d_bias_relu", 64, 192, 5, 27, 1, 1, b),
        _conv("conv2d_bias_relu", 192, 384, 3, 13, 1, 1, b),
        _conv("conv2d_bias_relu", 384, 256, 3, 13, 1, 1, b),
        _conv("conv2d_bias_relu", 256, 256, 3, 13, 1, 1, b),
        _pool("max_pool2d", 64, 55, 2, 1, b),
        _pool("max_pool2d", 192, 27, 2, 1, b),
        _pool("max_pool2d", 256, 13, 2, 1, b),
        _dense(9216, 4096, b), _dense(4096, 4096, b), _dense(4096, 1000, b),
    ])


def vgg16(batch: int = 1) -> list[KernelUse]:
    b = batch
    uses = []
    cfg = [(3, 64, 224, 2), (64, 128, 112, 2), (128, 256, 56, 3),
           (256, 512, 28, 3), (512, 512, 14, 3)]
    for cin, cout, hw, n in cfg:
        uses.append(_conv("conv2d_bias_relu", cin, cout, 3, hw, 1, 1, b))
        if n > 1:
            uses.append(_conv("conv2d_bias_relu", cout, cout, 3, hw, 1, n - 1, b))
        uses.append(_pool("max_pool2d", cout, hw, 2, 1, b))
    uses += [_dense(25088, 4096, b), _dense(4096, 4096, b), _dense(4096, 1000, b)]
    return dedup_uses(uses)


CNN_MODELS = {
    "resnet18": resnet18,
    "resnet50": resnet50,
    "alexnet": alexnet,
    "vgg16": vgg16,
}


def cnn_uses(name: str, batch: int = 1) -> list[KernelUse]:
    return CNN_MODELS[name](batch)
