"""Kernel workloads and kernel classes (paper §4.2).

A *kernel* is a fused unit of computation dispatched as one Pallas call
(e.g. a projection GEMM with its bias+activation epilogue, a flash-attention
invocation, a recurrent-scan chunk).

A *kernel class* is the set of kernels sharing the same operator sequence
regardless of tensor shapes — the unit within which auto-schedules are
transferable (paper §3, §4.2).  Structural attributes (epilogue ops,
causality, presence of a window or softcap) are part of the class; numeric
shape parameters (M/N/K, sequence lengths, window sizes) are per-instance.

A *workload key* hashes class + shapes + dtype: Ansor's exact-reuse unit.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Mapping, Sequence

# ---------------------------------------------------------------------------
# Kernel class registry: class_id -> loop axes the scheduler can transform.
# The axes define the schedule space (which tiles exist) for the class.
# ---------------------------------------------------------------------------

MATMUL_AXES = ("M", "N", "K")
ATTENTION_AXES = ("Q", "KV")
SCAN_AXES = ("T", "C")

#: class_id -> (axes, family). Family groups classes that share a kernel
#: template ("matmul", "attention", "scan") — schedules NEVER transfer across
#: class_ids (paper: across-class transfer is future work), but the family
#: tells us which Pallas template + cost model to use.
KERNEL_CLASSES: dict[str, tuple[tuple[str, ...], str]] = {
    # --- matmul family: projection GEMMs with fused epilogues -------------
    "matmul": (MATMUL_AXES, "matmul"),
    "matmul_bias": (MATMUL_AXES, "matmul"),
    "matmul_bias_gelu": (MATMUL_AXES, "matmul"),
    "matmul_silu_glu": (MATMUL_AXES, "matmul"),        # fused gate*up SwiGLU
    "matmul_gelu_glu": (MATMUL_AXES, "matmul"),        # GeGLU variant
    "matmul_residual": (MATMUL_AXES, "matmul"),        # out-proj + residual add
    "matmul_lmhead": (MATMUL_AXES, "matmul"),          # hidden -> vocab
    "matmul_lmhead_softcap": (MATMUL_AXES, "matmul"),  # gemma2 final softcap
    "moe_gemm_silu_glu": (MATMUL_AXES + ("E",), "matmul"),  # grouped expert up-GEMM
    "moe_gemm": (MATMUL_AXES + ("E",), "matmul"),      # grouped expert down-GEMM
    "moe_router": (MATMUL_AXES, "matmul"),             # hidden -> n_experts
    # --- attention family --------------------------------------------------
    "flash_attention_causal": (ATTENTION_AXES, "attention"),
    "flash_attention_swa": (ATTENTION_AXES, "attention"),        # sliding window
    "flash_attention_local": (ATTENTION_AXES, "attention"),      # gemma2 local
    "flash_attention_softcap": (ATTENTION_AXES, "attention"),    # gemma2 global
    "flash_attention_bidir": (ATTENTION_AXES, "attention"),      # encoder
    "flash_attention_cross": (ATTENTION_AXES, "attention"),      # enc-dec cross
    # --- recurrent-scan family ---------------------------------------------
    "rwkv6_scan": (SCAN_AXES, "scan"),
    "rglru_scan": (SCAN_AXES, "scan"),
    # --- CNN classes (paper §4.2 Table 1), TPU-adapted as implicit GEMM ----
    # (im2col: M = B·OH·OW, N = C_out, K = C_in·KH·KW) — the matmul family's
    # schedule space and cost model apply directly, which is exactly how
    # convolutions lower on the MXU.
    "conv2d_add": (MATMUL_AXES, "matmul"),
    "conv2d_bias_relu": (MATMUL_AXES, "matmul"),
    "conv2d_bias_add_relu": (MATMUL_AXES, "matmul"),
    "dense_add": (MATMUL_AXES, "matmul"),
    "max_pool2d": (("M", "N", "K"), "matmul"),          # window reduce: K = KH·KW
    "global_avg_pool2d": (("M", "N", "K"), "matmul"),
}


def class_axes(class_id: str) -> tuple[str, ...]:
    return KERNEL_CLASSES[class_id][0]


def class_family(class_id: str) -> str:
    return KERNEL_CLASSES[class_id][1]


def is_known_class(class_id: str) -> bool:
    return class_id in KERNEL_CLASSES


# ---------------------------------------------------------------------------
# Kernel instances
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, order=True)
class KernelInstance:
    """One concrete kernel: a class plus its numeric shape parameters.

    ``params`` must contain an entry for every axis of the class (the loop
    extents the scheduler tiles) and may contain extra structural-numeric
    parameters used by the cost model (e.g. ``H`` heads, ``D`` head_dim,
    ``window``, ``topk``).
    """

    class_id: str
    params: tuple[tuple[str, int], ...]
    dtype: str = "bfloat16"

    def __post_init__(self):
        if self.class_id not in KERNEL_CLASSES:
            raise ValueError(f"unknown kernel class: {self.class_id!r}")
        missing = [a for a in class_axes(self.class_id) if a not in dict(self.params)]
        if missing:
            raise ValueError(
                f"instance of {self.class_id} missing axis extents {missing}; got {self.params}"
            )

    @staticmethod
    def make(class_id: str, dtype: str = "bfloat16", **params: int) -> "KernelInstance":
        return KernelInstance(
            class_id=class_id,
            params=tuple(sorted((k, int(v)) for k, v in params.items())),
            dtype=dtype,
        )

    @property
    def p(self) -> dict[str, int]:
        return dict(self.params)

    def extent(self, axis: str) -> int:
        return dict(self.params)[axis]

    @property
    def axes(self) -> tuple[str, ...]:
        return class_axes(self.class_id)

    @property
    def family(self) -> str:
        return class_family(self.class_id)

    def workload_key(self) -> str:
        """Ansor-style unique ID: hash of class + shape params + dtype.

        Memoized on the instance — resolution paths key every lookup by it,
        so the hash is computed once per interned instance, not per call."""
        key = self.__dict__.get("_workload_key")
        if key is None:
            blob = json.dumps(
                {"class": self.class_id, "params": list(self.params), "dtype": self.dtype},
                sort_keys=True,
            )
            key = hashlib.sha1(blob.encode()).hexdigest()[:16]
            object.__setattr__(self, "_workload_key", key)
        return key

    def to_json(self) -> dict:
        return {"class_id": self.class_id, "params": list(self.params), "dtype": self.dtype}

    @staticmethod
    def from_json(d: Mapping) -> "KernelInstance":
        return KernelInstance(
            class_id=d["class_id"],
            params=tuple((str(k), int(v)) for k, v in d["params"]),
            dtype=d.get("dtype", "bfloat16"),
        )


@dataclasses.dataclass(frozen=True)
class KernelUse:
    """A kernel instance plus how many times the model invokes it.

    Mirrors paper Table 1's "Use Count": repeated layers share one tuning
    task but weigh proportionally in model cost.
    """

    instance: KernelInstance
    use_count: int = 1
    tag: str = ""  # human label, e.g. "layer.qkv_proj"

    def to_json(self) -> dict:
        return {"instance": self.instance.to_json(), "use_count": self.use_count, "tag": self.tag}

    @staticmethod
    def from_json(d: Mapping) -> "KernelUse":
        return KernelUse(
            instance=KernelInstance.from_json(d["instance"]),
            use_count=int(d["use_count"]),
            tag=d.get("tag", ""),
        )


def dedup_uses(uses: Sequence[KernelUse]) -> list[KernelUse]:
    """Merge identical instances, summing use counts (paper Table 1)."""
    merged: dict[str, KernelUse] = {}
    for u in uses:
        k = u.instance.workload_key()
        if k in merged:
            prev = merged[k]
            merged[k] = KernelUse(prev.instance, prev.use_count + u.use_count, prev.tag)
        else:
            merged[k] = u
    return sorted(merged.values(), key=lambda u: (u.instance.class_id, u.instance.params))


def classes_in(uses: Sequence[KernelUse]) -> list[str]:
    return sorted({u.instance.class_id for u in uses})
