"""Top-level tuning API gluing the core together (the paper's workflow).

    uses  = arch_uses("gemma2-2b", "train_4k", dp=16, tp=16)
    native = tune_arch(db, "gemma2-2b", ...)          # Ansor analogue
    donor  = select_donor(uses, db)                   # Eq. 1
    tt     = transfer_arch(db, "gemma2-2b", donors=[donor])   # transfer-tuning

All results carry virtual search seconds (measurement-harness time, the
paper's cost axis) and cost-model kernel seconds.
"""
from __future__ import annotations

from typing import Sequence

from repro.configs.base import get_arch, get_shape
from repro.core.autoscheduler import ModelTuneResult, tune_model
from repro.core.database import Record, ScheduleDB
from repro.core.extract import extract_kernels
from repro.core.heuristic import select_donor, select_donor_v2, top_donors
from repro.core.runner import MeasureRunner, resolve_runner
from repro.core.transfer import TransferResult, transfer_tune
from repro.core.workload import KernelUse
from repro.targets import target_name


def arch_uses(arch: str, shape: str = "train_4k", *, dp: int = 1, tp: int = 1
              ) -> list[KernelUse]:
    return extract_kernels(get_arch(arch), get_shape(shape), dp=dp, tp=tp)


def tune_arch(db: ScheduleDB, arch: str, shape: str = "train_4k", *,
              dp: int = 1, tp: int = 1, total_trials: int = 1024, seed: int = 0,
              runner: MeasureRunner | None = None, target=None,
              **kw) -> ModelTuneResult:
    """Full auto-scheduling of one arch for one hardware target; records land
    in `db` under the arch id, namespaced by the target."""
    uses = arch_uses(arch, shape, dp=dp, tp=tp)
    res = tune_model(uses, model_id=arch, total_trials=total_trials, seed=seed,
                     runner=runner, target=target, **kw)
    for r in res.records:
        db.add(r)
    return res


def transfer_arch(db: ScheduleDB, arch: str, shape: str = "train_4k", *,
                  dp: int = 1, tp: int = 1, donors: Sequence[str] | None | str = "auto",
                  mode: str = "strict", seed: int = 0,
                  runner: MeasureRunner | None = None, target=None,
                  source_target=None, **kw) -> TransferResult:
    """Transfer-tune one arch from donor schedules.

    donors="auto" applies the Eq. 1 heuristic (excluding the arch itself);
    donors="auto2" the beyond-paper compatibility-aware variant;
    donors=None uses the full mixed pool (paper §5.5); otherwise a list.

    ``target`` is the chip the arch will run on; ``source_target`` (optional)
    draws the donor pool from another chip's namespace — cross-target
    transfer, with every donor re-validated under ``target``'s spec.  The
    Eq. 1 heuristic counts donors in the source namespace in that case.

    One ``runner`` (default: memoizing analytical) serves both donor
    selection and the transfer pass, so the untuned-seconds queries Eq. 1
    makes are never recomputed by the transfer loop.
    """
    uses = arch_uses(arch, shape, dp=dp, tp=tp)
    runner, tname = resolve_runner(runner, target)
    donor_tname = target_name(source_target) if source_target is not None else tname
    if donors in ("auto", "auto2"):
        pick = select_donor_v2 if donors == "auto2" else select_donor
        best = pick(uses, db, exclude=(arch,), runner=runner,
                    donor_target=donor_tname)
        donors = [best] if best is not None else []
    return transfer_tune(uses, db, model_id=arch, donors=donors, mode=mode,
                         seed=seed, runner=runner, target=tname,
                         donor_target=donor_tname, **kw)


def tune_arch_registry(registry, arch: str, shape: str = "train_4k", *,
                       mode: str = "strict", **kw) -> ModelTuneResult:
    """:func:`tune_arch` writing through a schedule registry.

    The arch's records land as one atomically published segment — the
    online-store analogue of merging a freshly tuned ScheduleDB.  ``registry``
    is a :class:`repro.service.ScheduleRegistry` (duck-typed to avoid a
    core → service import cycle).
    """
    db = ScheduleDB()
    res = tune_arch(db, arch, shape, **kw)
    registry.merge_db(db, mode=mode)
    return res


def transfer_arch_registry(registry, arch: str, shape: str = "train_4k", *,
                           mode: str = "strict", publish: bool = True,
                           **kw) -> TransferResult:
    """:func:`transfer_arch` reading donors from — and publishing chosen
    schedules back to — a schedule registry.

    The donor pool is the registry's current snapshot (all modes; candidates
    are re-validated under ``mode`` by measurement).  With ``publish=True``
    every kernel's chosen schedule is published under the arch id in one
    atomic segment, so a subsequent :class:`~repro.service.TuningService`
    serves them as exact hits.
    """
    db = registry.snapshot().db(None)
    res = transfer_arch(db, arch, shape, mode=mode, **kw)
    if publish:
        registry.publish(
            [Record(instance=k.instance, schedule=k.chosen, seconds=k.seconds,
                    model_id=arch, target=res.target)
             for k in res.kernels if k.chosen is not None],
            mode=mode)
    return res


def donor_ranking(db: ScheduleDB, arch: str, shape: str = "train_4k", *,
                  dp: int = 1, tp: int = 1, k: int = 3,
                  runner: MeasureRunner | None = None, donor_target=None):
    uses = arch_uses(arch, shape, dp=dp, tp=tp)
    return top_donors(uses, db, k=k, exclude=(arch,), runner=runner,
                      donor_target=donor_target)
