"""Schedule IR: the TPU reformulation of the paper's schedule primitives.

Paper primitives (Algorithm 1) and their TPU/Pallas mapping:

=================  ========================================================
TVM primitive       This framework
=================  ========================================================
Split(ax, f)        ``tiles[ax] = f`` — BlockSpec block size for the axis.
Reorder(...)        ``order`` — grid iteration order (outer→inner); changes
                    which operand block stays VMEM-resident between
                    consecutive grid steps, i.e. the HBM traffic pattern.
Fuse + Parallel     ``parallel`` — number of leading grid axes given
                    ``dimension_semantics="parallel"`` (Megacore/pipelining).
Unroll(ax, n)       ``unroll`` — in-kernel sub-tile unroll factor for the
                    innermost loop (instruction-overhead knob).
Vectorize(ax)       ``vec`` — lane multiple the innermost tile must respect
                    ((8,128) VREG tiling; misalignment wastes lanes).
ComputeAt/Cache     ``cache_write`` — accumulate into an f32 VMEM scratch
                    buffer instead of the (bf16) output block.
=================  ========================================================

A ``Schedule`` stores *absolute* tile sizes — exactly what an auto-scheduler
measures on its source kernel.  Applying a schedule to another instance of
the same class is *transfer-tuning*; ``concretize`` validates it:

* ``strict``  — the paper's semantics: a tile that does not divide the new
  extent (or exceeds it, or overflows VMEM) makes the transferred schedule
  INVALID (the ``-1`` bars of paper Fig. 4).
* ``adaptive`` — beyond-paper extension: reformulate the tile
  shape-agnostically (paper §4.1's ``Split(N, N/8, 8)`` trick, generalized):
  snap the tile to the nearest divisor of the new extent, preserving the
  schedule's *structure*.  Recovers most invalid transfers; evaluated
  separately in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence

from repro.core.workload import KernelInstance, class_axes


class ScheduleInvalid(Exception):
    """Transferred schedule produces invalid code for this instance."""


UNROLL_CHOICES = (0, 4, 16, 64, 512)
VEC_CHOICES = (128, 256, 512)


@dataclasses.dataclass(frozen=True)
class Schedule:
    """A shape-transferable auto-schedule for one kernel class."""

    class_id: str
    tiles: tuple[tuple[str, int], ...]      # axis -> block size (absolute)
    order: tuple[str, ...]                  # grid axis order, outer→inner
    parallel: int = 1                       # leading grid axes marked parallel
    unroll: int = 0
    vec: int = 128
    cache_write: bool = True
    source: str = ""                        # workload key tuned on (provenance)

    @staticmethod
    def make(class_id: str, tiles: Mapping[str, int], order: Sequence[str] | None = None,
             parallel: int = 1, unroll: int = 0, vec: int = 128,
             cache_write: bool = True, source: str = "") -> "Schedule":
        axes = class_axes(class_id)
        order = tuple(order) if order is not None else tuple(axes)
        if sorted(order) != sorted(axes):
            raise ValueError(f"order {order} must permute axes {axes}")
        missing = [a for a in axes if a not in tiles]
        if missing:
            raise ValueError(f"tiles missing axes {missing}")
        return Schedule(
            class_id=class_id,
            tiles=tuple(sorted((a, int(tiles[a])) for a in axes)),
            order=order,
            parallel=int(parallel),
            unroll=int(unroll),
            vec=int(vec),
            cache_write=bool(cache_write),
            source=source,
        )

    @property
    def t(self) -> dict[str, int]:
        return dict(self.tiles)

    def with_source(self, source: str) -> "Schedule":
        return dataclasses.replace(self, source=source)

    def to_json(self) -> dict:
        return {
            "class_id": self.class_id,
            "tiles": list(self.tiles),
            "order": list(self.order),
            "parallel": self.parallel,
            "unroll": self.unroll,
            "vec": self.vec,
            "cache_write": self.cache_write,
            "source": self.source,
        }

    @staticmethod
    def from_json(d: Mapping) -> "Schedule":
        return Schedule(
            class_id=d["class_id"],
            tiles=tuple((str(a), int(v)) for a, v in d["tiles"]),
            order=tuple(d["order"]),
            parallel=int(d["parallel"]),
            unroll=int(d["unroll"]),
            vec=int(d["vec"]),
            cache_write=bool(d["cache_write"]),
            source=d.get("source", ""),
        )


@dataclasses.dataclass(frozen=True)
class ConcreteSchedule:
    """A schedule bound to one instance: validated tiles + derived grid."""

    schedule: Schedule
    instance: KernelInstance
    tiles: tuple[tuple[str, int], ...]   # validated per-axis block sizes
    grid: tuple[tuple[str, int], ...]    # axis -> trip count, in `order` order
    adapted: bool                        # True if adaptive reformulation fired

    @property
    def t(self) -> dict[str, int]:
        return dict(self.tiles)

    @property
    def g(self) -> dict[str, int]:
        return dict(self.grid)

    @property
    def order(self) -> tuple[str, ...]:
        return self.schedule.order

    def trip_counts(self) -> tuple[int, ...]:
        return tuple(n for _, n in self.grid)

    def total_steps(self) -> int:
        return math.prod(self.trip_counts())


def divisors_leq(n: int, cap: int) -> list[int]:
    return [d for d in range(1, min(n, cap) + 1) if n % d == 0]


def nearest_divisor(n: int, target: int) -> int:
    """Largest divisor of n that is <= target, else smallest divisor >= target."""
    below = [d for d in range(1, n + 1) if n % d == 0 and d <= target]
    if below:
        return below[-1]
    return n  # target < 1 cannot happen; fall back to full extent


#: Axes whose partial tiles the kernels mask on TPU (cdiv grids with clipped
#: OOB write-back / score masks): token rows (M), output columns (N — each
#: output column depends only on its own weight column), both attention axes,
#: and scan channels (C).  Reduction-carrying axes stay strict — a partial K
#: tile would pollute the accumulation and a partial T chunk would corrupt
#: the recurrent state — and those are exactly the splits that produce
#: invalid transferred code, the analogue of the paper's Fig. 4 "-1" bars.
MASKABLE_AXES = {"M", "N", "Q", "KV", "C"}

#: GLU epilogues pair adjacent (gate, up) columns: a partial N tile is fine
#: but an odd tile would split pairs.
GLU_CLASSES = ("matmul_silu_glu", "matmul_gelu_glu", "moe_gemm_silu_glu")


def concretize(schedule: Schedule, instance: KernelInstance, mode: str = "strict") -> ConcreteSchedule:
    """Bind a (possibly foreign) schedule to an instance.

    strict:   paper semantics — raise ScheduleInvalid on any layout-critical
              mismatch (maskable row axes tolerate partial tiles).
    adaptive: beyond-paper — shape-agnostic reformulation of tiles.
    """
    if schedule.class_id != instance.class_id:
        # Across-class transfer is out of scope (paper §4.2): always invalid.
        raise ScheduleInvalid(
            f"class mismatch: schedule {schedule.class_id} vs instance {instance.class_id}"
        )
    if mode not in ("strict", "adaptive"):
        raise ValueError(f"unknown mode {mode!r}")

    tiles: dict[str, int] = {}
    adapted = False
    for axis in class_axes(instance.class_id):
        extent = instance.extent(axis)
        tile = schedule.t[axis]
        maskable = axis in MASKABLE_AXES
        if tile > extent:
            if maskable:
                tile = extent  # one (partial) block — masked, still valid
            elif mode == "strict":
                # Paper §4.2: "a loop splitting factor which is larger than
                # the loop itself" → invalid code.
                raise ScheduleInvalid(f"tile {axis}={tile} exceeds extent {extent}")
            else:
                tile, adapted = extent, True
        if extent % tile != 0 and not maskable:
            if mode == "strict":
                raise ScheduleInvalid(f"tile {axis}={tile} does not divide extent {extent}")
            tile, adapted = nearest_divisor(extent, tile), True
        if axis == "N" and instance.class_id in GLU_CLASSES and tile % 2:
            if mode == "strict":
                raise ScheduleInvalid(f"odd N tile {tile} splits GLU pairs")
            tile, adapted = max(tile - 1, 2), True
        tiles[axis] = tile

    grid = tuple(
        (axis, -(-instance.extent(axis) // tiles[axis])) for axis in schedule.order
    )
    return ConcreteSchedule(
        schedule=schedule,
        instance=instance,
        tiles=tuple(sorted(tiles.items())),
        grid=grid,
        adapted=adapted,
    )


def is_valid(schedule: Schedule, instance: KernelInstance, mode: str = "strict") -> bool:
    try:
        concretize(schedule, instance, mode=mode)
        return True
    except ScheduleInvalid:
        return False


# ---------------------------------------------------------------------------
# Default (untuned) schedules: the baseline every speedup is measured against,
# playing the role of TVM's generic fallback schedules in the paper.
# They are deliberately generic: small fixed tiles, natural order, no staging.
# ---------------------------------------------------------------------------


REDUCTION_AXIS = {"matmul": "K", "attention": "KV", "scan": "T"}

#: Generic fallback tile targets — the analogue of TVM's hand-written
#: default schedules (sensible blocking + staging, but shape-agnostic and
#: therefore leaving the shape-specific headroom auto-scheduling recovers).
_DEFAULT_TARGET = {"M": 128, "Q": 128, "T": 128, "N": 512, "KV": 512, "C": 512,
                   "K": 256, "E": 1}


def default_schedule(instance: KernelInstance) -> Schedule:
    from repro.core.workload import class_family

    axes = class_axes(instance.class_id)
    tiles: dict[str, int] = {}
    for axis in axes:
        extent = instance.extent(axis)
        tiles[axis] = nearest_divisor(extent, min(_DEFAULT_TARGET[axis], extent))
    red = REDUCTION_AXIS[class_family(instance.class_id)]
    order = tuple(a for a in axes if a != red) + (red,)
    return Schedule.make(
        instance.class_id,
        tiles=tiles,
        order=order,
        parallel=1,
        unroll=0,
        vec=128,
        cache_write=True,
        source="__default__",
    )
