"""Schedule database: persistent store of tuned auto-schedules.

Mirrors Ansor's log-file records: each record binds a workload (instance) to
a measured schedule plus provenance (which model it was tuned for).  The DB
answers the two reuse queries:

* exact workload match (Ansor's native reuse);
* all schedules of a kernel class, optionally filtered by donor model
  (transfer-tuning's candidate pool, paper §4.2/§5.5).
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Iterable, Mapping, Sequence

from repro.core.schedule import Schedule
from repro.core.workload import KernelInstance
from repro.targets import DEFAULT_TARGET, target_name

#: On-disk schema version shared by every schedule store (the monolithic
#: ScheduleDB JSON payload and the registry's manifest / segment headers).
SCHEMA_VERSION = 1


class UnknownSchemaVersion(ValueError):
    """A persisted schedule payload declares a version this code can't read."""


def check_schema_version(payload: Mapping, *, source: str) -> None:
    """Validate the ``version`` field of a persisted payload.

    Raises :class:`UnknownSchemaVersion` with a readable message naming the
    offending file/segment; a missing field is treated as unknown too (the
    pre-versioned era never shipped, so absence means corruption).
    """
    v = payload.get("version")
    if v != SCHEMA_VERSION:
        raise UnknownSchemaVersion(
            f"{source}: unsupported schema version {v!r} "
            f"(this build reads version {SCHEMA_VERSION}); "
            "regenerate the store or upgrade the reader"
        )


@dataclasses.dataclass(frozen=True)
class Record:
    instance: KernelInstance
    schedule: Schedule
    seconds: float           # measured (cost-model) seconds on the source instance
    model_id: str            # donor model the kernel belongs to
    trials: int = 0          # search trials spent producing this record
    target: str = DEFAULT_TARGET  # hardware target the measurement ran on

    def to_json(self) -> dict:
        return {
            "instance": self.instance.to_json(),
            "schedule": self.schedule.to_json(),
            "seconds": self.seconds,
            "model_id": self.model_id,
            "trials": self.trials,
            "target": self.target,
        }

    @staticmethod
    def from_json(d: Mapping) -> "Record":
        return Record(
            instance=KernelInstance.from_json(d["instance"]),
            schedule=Schedule.from_json(d["schedule"]),
            seconds=float(d["seconds"]),
            model_id=d["model_id"],
            trials=int(d.get("trials", 0)),
            # Pre-target-subsystem stores only ever measured the seed chip,
            # so a missing field is unambiguous (same schema version).
            target=d.get("target", DEFAULT_TARGET),
        )


class ScheduleDB:
    """In-memory schedule store with JSON persistence (atomic writes).

    Holds up to MAX_PER_WORKLOAD distinct schedules per (target, workload,
    model) — Ansor's tuning logs retain every measured schedule, and
    transfer-tuning draws its candidate pool from them; keeping the top-k per
    donor kernel preserves pool sizes comparable to the paper's
    many-kernels-per-class CNNs even though LM stacks dedup to few unique
    workloads per class.

    Every record is **namespaced by hardware target**: queries take a
    ``target`` (name / Target / None = the default ``tpu-v5e``) and only ever
    return records measured on that chip, so a schedule tuned for one target
    cannot silently serve another.  Cross-target reuse is explicit — pass the
    donor chip's name as the query target (what
    :func:`repro.core.transfer.cross_target_transfer` does) and re-measure
    under the serving chip's spec.
    """

    MAX_PER_WORKLOAD = 5

    def __init__(self, records: Iterable[Record] = ()):
        # (target, workload, model) -> top-k records, sorted by seconds
        self._by_workload: dict[tuple[str, str, str], list[Record]] = {}
        # (target, workload) -> best record (any model)
        self._best: dict[tuple[str, str], Record] = {}
        self._frozen = False
        for r in records:
            self.add(r)

    def freeze(self) -> "ScheduleDB":
        """Make the DB read-only (adds raise) — shared snapshot views."""
        self._frozen = True
        return self

    # -- mutation -----------------------------------------------------------
    def add(self, record: Record) -> None:
        if self._frozen:
            raise RuntimeError(
                "ScheduleDB is frozen (a registry snapshot view is shared and "
                "immutable) — copy it with ScheduleDB(db.records()) to mutate")
        wk = record.instance.workload_key()
        cur = self._best.get((record.target, wk))
        if cur is None or record.seconds < cur.seconds:
            self._best[(record.target, wk)] = record
        key = (record.target, wk, record.model_id)
        bucket = self._by_workload.setdefault(key, [])
        for i, r in enumerate(bucket):
            if r.schedule == record.schedule:
                if record.seconds < r.seconds:
                    bucket[i] = record
                    bucket.sort(key=lambda x: x.seconds)
                return
        bucket.append(record)
        bucket.sort(key=lambda r: r.seconds)
        del bucket[self.MAX_PER_WORKLOAD:]

    @property
    def _records(self) -> dict:
        # flattened view keyed by (target, workload, model, rank)
        return {
            (*k, i): r
            for k, rs in self._by_workload.items()
            for i, r in enumerate(rs)
        }

    def merge(self, other: "ScheduleDB") -> None:
        for r in other.records():
            self.add(r)

    # -- queries -------------------------------------------------------------
    def records(self) -> list[Record]:
        return [r for rs in self._by_workload.values() for r in rs]

    def models(self, target=None) -> list[str]:
        """Donor model ids; ``target`` restricts to models with records for
        that chip (``None`` lists models across every target)."""
        if target is None:
            return sorted({m for (_t, _w, m) in self._by_workload})
        t = target_name(target)
        return sorted({m for (rt, _w, m) in self._by_workload if rt == t})

    def targets(self) -> list[str]:
        """Every hardware target this DB holds records for."""
        return sorted({t for (t, _w, _m) in self._by_workload})

    def exact(self, instance: KernelInstance, target=None) -> Record | None:
        """Best ``target`` record for this exact workload (any model) —
        Ansor reuse, namespaced by chip.

        O(1): the best-per-(target, workload) index is maintained by ``add``
        (bucket truncation only ever drops non-best records, so it stays
        exact), keeping the serving path's per-kernel resolution
        constant-time.
        """
        return self._best.get((target_name(target), instance.workload_key()))

    def by_class(self, class_id: str, models: Sequence[str] | None = None,
                 target=None) -> list[Record]:
        """All ``target`` schedules of a class — the transfer candidate pool."""
        t = target_name(target)
        out = [
            r
            for r in self.records()
            if r.instance.class_id == class_id and r.target == t
            and (models is None or r.model_id in models)
        ]
        return sorted(out, key=lambda r: (r.model_id, r.seconds))

    def class_counts(self, model_id: str, target=None) -> dict[str, int]:
        """|W_Tc| per class for one donor on one target (Eq. 1): distinct
        tuned *kernels* per class, matching the paper's per-kernel counting."""
        t = target_name(target)
        counts: dict[str, int] = {}
        for (rt, _w, m), rs in self._by_workload.items():
            if m == model_id and rt == t and rs:
                c = rs[0].instance.class_id
                counts[c] = counts.get(c, 0) + 1
        return counts

    def __len__(self) -> int:
        return len(self.records())

    # -- persistence ----------------------------------------------------------
    def save(self, path: str) -> None:
        payload = {"version": SCHEMA_VERSION,
                   "records": [r.to_json() for r in self.records()]}
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)

    @staticmethod
    def load(path: str) -> "ScheduleDB":
        with open(path) as f:
            payload = json.load(f)
        check_schema_version(payload, source=path)
        return ScheduleDB(Record.from_json(d) for d in payload["records"])

    @staticmethod
    def load_or_empty(path: str) -> "ScheduleDB":
        return ScheduleDB.load(path) if os.path.exists(path) else ScheduleDB()
