"""Extract kernel workloads from an (architecture × shape) cell.

This is the bridge between the model substrate and the transfer-tuning
core: it enumerates every Pallas-dispatched kernel the model executes for a
given shape cell — with *local* (post-sharding) extents, since schedules are
tuned for the per-chip problem — together with use counts (paper Table 1).

``dp``/``tp`` are the data(+pod) and model axis sizes of the target mesh
(1/1 = single-chip tuning, the paper's setting).
"""
from __future__ import annotations

import math
from typing import Sequence

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.workload import KernelInstance, KernelUse, dedup_uses


def _div(n: int, k: int) -> int:
    """Local extent of a dim sharded over k shards (GSPMD pads non-divisible
    dims, so the per-shard extent is the ceiling)."""
    return max(1, math.ceil(n / k)) if k > 1 else n


def extract_kernels(cfg: ArchConfig, shape: ShapeConfig, *, dp: int = 1,
                    tp: int = 1) -> list[KernelUse]:
    d, hd, f = cfg.d_model, cfg.head_dim, cfg.d_ff
    h, kv = cfg.n_heads, cfg.n_kv_heads
    dt = cfg.dtype
    decode = shape.kind == "decode"
    # chunk_prefill: seq_len tokens of one sequence attending into a cache of
    # ctx_len positions (paged serving's interleaved prefill slices).
    # verify: the speculative k+1-position verify burst — same prefill-like
    # attention geometry (short Q against a long cached KV), which is exactly
    # why chunk-prefill donors transfer onto it; unlike chunk_prefill the lm
    # head projects every position (acceptance needs all k+1 distributions).
    chunk = shape.kind in ("chunk_prefill", "verify")
    ctx = shape.ctx_len if chunk and shape.ctx_len else shape.seq_len
    b_local = _div(shape.global_batch, dp)
    s = shape.seq_len
    tokens = b_local if decode else b_local * s
    uses: list[KernelUse] = []

    def add(class_id: str, count: int, tag: str, **params):
        uses.append(KernelUse(KernelInstance.make(class_id, dtype=dt, **params),
                              use_count=count, tag=tag))

    kinds = cfg.layer_kinds
    n_attn = sum(1 for k_ in kinds if k_ in ("G", "L"))
    n_local = sum(1 for k_ in kinds if k_ == "L")
    n_global = n_attn - n_local
    n_rec = sum(1 for k_ in kinds if k_ == "R")

    # ---- attention layers ----------------------------------------------------
    if n_attn:
        add("matmul", 1 * n_attn, "attn.wq", M=tokens, N=_div(h * hd, tp), K=d)
        add("matmul", 2 * n_attn, "attn.wkv", M=tokens, N=max(_div(kv * hd, tp), hd), K=d)
        add("matmul", 1 * n_attn, "attn.wo", M=tokens, N=d, K=_div(h * hd, tp))
        q_len = 1 if decode else s
        h_loc = max(_div(h, tp), 1)
        if n_global:
            cls = "flash_attention_softcap" if cfg.attn_softcap > 0 else "flash_attention_causal"
            add(cls, n_global, "attn.global", Q=q_len, KV=ctx if chunk else s,
                H=h_loc, D=hd, B=b_local)
        if n_local:
            cls = "flash_attention_swa" if len(set(kinds)) == 1 else "flash_attention_local"
            if decode:
                kv_len = min(cfg.window, s)
            elif chunk:  # [ring prefix ‖ chunk]
                kv_len = min(cfg.window or ctx, ctx) + s
            else:
                kv_len = s
            add(cls, n_local, "attn.local", Q=q_len, KV=kv_len, H=h_loc, D=hd,
                B=b_local, window=cfg.window)
        # per-attention-layer FFN
        if cfg.n_experts > 0:
            e_loc = _div(cfg.n_experts, tp)
            ep = cfg.n_experts % tp == 0 and tp > 1
            f_loc = f if ep else _div(f, tp)
            routed = max(tokens * cfg.moe_topk // (tp if ep else 1), 1)
            add("moe_router", n_attn, "moe.router", M=tokens, N=cfg.n_experts, K=d)
            add("moe_gemm_silu_glu", n_attn, "moe.up", M=routed, N=2 * f_loc, K=d,
                E=e_loc if ep else cfg.n_experts)
            add("moe_gemm", n_attn, "moe.down", M=routed, N=d, K=f_loc,
                E=e_loc if ep else cfg.n_experts)
        else:
            _add_dense_mlp(add, cfg, tokens, tp, n_attn, d, f)

    # ---- recurrent layers ------------------------------------------------------
    if n_rec:
        t_len = 1 if decode else s
        if cfg.family == "ssm":  # rwkv6
            add("matmul", 4 * n_rec, "rwkv.proj", M=tokens, N=_div(d, tp), K=d)
            add("matmul", 1 * n_rec, "rwkv.decay_a", M=tokens, N=64, K=d)
            add("matmul", 1 * n_rec, "rwkv.decay_b", M=tokens, N=_div(d, tp), K=64)
            add("matmul", 1 * n_rec, "rwkv.wo", M=tokens, N=d, K=_div(d, tp))
            add("rwkv6_scan", n_rec, "rwkv.scan", T=t_len, C=_div(d, tp), D=hd, B=b_local)
            add("matmul", 1 * n_rec, "rwkv.ck", M=tokens, N=_div(f, tp), K=d)
            add("matmul", 1 * n_rec, "rwkv.cv", M=tokens, N=d, K=_div(f, tp))
            add("matmul", 1 * n_rec, "rwkv.cr", M=tokens, N=_div(d, tp), K=d)
        else:  # griffin
            w = cfg.rnn_width or d
            add("matmul", 2 * n_rec, "griffin.in", M=tokens, N=_div(w, tp), K=d)
            add("matmul", 1 * n_rec, "griffin.out", M=tokens, N=d, K=_div(w, tp))
            add("rglru_scan", n_rec, "griffin.scan", T=t_len, C=_div(w, tp), B=b_local)
            _add_dense_mlp(add, cfg, tokens, tp, n_rec, d, f)

    # ---- encoder (whisper) --------------------------------------------------------
    if cfg.encoder_layers:
        enc_tokens = b_local * cfg.encoder_seq
        ne = cfg.encoder_layers
        add("matmul", 3 * ne, "enc.qkv", M=enc_tokens, N=_div(h * hd, tp), K=d)
        add("matmul", 1 * ne, "enc.wo", M=enc_tokens, N=d, K=_div(h * hd, tp))
        add("flash_attention_bidir", ne, "enc.attn", Q=cfg.encoder_seq,
            KV=cfg.encoder_seq, H=max(_div(h, tp), 1), D=hd, B=b_local)
        _add_dense_mlp(add, cfg, enc_tokens, tp, ne, d, f)
        # decoder cross-attention
        q_len = 1 if decode else s
        add("matmul", 1 * cfg.n_layers, "dec.cross_q", M=tokens, N=_div(h * hd, tp), K=d)
        add("matmul", 2 * cfg.n_layers, "dec.cross_kv", M=enc_tokens,
            N=max(_div(kv * hd, tp), hd), K=d)
        add("flash_attention_cross", cfg.n_layers, "dec.cross", Q=q_len,
            KV=cfg.encoder_seq, H=max(_div(h, tp), 1), D=hd, B=b_local)

    # ---- vlm projector ---------------------------------------------------------------
    if cfg.vision_tokens and not decode:
        add("matmul", 1, "vlm.proj", M=b_local * cfg.vision_tokens, N=_div(d, tp), K=d)

    # ---- lm head ------------------------------------------------------------------------
    head_cls = "matmul_lmhead_softcap" if cfg.final_softcap > 0 else "matmul_lmhead"
    # decode and chunk_prefill project logits for the last position only;
    # verify projects all k+1 positions (acceptance compares each of them)
    head_tokens = b_local if (decode or shape.kind == "chunk_prefill") else tokens
    add(head_cls, 1, "lm_head", M=head_tokens, N=_div(cfg.vocab_size, tp), K=d)

    return dedup_uses(uses)


def _add_dense_mlp(add, cfg: ArchConfig, tokens: int, tp: int, count: int,
                   d: int, f: int) -> None:
    f_loc = _div(f, tp)
    if cfg.mlp_kind == "swiglu":
        add("matmul_silu_glu", count, "mlp.up", M=tokens, N=2 * f_loc, K=d)
        add("matmul", count, "mlp.down", M=tokens, N=d, K=f_loc)
    elif cfg.mlp_kind == "geglu":
        add("matmul_gelu_glu", count, "mlp.up", M=tokens, N=2 * f_loc, K=d)
        add("matmul", count, "mlp.down", M=tokens, N=d, K=f_loc)
    else:
        if cfg.mlp_bias:
            add("matmul_bias_gelu", count, "mlp.up", M=tokens, N=f_loc, K=d)
            add("matmul_bias", count, "mlp.down", M=tokens, N=d, K=f_loc)
        else:
            add("matmul_bias_gelu", count, "mlp.up", M=tokens, N=f_loc, K=d)
            add("matmul", count, "mlp.down", M=tokens, N=d, K=f_loc)
