"""repro: Transfer-Tuning (Gibson & Cano 2022) as a production JAX framework.

Reuses auto-schedules across kernel classes to cut tensor-program tuning
cost, integrated as a first-class feature of a multi-pod training/serving
stack for 10 LM-family architectures on TPU v5e.
"""
__version__ = "1.0.0"
