"""Serving-fleet subsystem.

A request front-end over N :class:`~repro.serving.ServingEngine` replicas:

    traffic.py ..... seeded request streams (Poisson, bursty, diurnal, replay)
    router.py ...... bounded admission queue + pluggable dispatch policies
    demand.py ...... decayed per-bucket arrival counts driving tuning order
    acceptance.py .. decayed per-class speculative acceptance estimates
    metrics.py ..... latency percentiles, windowed telemetry, shed accounting
    autoscale.py ... hysteresis autoscaler over the windowed telemetry
    advisor.py ..... telemetry-driven tuning priority (critical-path seconds
                     x speedup headroom), replacing demand-count ordering
    fleet.py ....... replicas + shared-registry propagation + the serve loop
                     + elastic lifecycle (warm-join / drain-retire)
"""
from repro.fleet.acceptance import AcceptanceTracker
from repro.fleet.advisor import RankedWorkload, TuningAdvisor
from repro.fleet.autoscale import Autoscaler, ScaleDecision
from repro.fleet.demand import DemandTracker
from repro.fleet.fleet import PagedReplica, Replica, ServingFleet
from repro.fleet.metrics import FleetMetrics, percentile
from repro.fleet.router import (
    POLICIES,
    DispatchPolicy,
    LeastLoaded,
    PlanAware,
    QueueFull,
    RequestRouter,
    RoundRobin,
    make_policy,
    register_policy,
)
from repro.fleet.traffic import (
    BurstyTraffic,
    DiurnalTraffic,
    FleetRequest,
    TrafficGenerator,
    VariableRateTraffic,
    load_trace,
    sample_prompts,
    save_trace,
)

__all__ = [
    "AcceptanceTracker",
    "Autoscaler",
    "BurstyTraffic",
    "DemandTracker",
    "DispatchPolicy",
    "DiurnalTraffic",
    "FleetMetrics",
    "FleetRequest",
    "LeastLoaded",
    "POLICIES",
    "PagedReplica",
    "PlanAware",
    "QueueFull",
    "RankedWorkload",
    "Replica",
    "RequestRouter",
    "RoundRobin",
    "ScaleDecision",
    "ServingFleet",
    "TrafficGenerator",
    "TuningAdvisor",
    "VariableRateTraffic",
    "load_trace",
    "make_policy",
    "percentile",
    "register_policy",
    "sample_prompts",
    "save_trace",
]
