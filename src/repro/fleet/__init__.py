"""Serving-fleet subsystem.

A request front-end over N :class:`~repro.serving.ServingEngine` replicas:

    traffic.py ..... seeded synthetic request streams (Poisson, mixed shapes)
    router.py ...... bounded admission queue + pluggable dispatch policies
    demand.py ...... per-bucket arrival counts driving demand-driven tuning
    metrics.py ..... latency percentiles, throughput, queue/shed telemetry
    fleet.py ....... replicas + shared-registry propagation + the serve loop
"""
from repro.fleet.demand import DemandTracker
from repro.fleet.fleet import PagedReplica, Replica, ServingFleet
from repro.fleet.metrics import FleetMetrics, percentile
from repro.fleet.router import (
    POLICIES,
    DispatchPolicy,
    LeastLoaded,
    PlanAware,
    QueueFull,
    RequestRouter,
    RoundRobin,
    make_policy,
    register_policy,
)
from repro.fleet.traffic import FleetRequest, TrafficGenerator, sample_prompts

__all__ = [
    "DemandTracker",
    "DispatchPolicy",
    "FleetMetrics",
    "FleetRequest",
    "LeastLoaded",
    "POLICIES",
    "PagedReplica",
    "PlanAware",
    "QueueFull",
    "Replica",
    "RequestRouter",
    "RoundRobin",
    "ServingFleet",
    "TrafficGenerator",
    "make_policy",
    "percentile",
    "register_policy",
    "sample_prompts",
]
