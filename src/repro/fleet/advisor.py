"""Telemetry-driven tuning priority: critical-path seconds x headroom.

The fleet's original prefetch ordering was demand counts — tune whatever
arrives most.  That conflates *traffic* with *impact*: a hot bucket whose
kernels are already near their attainable speedup outranks a cooler one
whose kernels still run 2x slower than the donor pool suggests they could.
Ansor prioritizes tuning time across subgraphs by estimated end-to-end
impact; the :class:`TuningAdvisor` applies the same idea one level up,
ranking every un-exhausted workload the fleet has actually executed by

    priority = critical-path seconds observed  x  remaining speedup headroom

Critical-path seconds come from the live profiler
(:func:`repro.obs.profiler.live_workload_seconds` — replica cell counters
times plan-derived kernel costs, no tracer required).  Headroom is a *class
prior* estimated from the donor pool: the best donor-record-to-untuned
ratio of the workload's schedule class bounds how much a transfer is likely
to recover, before spending any search on the workload itself (the same
cheap-estimate-steers-expensive-measurement principle as Pruner's
draft stage).  Workloads that already resolved at the exact tier, or whose
background job already ran, are exhausted — the advisor skips them, so
tuning budget always flows to the largest remaining (seconds x headroom)
product.
"""
from __future__ import annotations

import dataclasses

from repro.obs.profiler import live_workload_seconds


@dataclasses.dataclass(frozen=True)
class RankedWorkload:
    """One advisor recommendation, strongest first."""

    instance: object          # KernelInstance to prefetch
    target: str
    critical_s: float         # observed critical-path seconds
    headroom: float           # estimated remaining speedup fraction (0..1)
    priority: float           # critical_s * headroom — the queue priority


class TuningAdvisor:
    """Ranks un-exhausted workloads for :meth:`TuningService.prefetch`.

    ``default_headroom`` is assumed when a class has no donor records to
    estimate from; ``min_headroom`` keeps every candidate's priority
    positive so observed-but-low-headroom work still outranks never-observed
    work instead of dropping to zero (the anti-starvation floor —
    ``TuningService.stats()``'s starvation counters verify it suffices).
    """

    def __init__(self, *, default_headroom: float = 0.5,
                 min_headroom: float = 0.05):
        self.default_headroom = default_headroom
        self.min_headroom = min_headroom
        self._prior_cache: dict[tuple[str, str], float] = {}

    def class_headroom(self, instance, svc, db) -> float:
        """Prior speedup headroom for ``instance``'s schedule class.

        ``1 - min(donor seconds / untuned seconds)`` over the service's
        donor pool for the class: if the best donor of this class reached a
        3x speedup on its own workload, a transfer plausibly recovers most
        of a similar ratio here.  Cached per (class, target) — the donor
        pool is fixed for a service's lifetime.
        """
        key = (instance.class_id, svc.target)
        h = self._prior_cache.get(key)
        if h is None:
            ratios = []
            for rec in db.by_class(instance.class_id,
                                   models=svc.donor_models(db),
                                   target=svc.donor_target):
                untuned = svc.runner.seconds(rec.instance, None)
                if untuned > 0:
                    ratios.append(rec.seconds / untuned)
            h = (1.0 - min(ratios)) if ratios else self.default_headroom
            h = self._prior_cache[key] = min(max(h, self.min_headroom), 1.0)
        return h

    def rank(self, fleet) -> list[RankedWorkload]:
        """Rank every executed, un-exhausted workload, highest priority
        first (ties broken by workload key for determinism)."""
        crit = live_workload_seconds(fleet.live_replicas())
        services = fleet.services
        snaps: dict = {}
        out = []
        for (key, target), row in crit.items():
            svc = services.get(target)
            if svc is None:
                continue
            db = snaps.get(target)
            if db is None:
                db = snaps[target] = svc.registry.snapshot().db(None)
            inst = row["instance"]
            if db.exact(inst, target=svc.target) is not None:
                continue  # exhausted: already serving an exact record
            if svc.attempted(key):
                continue  # exhausted: search ran, found nothing better
            h = self.class_headroom(inst, svc, db)
            out.append(RankedWorkload(inst, target, row["seconds"], h,
                                      row["seconds"] * h))
        out.sort(key=lambda r: (-r.priority, r.instance.workload_key()))
        return out
