"""Elastic-fleet autoscaler: windowed telemetry in, scale decisions out.

The paper's economics make *tuning* cheap enough to follow demand
(transfer-tuning produces a serving-grade schedule in seconds, where a full
Ansor search cannot react on-line); the fleet's demand-driven prefetch
already exploits that.  This module closes the remaining loop — *capacity*
following demand: an :class:`Autoscaler` watches the same windowed signal
the metrics pipeline produces (queue depth, shed rate, replica utilization,
p95 trend) and emits scale-up / scale-down decisions that
:class:`~repro.fleet.fleet.ServingFleet` turns into replica lifecycle
actions (warm-join / drain-retire).

The controller is deliberately boring — thresholds with hysteresis — because
the interesting property lives elsewhere: a *joining* replica is cheap only
because the shared :class:`~repro.service.ScheduleRegistry` lets it boot at
the fleet's current schedule tier (its execution plan resolves every shape
the fleet already tuned at the exact tier, the transfer-tuning analogue of
warm-starting search from a donor).  Without that, every scale-up would
serve default-tier schedules until background tuning caught up, and the
elasticity win would be eaten by cold-start latency.

Hysteresis has three guards, each pinned by a test:

* **N-consecutive windows** — one hot window never scales; ``up_windows``
  (resp. ``down_windows``) consecutive windows of pressure must agree, so
  a single burst-edge sample cannot flap the fleet.
* **Cooldown** — after any scale action, decisions hold for ``cooldown_s``
  virtual seconds: the fleet observes the *scaled* system before scaling
  again (a joining replica needs a window to absorb queue backlog).
* **Bounds** — the live replica count is clamped to
  ``[min_replicas, max_replicas]``.
"""
from __future__ import annotations

import dataclasses

from repro.obs import NULL_TRACER, MetricsRegistry


@dataclasses.dataclass(frozen=True)
class ScaleDecision:
    """One autoscaler evaluation: what it decided and why."""

    t: float            # virtual instant the decision was made
    action: str         # "up" | "down" | "hold"
    reason: str         # which signal (or guard) produced the action
    replicas: int       # live replica count when decided
    window: dict        # the metrics window the decision was based on


class Autoscaler:
    """Threshold-with-hysteresis controller over windowed fleet telemetry.

    :meth:`observe` consumes one metrics window
    (:meth:`~repro.fleet.metrics.FleetMetrics.window` dict) per
    ``window_s`` of virtual time and returns a :class:`ScaleDecision`.
    The caller (the fleet's serve loop) applies ``up``/``down`` actions;
    every decision is appended to :attr:`decisions` for the audit trail.

    Scale-up pressure (any one suffices):
      * mean queue depth above ``queue_high`` — work is waiting;
      * shed rate above ``shed_high`` — work is being *lost*;
      * mean utilization above ``util_high`` — no headroom for a burst;
      * p95 latency rose by more than ``p95_rise`` versus the previous
        window — the system is falling behind even before queues show it.

    Scale-down requires a *quiet* window (all must hold): utilization below
    ``util_low``, mean queue depth below ``queue_low``, and zero sheds.
    """

    def __init__(self, *, min_replicas: int = 1, max_replicas: int = 4,
                 window_s: float, cooldown_s: float,
                 up_windows: int = 1, down_windows: int = 2,
                 queue_high: float = 2.0, shed_high: float = 0.0,
                 util_high: float = 0.9, util_low: float = 0.35,
                 queue_low: float = 0.5, p95_rise: float = 0.5,
                 metrics: MetricsRegistry | None = None, tracer=None):
        if not 1 <= min_replicas <= max_replicas:
            raise ValueError("need 1 <= min_replicas <= max_replicas")
        if window_s <= 0 or cooldown_s < 0:
            raise ValueError("window_s must be positive, cooldown_s >= 0")
        if up_windows < 1 or down_windows < 1:
            raise ValueError("up_windows/down_windows must be >= 1")
        self.min_replicas = min_replicas
        self.max_replicas = max_replicas
        self.window_s = window_s
        self.cooldown_s = cooldown_s
        self.up_windows = up_windows
        self.down_windows = down_windows
        self.queue_high = queue_high
        self.shed_high = shed_high
        self.util_high = util_high
        self.util_low = util_low
        self.queue_low = queue_low
        self.p95_rise = p95_rise
        self.decisions: list[ScaleDecision] = []
        self._up_streak = 0
        self._down_streak = 0
        self._last_scale_t: float | None = None
        self._prev_p95 = 0.0
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._bind_metrics(metrics if metrics is not None
                           else MetricsRegistry())

    def _bind_metrics(self, metrics: MetricsRegistry) -> None:
        self.metrics = metrics
        self._counters = metrics.group(
            "autoscaler", ["evaluations", "scale_ups", "scale_downs",
                           "holds"])

    def bind_obs(self, tracer, metrics: MetricsRegistry) -> None:
        """Re-home telemetry into the owner's tracer/registry.

        Fleets construct their observability sinks after the controller is
        built (``attach_autoscaler``), so the controller's counters move to
        the fleet registry — carrying any evaluations already made.
        """
        self.tracer = tracer
        old = {n: self._counters[n] for n in self._counters}
        self._bind_metrics(metrics)
        for n, v in old.items():
            if v:
                self._counters.inc(n, v)

    # -- pressure classification ----------------------------------------------
    def _up_reason(self, w: dict) -> str | None:
        # SLO burn-rate alerts (windows carry them when the fleet runs an
        # SLOMonitor) outrank the raw-signal thresholds: a burning error
        # budget is the user-facing definition of "falling behind".
        if w.get("slo_alerts", 0) > 0:
            return f"slo burn-rate alert on {w['slo_alerts']} objective(s)"
        if w["queue_depth_mean"] > self.queue_high:
            return f"queue_depth_mean {w['queue_depth_mean']:.2f} > {self.queue_high}"
        if w["shed_rate"] > self.shed_high:
            return f"shed_rate {w['shed_rate']:.2f} > {self.shed_high}"
        if w["utilization_mean"] > self.util_high:
            return f"utilization {w['utilization_mean']:.2f} > {self.util_high}"
        p95 = w["latency_s"]["p95"]
        if self._prev_p95 > 0 and p95 > self._prev_p95 * (1 + self.p95_rise):
            return f"p95 rose {p95 / self._prev_p95:.2f}x"
        return None

    def _down_ok(self, w: dict) -> bool:
        return (w["utilization_mean"] < self.util_low
                and w["queue_depth_mean"] < self.queue_low
                and w["shed"] == 0
                and w.get("slo_alerts", 0) == 0)

    # -- the decision ----------------------------------------------------------
    def observe(self, window: dict, *, now: float, replicas: int
                ) -> ScaleDecision:
        """Fold one telemetry window into the controller state and decide.

        ``replicas`` is the *live* (active + draining) count — the capacity
        that exists, which is what the bounds clamp.
        """
        up_reason = self._up_reason(window)
        if up_reason is not None:
            self._up_streak += 1
            self._down_streak = 0
        elif self._down_ok(window):
            self._down_streak += 1
            self._up_streak = 0
        else:
            self._up_streak = self._down_streak = 0
        self._prev_p95 = window["latency_s"]["p95"]

        action, reason = "hold", "no pressure"
        if self._up_streak >= self.up_windows:
            action, reason = "up", up_reason or "up pressure"
        elif self._down_streak >= self.down_windows:
            action, reason = "down", (
                f"quiet: util {window['utilization_mean']:.2f} < "
                f"{self.util_low}, queue {window['queue_depth_mean']:.2f} < "
                f"{self.queue_low}, 0 sheds")

        # Guards, strongest first: cooldown, then bounds.  Streaks are NOT
        # reset by a guard — pressure observed during cooldown still counts,
        # so a sustained burst acts the instant the cooldown expires.
        if action != "hold":
            in_cooldown = (self._last_scale_t is not None
                           and now - self._last_scale_t < self.cooldown_s)
            if in_cooldown:
                action, reason = "hold", "cooldown"
            elif action == "up" and replicas >= self.max_replicas:
                action, reason = "hold", f"at max_replicas {self.max_replicas}"
            elif action == "down" and replicas <= self.min_replicas:
                action, reason = "hold", f"at min_replicas {self.min_replicas}"
            else:
                self._last_scale_t = now
                self._up_streak = self._down_streak = 0

        decision = ScaleDecision(t=now, action=action, reason=reason,
                                 replicas=replicas, window=window)
        self.decisions.append(decision)
        self._counters["evaluations"] += 1
        self._counters[{"up": "scale_ups", "down": "scale_downs",
                        "hold": "holds"}[action]] += 1
        if self.tracer.enabled:
            self.tracer.event(
                "scale_decision", "autoscaler", t=now, action=action,
                reason=reason, replicas=replicas,
                queue_depth_mean=window["queue_depth_mean"],
                utilization_mean=window["utilization_mean"],
                shed=window["shed"], p95=window["latency_s"]["p95"])
        return decision

    # -- telemetry ------------------------------------------------------------
    def stats(self) -> dict:
        return {
            "evaluations": int(self._counters["evaluations"]),
            "scale_ups": int(self._counters["scale_ups"]),
            "scale_downs": int(self._counters["scale_downs"]),
            "holds": int(self._counters["holds"]),
            "window_s": self.window_s,
            "cooldown_s": self.cooldown_s,
            "min_replicas": self.min_replicas,
            "max_replicas": self.max_replicas,
        }
