"""Demand tracking: per-(prefill-bucket) arrival counts drive tuning order.

The paper's economics are about *where to spend search*: transfer-tuning
makes each tuned schedule cheap, but a fleet still has a bounded background
tuning budget, so the order in which shapes graduate default → transfer →
exact matters.  :class:`DemandTracker` aggregates what the router actually
sees — arrival counts keyed by prefill bucket — and ranks buckets hottest
first, so the fleet can prefetch tuning jobs for the shapes traffic is
hitting *now* while cold shapes never spend budget.
"""
from __future__ import annotations

import collections
from typing import Callable

from repro.fleet.traffic import FleetRequest


class DemandTracker:
    """Arrival counts per workload bucket (prefill bucket length).

    ``bucket_for`` maps a prompt length to its bucket — normally the
    reference replica's :meth:`~repro.serving.ServingEngine.bucket_for`, so
    demand is keyed exactly the way the engines pad and the plans resolve.
    Without one, the raw prompt length is the bucket.
    """

    def __init__(self, bucket_for: "Callable[[int], int] | None" = None):
        self.bucket_for = bucket_for
        self.counts: collections.Counter[int] = collections.Counter()

    def record(self, req: FleetRequest) -> int:
        """Count one arrival; stamps and returns the request's bucket."""
        n = len(req.prompt)
        bucket = self.bucket_for(n) if self.bucket_for is not None else n
        req.bucket = bucket
        self.counts[bucket] += 1
        return bucket

    @property
    def total(self) -> int:
        return sum(self.counts.values())

    def hottest(self) -> list[tuple[int, int]]:
        """(bucket, count) pairs, hottest first (ties: smaller bucket)."""
        return sorted(self.counts.items(), key=lambda kv: (-kv[1], kv[0]))

    def weighted(self, value_of: Callable[[int], float]) -> float:
        """Traffic-weighted mean of a per-bucket value (0.0 with no demand)."""
        total = self.total
        if total == 0:
            return 0.0
        return sum(c * value_of(b) for b, c in self.counts.items()) / total

    def stats(self) -> dict:
        return {"total": self.total,
                "buckets": {str(b): c for b, c in self.hottest()}}
