"""Demand tracking: per-(prefill-bucket) arrival counts drive tuning order.

The paper's economics are about *where to spend search*: transfer-tuning
makes each tuned schedule cheap, but a fleet still has a bounded background
tuning budget, so the order in which shapes graduate default → transfer →
exact matters.  :class:`DemandTracker` aggregates what the router actually
sees — arrival counts keyed by prefill bucket — and ranks buckets hottest
first, so the fleet can prefetch tuning jobs for the shapes traffic is
hitting *now* while cold shapes never spend budget.

With ``half_life_s`` set, counts decay exponentially in *virtual seconds*
(each arrival's weight halves every ``half_life_s`` of trace time), so the
ranking tracks current traffic: a bucket that was hot an hour ago no longer
outranks the bucket that is hot now.  This is the signal both prefetch
priority and the autoscaler's demand view consume — without decay, a load
shift would keep tuning (and scaling for) yesterday's shapes.
"""
from __future__ import annotations

import collections
from typing import Callable

from repro.fleet.traffic import FleetRequest

#: Decayed weights below this are dropped from the table entirely: a bucket
#: that has not seen traffic for many half-lives stops being demand at all.
_EPS = 1e-9


class DemandTracker:
    """Arrival counts per workload bucket (prefill bucket length).

    ``bucket_for`` maps a prompt length to its bucket — normally the
    reference replica's :meth:`~repro.serving.ServingEngine.bucket_for`, so
    demand is keyed exactly the way the engines pad and the plans resolve.
    Without one, the raw prompt length is the bucket.

    ``half_life_s``: when set, every count decays by ``0.5 ** (dt /
    half_life_s)`` as the stream clock (the latest ``arrival_s`` seen)
    advances ``dt`` virtual seconds.  ``None`` (default) keeps exact integer
    counts that never decay.
    """

    def __init__(self, bucket_for: "Callable[[int], int] | None" = None, *,
                 half_life_s: float | None = None):
        if half_life_s is not None and half_life_s <= 0:
            raise ValueError("half_life_s must be positive")
        self.bucket_for = bucket_for
        self.half_life_s = half_life_s
        self.counts: collections.Counter[int] = collections.Counter()
        self._now = 0.0  # stream clock: the latest arrival time seen

    def _decay_to(self, t: float) -> None:
        """Advance the stream clock to ``t``, decaying every bucket."""
        if self.half_life_s is None or t <= self._now:
            return
        factor = 0.5 ** ((t - self._now) / self.half_life_s)
        self._now = t
        for b in list(self.counts):
            v = self.counts[b] * factor
            if v < _EPS:
                del self.counts[b]
            else:
                self.counts[b] = v

    def record(self, req: FleetRequest) -> int:
        """Count one arrival; stamps and returns the request's bucket."""
        n = len(req.prompt)
        bucket = self.bucket_for(n) if self.bucket_for is not None else n
        req.bucket = bucket
        self._decay_to(req.arrival_s)
        self.counts[bucket] += 1
        return bucket

    @property
    def total(self) -> float:
        return sum(self.counts.values())

    def hottest(self) -> list[tuple[int, float]]:
        """(bucket, count) pairs, hottest first (ties: smaller bucket)."""
        return sorted(self.counts.items(), key=lambda kv: (-kv[1], kv[0]))

    def weighted(self, value_of: Callable[[int], float]) -> float:
        """Traffic-weighted mean of a per-bucket value (0.0 with no demand)."""
        total = self.total
        if total == 0:
            return 0.0
        return sum(c * value_of(b) for b, c in self.counts.items()) / total

    def stats(self) -> dict:
        return {"total": self.total,
                "half_life_s": self.half_life_s,
                "buckets": {str(b): round(c, 4) if self.half_life_s else c
                            for b, c in self.hottest()}}
