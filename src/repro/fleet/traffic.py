"""Synthetic serving traffic: seeded Poisson arrivals, mixed request shapes.

The fleet benchmarks need *reproducible-but-variable* load: the same seed
must replay the identical request stream across routing policies (so policy
comparisons are apples-to-apples on one trace), while different seeds vary
the arrival pattern.  :class:`TrafficGenerator` produces such traces — a
Poisson arrival process (exponential inter-arrival times) over a mixture of
short and long prompts with per-request new-token counts and optional
deadlines.

Times are expressed in *ticks* — one tick is the untuned decode-step cost of
a reference replica (the fleet computes it from the cost model) — so an
``arrival_rate`` of 0.5 means "one request every two untuned step times"
regardless of the arch being served.

:func:`sample_prompts` is the shared single-engine stream sampler
(``launch/serve.py --seed`` uses it), kept here so serve and fleet runs draw
from the same distribution family.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class FleetRequest:
    """One request flowing through the router; outcome fields are filled in
    by the fleet as the request is queued, dispatched, and completed."""

    uid: int
    prompt: list[int]
    max_new_tokens: int
    arrival_s: float                 # virtual seconds
    deadline_s: float | None = None  # absolute; None -> never shed on age
    eos_id: int | None = None
    # -- routing outcome ------------------------------------------------------
    bucket: int = 0                  # prefill bucket the demand tracker keyed
    replica: int | None = None
    admitted_s: float | None = None
    finished_s: float | None = None
    shed: str = ""                   # "" | "queue_full" | "deadline" | "invalid"
    tokens: int = 0
    exact_share_at_admit: float = 0.0

    @property
    def latency_s(self) -> float | None:
        if self.finished_s is None:
            return None
        return self.finished_s - self.arrival_s


def sample_prompts(rng: np.random.Generator, n: int, vocab_size: int, *,
                   lo: int = 3, hi: int = 8) -> list[list[int]]:
    """``n`` random-token prompts with uniform[lo, hi] lengths.

    The single-engine serve driver's stream; the fleet generator's "short"
    mixture component uses the same family.
    """
    return [[int(t) for t in rng.integers(1, vocab_size,
                                          size=int(rng.integers(lo, hi + 1)))]
            for _ in range(n)]


class TrafficGenerator:
    """Seeded synthetic request stream for fleet serving.

    * **Arrivals** — Poisson process: exponential inter-arrival times with
      mean ``tick_s / arrival_rate`` (``arrival_rate`` = expected requests
      per tick).
    * **Prompt lengths** — a two-component mixture: ``long_frac`` of
      requests draw uniform from ``long_lens``, the rest from
      ``short_lens``; lengths are clamped to ``prompt_cap``.  The skew makes
      one prefill bucket *hot*, which is what demand-driven tuning exploits.
    * **New tokens** — uniform from ``new_tokens``; when
      ``long_new_tokens`` is given, requests from the long prompt component
      draw from it instead.  Coupling long prompts with long generations
      makes the footprint distribution *long-tailed*: capacity must be
      provisioned for the rare worst case while the typical request is much
      smaller — the regime where paged KV memory pays off.
    * **Deadlines** — ``deadline_ticks`` ticks after arrival (None: never
      expire).
    """

    def __init__(self, *, seed: int = 0, vocab_size: int = 256,
                 arrival_rate: float = 0.5, tick_s: float = 1.0,
                 short_lens: tuple[int, int] = (3, 8),
                 long_lens: tuple[int, int] = (16, 32),
                 long_frac: float = 0.25,
                 new_tokens: tuple[int, int] = (4, 8),
                 long_new_tokens: tuple[int, int] | None = None,
                 deadline_ticks: float | None = None,
                 prompt_cap: int | None = None):
        if arrival_rate <= 0:
            raise ValueError("arrival_rate must be positive")
        self.rng = np.random.default_rng(seed)
        self.seed = seed
        self.vocab_size = vocab_size
        self.arrival_rate = arrival_rate
        self.tick_s = tick_s
        self.short_lens = short_lens
        self.long_lens = long_lens
        self.long_frac = long_frac
        self.new_tokens = new_tokens
        self.long_new_tokens = long_new_tokens
        self.deadline_ticks = deadline_ticks
        self.prompt_cap = prompt_cap
        self._uid = 0
        self._t = 0.0  # stream clock: carried across trace() calls

    def _shape(self) -> tuple[int, int]:
        """(prompt_len, max_new_tokens) for one request."""
        long = self.rng.random() < self.long_frac
        lo, hi = self.long_lens if long else self.short_lens
        n = int(self.rng.integers(lo, hi + 1))
        if self.prompt_cap is not None:
            n = min(n, self.prompt_cap)
        nt = (self.long_new_tokens if long and self.long_new_tokens is not None
              else self.new_tokens)
        mnt = int(self.rng.integers(nt[0], nt[1] + 1))
        return max(n, 1), mnt

    def trace(self, n_requests: int) -> list[FleetRequest]:
        """``n_requests`` arrivals in order; repeated calls continue the
        stream (fresh generator + same seed -> identical trace)."""
        out: list[FleetRequest] = []
        mean_gap = self.tick_s / self.arrival_rate
        for _ in range(n_requests):
            self._t += float(self.rng.exponential(mean_gap))
            t = self._t
            plen, mnt = self._shape()
            prompt = [int(x) for x in
                      self.rng.integers(1, self.vocab_size, size=plen)]
            deadline = (t + self.deadline_ticks * self.tick_s
                        if self.deadline_ticks is not None else None)
            self._uid += 1
            out.append(FleetRequest(uid=self._uid, prompt=prompt,
                                    max_new_tokens=mnt, arrival_s=t,
                                    deadline_s=deadline))
        return out
