"""Synthetic serving traffic: seeded arrivals, mixed request shapes.

The fleet benchmarks need *reproducible-but-variable* load: the same seed
must replay the identical request stream across routing policies (so policy
comparisons are apples-to-apples on one trace), while different seeds vary
the arrival pattern.  :class:`TrafficGenerator` produces such traces — a
Poisson arrival process (exponential inter-arrival times) over a mixture of
short and long prompts with per-request new-token counts and optional
deadlines.

A stationary Poisson process cannot exercise *capacity* decisions — its
smoothed rate never moves, so an autoscaler watching it would correctly
never scale.  Two non-homogeneous generators (both Lewis–Shedler thinning
over a deterministic rate curve) provide production-shaped load:

* :class:`BurstyTraffic` — a square wave: ``arrival_rate`` between bursts,
  ``burst_rate`` inside periodic bursts (``burst_every_ticks`` period,
  ``burst_len_ticks`` duration).  ``phase_at(t)`` labels each instant so
  benchmarks can compare per-phase windows.
* :class:`DiurnalTraffic` — a sinusoid: rate swings ``±amplitude`` around
  ``arrival_rate`` with period ``period_ticks`` (the day/night curve).

:func:`save_trace` / :func:`load_trace` round-trip any request list through
JSON-lines, so a recorded production log (arrival timestamps + prompt +
token budget) replays through ``ServingFleet.serve`` exactly.

Times are expressed in *ticks* — one tick is the untuned decode-step cost of
a reference replica (the fleet computes it from the cost model) — so an
``arrival_rate`` of 0.5 means "one request every two untuned step times"
regardless of the arch being served.

:func:`sample_prompts` is the shared single-engine stream sampler
(``launch/serve.py --seed`` uses it), kept here so serve and fleet runs draw
from the same distribution family.
"""
from __future__ import annotations

import dataclasses
import json
import math

import numpy as np


@dataclasses.dataclass
class FleetRequest:
    """One request flowing through the router; outcome fields are filled in
    by the fleet as the request is queued, dispatched, and completed."""

    uid: int
    prompt: list[int]
    max_new_tokens: int
    arrival_s: float                 # virtual seconds
    deadline_s: float | None = None  # absolute; None -> never shed on age
    eos_id: int | None = None
    request_class: str = ""          # workload class ("chat", "bulk", ...); ""=unclassified
    # -- routing outcome ------------------------------------------------------
    bucket: int = 0                  # prefill bucket the demand tracker keyed
    replica: int | None = None
    admitted_s: float | None = None
    prefill_done_s: float | None = None  # first generated token available
    finished_s: float | None = None
    shed: str = ""                   # "" | "queue_full" | "deadline" | "invalid"
    shed_s: float | None = None      # virtual instant the shed happened
    speculative: bool | None = None  # admit-time spec decision (None: n/a)
    tokens: int = 0
    generated: list[int] | None = None  # the served token ids, for audits
    exact_share_at_admit: float = 0.0

    @property
    def latency_s(self) -> float | None:
        if self.finished_s is None:
            return None
        return self.finished_s - self.arrival_s


def sample_prompts(rng: np.random.Generator, n: int, vocab_size: int, *,
                   lo: int = 3, hi: int = 8) -> list[list[int]]:
    """``n`` random-token prompts with uniform[lo, hi] lengths.

    The single-engine serve driver's stream; the fleet generator's "short"
    mixture component uses the same family.
    """
    return [[int(t) for t in rng.integers(1, vocab_size,
                                          size=int(rng.integers(lo, hi + 1)))]
            for _ in range(n)]


class TrafficGenerator:
    """Seeded synthetic request stream for fleet serving.

    * **Arrivals** — Poisson process: exponential inter-arrival times with
      mean ``tick_s / arrival_rate`` (``arrival_rate`` = expected requests
      per tick).
    * **Prompt lengths** — a two-component mixture: ``long_frac`` of
      requests draw uniform from ``long_lens``, the rest from
      ``short_lens``; lengths are clamped to ``prompt_cap``.  The skew makes
      one prefill bucket *hot*, which is what demand-driven tuning exploits.
    * **New tokens** — uniform from ``new_tokens``; when
      ``long_new_tokens`` is given, requests from the long prompt component
      draw from it instead.  Coupling long prompts with long generations
      makes the footprint distribution *long-tailed*: capacity must be
      provisioned for the rare worst case while the typical request is much
      smaller — the regime where paged KV memory pays off.
    * **Deadlines** — ``deadline_ticks`` ticks after arrival (None: never
      expire).
    * **Classes** — ``class_mix`` (e.g. ``{"chat": 0.7, "bulk": 0.3}``)
      stamps each request with a seeded workload class; the router's
      acceptance-aware speculative policy keys off it.  ``None`` (default)
      draws no extra randomness, so legacy seeded traces are unchanged.
    """

    def __init__(self, *, seed: int = 0, vocab_size: int = 256,
                 arrival_rate: float = 0.5, tick_s: float = 1.0,
                 short_lens: tuple[int, int] = (3, 8),
                 long_lens: tuple[int, int] = (16, 32),
                 long_frac: float = 0.25,
                 new_tokens: tuple[int, int] = (4, 8),
                 long_new_tokens: tuple[int, int] | None = None,
                 deadline_ticks: float | None = None,
                 prompt_cap: int | None = None,
                 class_mix: dict[str, float] | None = None):
        if arrival_rate <= 0:
            raise ValueError("arrival_rate must be positive")
        if class_mix is not None:
            if not class_mix or any(w < 0 for w in class_mix.values()):
                raise ValueError("class_mix needs non-negative weights")
            if sum(class_mix.values()) <= 0:
                raise ValueError("class_mix weights must sum to > 0")
        self.rng = np.random.default_rng(seed)
        self.seed = seed
        self.vocab_size = vocab_size
        self.arrival_rate = arrival_rate
        self.tick_s = tick_s
        self.short_lens = short_lens
        self.long_lens = long_lens
        self.long_frac = long_frac
        self.new_tokens = new_tokens
        self.long_new_tokens = long_new_tokens
        self.deadline_ticks = deadline_ticks
        self.prompt_cap = prompt_cap
        self.class_mix = class_mix
        self._uid = 0
        self._t = 0.0  # stream clock: carried across trace() calls

    def _shape(self) -> tuple[int, int]:
        """(prompt_len, max_new_tokens) for one request."""
        long = self.rng.random() < self.long_frac
        lo, hi = self.long_lens if long else self.short_lens
        n = int(self.rng.integers(lo, hi + 1))
        if self.prompt_cap is not None:
            n = min(n, self.prompt_cap)
        nt = (self.long_new_tokens if long and self.long_new_tokens is not None
              else self.new_tokens)
        mnt = int(self.rng.integers(nt[0], nt[1] + 1))
        return max(n, 1), mnt

    def _next_arrival(self) -> float:
        """Advance the stream clock to the next arrival and return it."""
        self._t += float(self.rng.exponential(self.tick_s / self.arrival_rate))
        return self._t

    def _emit(self, t: float) -> FleetRequest:
        plen, mnt = self._shape()
        prompt = [int(x) for x in
                  self.rng.integers(1, self.vocab_size, size=plen)]
        deadline = (t + self.deadline_ticks * self.tick_s
                    if self.deadline_ticks is not None else None)
        # class_mix=None draws no extra randomness, so existing seeded traces
        # (every bench gate replays one) stay byte-identical.
        cls = ""
        if self.class_mix is not None:
            names = sorted(self.class_mix)
            weights = np.array([self.class_mix[c] for c in names], dtype=float)
            u = self.rng.random() * weights.sum()
            cls = names[int(np.searchsorted(np.cumsum(weights), u, side="right")
                            .clip(0, len(names) - 1))]
        self._uid += 1
        return FleetRequest(uid=self._uid, prompt=prompt, max_new_tokens=mnt,
                            arrival_s=t, deadline_s=deadline, request_class=cls)

    def trace(self, n_requests: int) -> list[FleetRequest]:
        """``n_requests`` arrivals in order; repeated calls continue the
        stream (fresh generator + same seed -> identical trace)."""
        return [self._emit(self._next_arrival()) for _ in range(n_requests)]


class VariableRateTraffic(TrafficGenerator):
    """Non-homogeneous Poisson arrivals over a deterministic rate curve.

    Subclasses define :meth:`rate_at` (expected requests per tick at virtual
    instant ``t``) and :meth:`peak_rate` (its maximum).  Arrivals are drawn
    by Lewis–Shedler thinning: candidate gaps at the peak rate, each kept
    with probability ``rate_at(t) / peak_rate()`` — exact for any bounded
    rate curve, and seed-deterministic like the base generator.
    """

    def rate_at(self, t: float) -> float:
        raise NotImplementedError

    def peak_rate(self) -> float:
        raise NotImplementedError

    def _next_arrival(self) -> float:
        peak = self.peak_rate()
        mean_gap = self.tick_s / peak
        while True:
            self._t += float(self.rng.exponential(mean_gap))
            if self.rng.random() * peak <= self.rate_at(self._t):
                return self._t


class BurstyTraffic(VariableRateTraffic):
    """Square-wave load: a base rate punctuated by periodic bursts.

    Every ``burst_every_ticks`` ticks a burst of ``burst_len_ticks`` begins
    during which the arrival rate jumps from ``arrival_rate`` to
    ``burst_rate``; ``offset_ticks`` delays the first burst.  This is the
    canonical autoscaler workload: sustained spikes a fixed fleet must
    either over-provision for or shed.
    """

    def __init__(self, *, burst_rate: float, burst_every_ticks: float,
                 burst_len_ticks: float, offset_ticks: float = 0.0, **kw):
        super().__init__(**kw)
        if burst_rate < self.arrival_rate:
            raise ValueError("burst_rate must be >= arrival_rate")
        if not 0 < burst_len_ticks <= burst_every_ticks:
            raise ValueError("need 0 < burst_len_ticks <= burst_every_ticks")
        self.burst_rate = burst_rate
        self.burst_every_ticks = burst_every_ticks
        self.burst_len_ticks = burst_len_ticks
        self.offset_ticks = offset_ticks

    def phase_at(self, t: float) -> str:
        """``"burst"`` or ``"base"`` at virtual instant ``t``."""
        ticks = t / self.tick_s - self.offset_ticks
        if ticks < 0:
            return "base"
        return ("burst" if ticks % self.burst_every_ticks < self.burst_len_ticks
                else "base")

    def rate_at(self, t: float) -> float:
        return self.burst_rate if self.phase_at(t) == "burst" else self.arrival_rate

    def peak_rate(self) -> float:
        return self.burst_rate


class DiurnalTraffic(VariableRateTraffic):
    """Sinusoidal load: rate swings ``±amplitude`` around ``arrival_rate``
    with period ``period_ticks`` — the day/night demand curve, for
    predictive-scaling experiments and slow-ramp controller tests."""

    def __init__(self, *, period_ticks: float, amplitude: float | None = None,
                 **kw):
        super().__init__(**kw)
        if period_ticks <= 0:
            raise ValueError("period_ticks must be positive")
        self.period_ticks = period_ticks
        self.amplitude = (amplitude if amplitude is not None
                          else 0.8 * self.arrival_rate)
        if not 0 <= self.amplitude <= self.arrival_rate:
            raise ValueError("amplitude must lie in [0, arrival_rate]")

    def rate_at(self, t: float) -> float:
        phase = 2.0 * math.pi * (t / self.tick_s) / self.period_ticks
        return self.arrival_rate + self.amplitude * math.sin(phase)

    def peak_rate(self) -> float:
        return self.arrival_rate + self.amplitude


# ---------------------------------------------------------------------------
# Recorded-trace replay
# ---------------------------------------------------------------------------


def save_trace(path: str, requests: "list[FleetRequest]") -> None:
    """Write a request trace as JSON-lines (arrival order preserved).

    Only the *workload* fields are recorded — arrival time, prompt, token
    budget, deadline, EOS — so a saved trace replays identically regardless
    of what routing/scaling outcome it had when recorded.
    """
    with open(path, "w") as f:
        for r in requests:
            f.write(json.dumps({
                "uid": r.uid, "arrival_s": r.arrival_s, "prompt": r.prompt,
                "max_new_tokens": r.max_new_tokens,
                "deadline_s": r.deadline_s, "eos_id": r.eos_id,
                "request_class": r.request_class}) + "\n")


def load_trace(path: str) -> "list[FleetRequest]":
    """Load a trace saved by :func:`save_trace` (or a recorded production
    log in the same JSON-lines shape) for replay through a fleet."""
    out: list[FleetRequest] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            out.append(FleetRequest(
                uid=int(d["uid"]), prompt=[int(t) for t in d["prompt"]],
                max_new_tokens=int(d["max_new_tokens"]),
                arrival_s=float(d["arrival_s"]),
                deadline_s=(float(d["deadline_s"])
                            if d.get("deadline_s") is not None else None),
                eos_id=(int(d["eos_id"])
                        if d.get("eos_id") is not None else None),
                request_class=str(d.get("request_class", ""))))
    out.sort(key=lambda r: r.arrival_s)
    return out
