"""Per-class speculative acceptance tracking for routing decisions.

Speculative decoding only pays when the draft agrees with the target often
enough: the burst commits ``expected_committed_tokens(k, alpha)`` tokens for
a cost of ``(k+1)`` draft steps plus one verify, so the break-even acceptance
rate depends on the measured cost ratio.  ``alpha`` is a *traffic* property,
not a model property — chat-style continuations are easy to draft, bulk
extraction over rare tokens is not — so the fleet tracks it per request
class and decides spec-vs-plain per request at admit time.

:class:`AcceptanceTracker` mirrors :class:`~repro.fleet.demand.DemandTracker`
mechanics: decayed counters in virtual seconds (each observation's weight
halves every ``half_life_s`` of trace time), so a class whose draftability
shifts — a prompt-template change, say — re-converges instead of being
pinned to stale history.  A Beta-style prior (``prior_alpha`` worth of
``prior_weight`` pseudo-tokens) keeps cold classes optimistic enough to
*try* speculation and gather real evidence.
"""
from __future__ import annotations

#: Decayed weights below this drop the class entry entirely.
_EPS = 1e-9


class AcceptanceTracker:
    """Decayed per-class acceptance-rate estimates for speculative routing.

    ``record(cls, proposed, accepted, t)`` folds one burst's outcome in;
    ``alpha(cls)`` returns the current blended estimate.  Classes are plain
    strings; the empty string is the unclassified bucket and works like any
    other class.
    """

    def __init__(self, *, half_life_s: float | None = None,
                 prior_alpha: float = 0.7, prior_weight: float = 8.0):
        if half_life_s is not None and half_life_s <= 0:
            raise ValueError("half_life_s must be positive")
        if not 0.0 <= prior_alpha <= 1.0:
            raise ValueError("prior_alpha must lie in [0, 1]")
        if prior_weight < 0:
            raise ValueError("prior_weight must be non-negative")
        self.half_life_s = half_life_s
        self.prior_alpha = prior_alpha
        self.prior_weight = prior_weight
        self._proposed: dict[str, float] = {}
        self._accepted: dict[str, float] = {}
        self._now = 0.0  # stream clock: latest observation time seen

    def _decay_to(self, t: float) -> None:
        if self.half_life_s is None or t <= self._now:
            return
        factor = 0.5 ** ((t - self._now) / self.half_life_s)
        self._now = t
        for cls in list(self._proposed):
            p = self._proposed[cls] * factor
            if p < _EPS:
                del self._proposed[cls]
                del self._accepted[cls]
            else:
                self._proposed[cls] = p
                self._accepted[cls] *= factor

    def record(self, cls: str, proposed: int, accepted: int,
               t: float = 0.0) -> None:
        """Fold one burst outcome (``accepted`` of ``proposed`` draft tokens
        matched the target) observed at virtual instant ``t``."""
        if proposed < 0 or not 0 <= accepted <= max(proposed, 0):
            raise ValueError("need 0 <= accepted <= proposed")
        self._decay_to(t)
        if proposed == 0:
            return
        self._proposed[cls] = self._proposed.get(cls, 0.0) + proposed
        self._accepted[cls] = self._accepted.get(cls, 0.0) + accepted

    def alpha(self, cls: str = "") -> float:
        """Blended acceptance-rate estimate for ``cls``.

        With no evidence this is exactly ``prior_alpha``; evidence shifts the
        estimate toward the measured rate with weight proportional to the
        (decayed) observed token count.
        """
        p = self._proposed.get(cls, 0.0)
        a = self._accepted.get(cls, 0.0)
        denom = p + self.prior_weight
        if denom <= 0:
            return self.prior_alpha
        return (a + self.prior_alpha * self.prior_weight) / denom

    def observed(self, cls: str = "") -> float:
        """Decayed count of proposed tokens seen for ``cls`` (evidence mass)."""
        return self._proposed.get(cls, 0.0)

    def stats(self) -> dict:
        """Per-class ``{alpha, proposed}`` snapshot, plus the prior."""
        return {"prior_alpha": self.prior_alpha,
                "prior_weight": self.prior_weight,
                "half_life_s": self.half_life_s,
                "classes": {cls: {"alpha": round(self.alpha(cls), 4),
                                  "proposed": round(p, 2)}
                            for cls, p in sorted(self._proposed.items())}}
